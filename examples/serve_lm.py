"""Serve a small model with batched requests through the continuous-batching
engine (prefill → slot insert → lockstep decode → early slot recycling).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine

cfg = get_config("qwen2.5-32b", reduced=True)
params = lm.init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, max_slots=4, max_len=128, temperature=0.7)

rng = np.random.default_rng(0)
t0 = time.perf_counter()
for i in range(10):
    prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 20))).tolist()
    engine.add_request(prompt, max_new_tokens=int(rng.integers(4, 12)))

done = engine.run_to_completion()
dt = time.perf_counter() - t0
tokens = sum(len(r.generated) for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens / dt:.1f} tok/s, 4 slots, continuous batching)")
for r in done[:5]:
    print(f"  req {r.uid:2d} prompt_len={len(r.prompt):2d} → {r.generated}")
