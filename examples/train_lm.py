"""End-to-end training driver: a llama-style LM with DistrAttention, the
full substrate (data pipeline → train step → checkpoints → resume).

Default is a CPU-friendly ~1M-param model for a quick demo:

  PYTHONPATH=src python examples/train_lm.py --steps 100

The assignment-scale run (~100M params, few hundred steps) is:

  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse

from repro.configs import get_config
from repro.train.data import SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer


def build_config(preset: str):
    base = get_config("minicpm-2b", reduced=True)
    if preset == "tiny":
        return base  # ~0.4M params
    if preset == "100m":
        # ~100M params: 12L × d768 × ff2048, 12 heads, 16k vocab
        return base.replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
            d_ff=2048, vocab=16384, compute_dtype="float32",
        )
    raise ValueError(preset)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("tiny", "100m"), default="tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro_example_train")
    ap.add_argument(
        "--impl", default="distr",
        choices=("distr", "xla_flash", "pallas_distr", "pallas_flash"),
        help="pallas_* trains through the fused custom_vjp kernel path "
             "(compiled on TPU, interpret mode on CPU)",
    )
    args = ap.parse_args()

    cfg = build_config(args.preset)
    cfg = cfg.replace(attention=cfg.attention.with_impl(args.impl))
    opt = OptimizerConfig(
        peak_lr=3e-4 if args.preset == "100m" else 1e-3,
        warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps,
        schedule="wsd",
    )
    data = SyntheticLMData(cfg.vocab, args.batch, args.seq, seed=0)
    trainer = Trainer(cfg, opt, data, workdir=args.workdir, log_every=10,
                      ckpt_every=max(args.steps // 4, 10))
    hist = trainer.run(args.steps)
    print(
        f"\ndone: loss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f} "
        f"({len(hist)} steps, attention={args.impl}); "
        f"checkpoints in {args.workdir}/checkpoints"
    )


if __name__ == "__main__":
    main()
