"""Quickstart: DistrAttention in three steps.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    AttentionConfig,
    DistrConfig,
    attend,
    reference_attention,
)

# 1. Make some attention inputs (batch 2, 8 heads, 512 tokens, d=64).
#    Q/K share structure, like real transformer activations do — iid noise
#    makes softmax outputs collapse to the V-mean and relative errors
#    meaningless.
ks = jax.random.split(jax.random.PRNGKey(0), 4)
base = jax.random.normal(ks[0], (2, 8, 512, 64))
q = 2.0 * base + 0.5 * jax.random.normal(ks[1], (2, 8, 512, 64))
k = 2.0 * base + 0.5 * jax.random.normal(ks[2], (2, 8, 512, 64))
v = jax.random.normal(ks[3], (2, 8, 512, 64))

# 2. Exact attention vs DistrAttention (paper: group similar embedding-dim
#    columns with LSH, sample Q / fuse K, compute scores over d/G* dims).
exact = reference_attention(q, k, v, causal=True)
for g in (2, 4):
    cfg = AttentionConfig(
        impl="distr",
        distr=DistrConfig(group_size=g, block_q=128, block_k=128),
    )
    approx = attend(q, k, v, cfg, causal=True)
    rel = float(jnp.abs(approx - exact).mean() / jnp.abs(exact).mean())
    print(f"G*={g}: score-dim {64}→{64//g}, output rel err {rel:.4f}")

# 3. The same thing as a fused Pallas TPU kernel (interpret mode on CPU).
from repro.kernels import ops

out = ops.distr_attention(
    q, k, v, DistrConfig(group_size=2, block_q=128, block_k=128), causal=True
)
print("pallas kernel output:", out.shape, out.dtype, "finite:",
      bool(jnp.isfinite(out).all()))
