"""Side-by-side: exact attention, DistrAttention (XLA + Pallas), and the
paper's baseline family (Hydra / Flatten / Primal-lowrank / Hyper-sampled).

  PYTHONPATH=src python examples/attention_showcase.py
"""
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import AttentionConfig, DistrConfig, attend, reference_attention
from repro.core.baselines import BASELINES

B, H, N, D = 2, 8, 1024, 64
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, H, N, D))
k = jax.random.normal(ks[1], (B, H, N, D))
v = jax.random.normal(ks[2], (B, H, N, D))

exact = reference_attention(q, k, v, causal=True)

methods = {
    "exact_flash(xla)": jax.jit(functools.partial(
        attend, cfg=AttentionConfig(impl="xla_flash"), causal=True)),
    "distr_g2(xla)": jax.jit(functools.partial(
        attend, cfg=AttentionConfig(impl="distr", distr=DistrConfig(group_size=2)),
        causal=True)),
    "distr_g2(pallas)": jax.jit(functools.partial(
        attend,
        cfg=AttentionConfig(impl="pallas_distr", distr=DistrConfig(group_size=2)),
        causal=True)),
}
for name, fn in BASELINES.items():
    methods[name] = jax.jit(functools.partial(fn, causal=True))

print(f"{'method':22s} {'rel_err':>9s} {'cosine':>8s} {'ms':>8s}")
for name, fn in methods.items():
    out = fn(q, k, v)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    jax.block_until_ready(fn(q, k, v))
    ms = (time.perf_counter() - t0) * 1e3
    o = out.astype(jnp.float32)
    rel = float(jnp.abs(o - exact).mean() / jnp.abs(exact).mean())
    cos = float(jnp.sum(o * exact) / (jnp.linalg.norm(o) * jnp.linalg.norm(exact)))
    print(f"{name:22s} {rel:9.4f} {cos:8.4f} {ms:8.1f}")
