"""Training substrate: optimizer, schedules, checkpointing, trainer
fault-tolerance behaviours."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLMData
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    schedule,
)
from repro.train.trainer import Trainer
from repro.train.train_step import make_train_step


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt_cfg = OptimizerConfig(peak_lr=0.3, warmup_steps=0, total_steps=200,
                              schedule="constant", weight_decay=0.0)
    state = adamw_init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, opt_cfg,
                                     schedule(opt_cfg, jnp.asarray(step)))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5
    )


def test_schedules():
    base = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                           min_lr_ratio=0.1)
    cos = base
    wsd = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1, schedule="wsd", wsd_decay_frac=0.2)
    # warmup
    assert float(schedule(cos, jnp.asarray(5))) == pytest.approx(0.5)
    # cosine decays monotonically to floor
    lrs = [float(schedule(cos, jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(lrs, lrs[1:]))
    assert lrs[-1] == pytest.approx(0.1, abs=1e-3)
    # WSD holds at peak through the stable phase then decays linearly
    assert float(schedule(wsd, jnp.asarray(50))) == pytest.approx(1.0)
    assert float(schedule(wsd, jnp.asarray(79))) == pytest.approx(1.0)
    assert float(schedule(wsd, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_grad_accum_matches_full_batch():
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab),
    }
    o1 = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                         schedule="constant", grad_accum=1)
    o2 = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                         schedule="constant", grad_accum=2)
    p1, _, m1 = make_train_step(cfg, o1)(params, adamw_init(params), batch,
                                         jnp.asarray(0))
    p2, _, m2 = make_train_step(cfg, o2)(params, adamw_init(params), batch,
                                         jnp.asarray(0))
    # same data ⇒ same mean loss; updates match to Adam's sensitivity (the
    # m/√v normalisation amplifies fp reassociation on near-zero grads,
    # e.g. rarely-hit embedding rows — so the bound is loose but real).
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2
    )
    assert max(jax.tree_util.tree_leaves(d)) < 2e-2


def test_nan_guard_skips_update():
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab),
    }
    bad = {k: v for k, v in batch.items()}
    # poison the params instead of the batch: a NaN weight ⇒ NaN loss
    poisoned = jax.tree_util.tree_map(lambda x: x, params)
    poisoned["final_norm"]["scale"] = poisoned["final_norm"]["scale"] * jnp.nan
    opt_cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10)
    step = make_train_step(cfg, opt_cfg)
    new_params, _, metrics = step(poisoned, adamw_init(poisoned), bad, jnp.asarray(0))
    assert float(metrics["skipped"]) == 1.0
    # params unchanged (update suppressed)
    same = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.all((a == b) | (jnp.isnan(a) & jnp.isnan(b)))),
        poisoned, new_params,
    )
    assert all(jax.tree_util.tree_leaves(same))


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = get_config("mamba2-130m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    for s in (10, 20, 30, 40):
        ckpt.save_checkpoint(str(tmp_path), s, params, opt, {"step": s}, keep=2)
    assert ckpt.list_checkpoints(str(tmp_path)) == [30, 40]  # GC kept last 2
    tmpl_p = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    tmpl_o = jax.eval_shape(adamw_init, tmpl_p)
    step, p2, o2, meta = ckpt.load_checkpoint(str(tmp_path), tmpl_p, tmpl_o)
    assert step == 40 and meta["data_state"]["step"] == 40
    chk = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.array_equal(a, b)), params, p2
    )
    assert all(jax.tree_util.tree_leaves(chk))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg = get_config("mamba2-130m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ckpt.save_checkpoint(str(tmp_path), 1, params)
    other = get_config("minicpm-2b", reduced=True)
    tmpl = jax.eval_shape(lambda k: lm.init_params(k, other), jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        ckpt.load_checkpoint(str(tmp_path), tmpl)


@pytest.mark.slow
def test_trainer_end_to_end_resume(tmp_path):
    cfg = get_config("minicpm-2b", reduced=True)
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=40)
    data = SyntheticLMData(cfg.vocab, batch=4, seq_len=32, seed=0)
    tr = Trainer(cfg, opt_cfg, data, workdir=str(tmp_path), ckpt_every=10,
                 log_every=100)
    hist = tr.run(20)
    assert hist[-1]["loss"] < hist[0]["loss"]
    tr2 = Trainer(cfg, opt_cfg, data, workdir=str(tmp_path), ckpt_every=10,
                  log_every=100)
    assert tr2.step == 20  # resumed
    tr2.run(5)
    assert tr2.step == 25
