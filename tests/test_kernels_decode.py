"""Split-K flash-decoding kernels (kernels/decode.py + ops.decode_attention):
parity vs the pure-JAX decode references across GQA ratios, ragged live
lengths, dtypes, speculative q_len, the ring cache, and a multi-step engine
decode that matches full-sequence prefill logits (slow)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import grouping
from repro.core.api import AttentionConfig, attend_decode
from repro.core.flash_reference import reference_attention
from repro.kernels import ops
from repro.models import lm
from repro.models.attention import cache_insert
from repro.roofline.analysis import decode_attention_cost
from repro.serve import kv_cache
from repro.serve.serve_step import make_decode_step, make_prefill


def _qkv(seed, b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    return q, k, v


def _masked_ref(q, k, v, lengths, scale=None):
    kv_mask = jnp.arange(k.shape[2])[None, :] < lengths[:, None]
    return reference_attention(
        q, k.astype(q.dtype), v.astype(q.dtype),
        causal=False, scale=scale, kv_mask=kv_mask,
    )


# (b, hq, hkv, S, d, lengths, block_k, dtype) — ragged lengths cover
# length < block, length spanning split boundaries, part-filled tail
# blocks, and the full cache.
DECODE_CASES = [
    (1, 1, 1, 128, 64, (5,), 64, jnp.float32),           # < one block
    (2, 4, 4, 256, 64, (37, 256), 64, jnp.float32),      # MHA, ragged
    (2, 8, 2, 256, 64, (64, 129), 64, jnp.float32),      # GQA 4:1, split edge
    (2, 8, 1, 512, 32, (1, 511), 128, jnp.float32),      # GQA 8:1, extremes
    (2, 4, 2, 192, 32, (100, 192), 64, jnp.float32),     # non-pow2 cache
    (2, 8, 2, 256, 64, (64, 200), 64, jnp.bfloat16),     # bf16
]


@pytest.mark.parametrize("b,hq,hkv,s,d,lengths,block_k,dtype", DECODE_CASES)
def test_decode_op_vs_reference(b, hq, hkv, s, d, lengths, block_k, dtype):
    q, k, v = _qkv(0, b, hq, hkv, s, d, dtype)
    lens = jnp.asarray(lengths, jnp.int32)
    out = ops.decode_attention(q, k, v, lengths=lens, block_k=block_k)
    want = _masked_ref(q, k, v, lens)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("g", [2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_op_fused_vs_reference(g, dtype):
    """Distr fused-K̂ variant: the kernel's sampled-Q × fused-K̂ scores match
    the dense reference over the fused cache (exact parity — the
    *approximation* story vs raw K is benchmarks/distr_decode.py)."""
    b, hq, hkv, s, d = 2, 8, 2, 256, 64
    q, k, v = _qkv(1, b, hq, hkv, s, d, dtype)
    lens = jnp.asarray([50, 222], jnp.int32)
    perm = jnp.stack([
        jax.random.permutation(jax.random.PRNGKey(10 + h), d)
        for h in range(hkv)
    ]).astype(jnp.int32)
    k_f = grouping.fuse_columns(
        k.astype(jnp.float32), perm[None], g
    ).astype(dtype)
    scale = 1.0 / d ** 0.5
    out = ops.decode_attention(
        q, None, v, lengths=lens, k_fused=k_f, perm=perm, group_size=g,
        scale=scale, block_k=64,
    )
    want = attend_decode(
        q, None, v, AttentionConfig(impl="reference"), lengths=lens,
        k_fused=k_f, perm=perm, group_size=g, scale=scale,
    )
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_decode_op_speculative_window():
    """q_len > 1: packed row i sees the cache minus its successors."""
    b, hq, hkv, s, d, ql = 2, 4, 2, 256, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, hq, ql, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    lens = jnp.asarray([9, 200], jnp.int32)
    out = ops.decode_attention(q, k, v, lengths=lens, block_k=64)
    outs = []
    for i in range(ql):
        li = lens - (ql - 1 - i)
        outs.append(_masked_ref(q[:, :, i : i + 1], k, v, li))
    want = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4
    )


def test_decode_op_full_cache_no_lengths():
    """lengths=None ⇒ every slot live (cross-attention style)."""
    q, k, v = _qkv(3, 2, 4, 4, 128, 32, jnp.float32)
    out = ops.decode_attention(q, k, v, block_k=64)
    want = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4
    )


def test_ring_cache_insert_wraps():
    """Absolute positions past S wrap to pos % S (ring invariant)."""
    b, h, s, d = 2, 2, 8, 4
    cache = jnp.zeros((b, h, s, d))
    new = jnp.ones((b, h, 1, d))
    pos = jnp.asarray([s + 3, 2 * s], jnp.int32)  # slots 3 and 0
    out = cache_insert(cache, new, pos)
    assert float(out[0, 0, 3, 0]) == 1.0 and float(out[0, 0, 2, 0]) == 0.0
    assert float(out[1, 0, 0, 0]) == 1.0 and float(out[1, 0, 1, 0]) == 0.0


def test_decode_cost_model_live_length_scaling():
    """Acceptance: per-token KV bytes scale with live length, not max_len —
    ≥2× fewer bytes at length=64 vs length=512 (and the fused variant
    strictly cheaper than plain at equal length)."""
    kw = dict(b=1, hq=8, hkv=2, max_len=512, d=64, block_k=64)
    c64 = decode_attention_cost(length=64, **kw)
    c512 = decode_attention_cost(length=512, **kw)
    assert c512["kv_bytes"] >= 2 * c64["kv_bytes"]
    # the dense (pre-kernel) path pays max_len regardless of live length
    assert c64["dense_kv_bytes"] == c512["dense_kv_bytes"]
    assert c64["kv_bytes"] < c64["dense_kv_bytes"]
    c64_fused = decode_attention_cost(length=64, group_size=2, **kw)
    assert c64_fused["kv_bytes"] < c64["kv_bytes"]


def test_block_decode_apply_kernel_matches_reference_impl():
    """models-layer parity: the same weights/cache decoded via the kernel
    path (xla_flash → attend_decode → ops.decode_attention) and via the
    pure-JAX reference produce the same per-layer output."""
    from repro.models import transformer

    cfg = get_config("qwen1.5-4b", reduced=True)
    cfg_k = cfg.replace(attention=cfg.attention.with_impl("xla_flash"))
    cfg_r = cfg.replace(attention=cfg.attention.with_impl("reference"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)["blocks"]
    lp = jax.tree_util.tree_map(lambda p: p[0], params)

    b, s, dm = 2, 64, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, dm), jnp.float32)
    cache = {
        "k": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_kv_heads, s, cfg.head_dim_)
        ),
        "v": jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_kv_heads, s, cfg.head_dim_)
        ),
    }
    pos = jnp.asarray([5, 41], jnp.int32)
    got, ck = transformer.block_decode_apply(
        lp, x, cfg_k, "dense", cache=dict(cache), cache_index=pos
    )
    want, cr = transformer.block_decode_apply(
        lp, x, cfg_r, "dense", cache=dict(cache), cache_index=pos
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4
    )
    np.testing.assert_allclose(np.asarray(ck["k"]), np.asarray(cr["k"]))


def test_fused_decode_kernel_matches_reference_impl():
    """attention_decode_fused parity: kernel fused-K̂ path vs the pure-JAX
    fused reference, same static perm and caches."""
    from repro.models.attention import attention_decode_fused

    cfg = get_config("qwen2.5-32b", reduced=True)
    cfg = cfg.replace(
        attention=dataclasses.replace(
            cfg.attention, impl="xla_flash", distr_decode=True
        )
    )
    cfg_ref = cfg.replace(
        attention=dataclasses.replace(cfg.attention, impl="reference")
    )
    g = cfg.attention.distr.group_size
    params = lm.init_params(jax.random.PRNGKey(0), cfg)["blocks"]
    lp = jax.tree_util.tree_map(lambda p: p[0], params)["attn"]

    b, s, dh = 2, 64, cfg.head_dim_
    x = jax.random.normal(jax.random.PRNGKey(4), (b, 1, cfg.d_model))
    perm = kv_cache.static_perms(cfg, n_layers=1)[0]
    cache_v = jax.random.normal(jax.random.PRNGKey(5), (b, cfg.n_kv_heads, s, dh))
    cache_kf = jax.random.normal(
        jax.random.PRNGKey(6), (b, cfg.n_kv_heads, s, dh // g)
    )
    pos = jnp.asarray([7, 33], jnp.int32)
    got, _ = attention_decode_fused(
        lp, x, cfg, cache_k=None, cache_v=cache_v, cache_k_fused=cache_kf,
        perm=perm, cache_index=pos,
    )
    want, _ = attention_decode_fused(
        lp, x, cfg_ref, cache_k=None, cache_v=cache_v, cache_k_fused=cache_kf,
        perm=perm, cache_index=pos,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4
    )


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen1.5-4b", "qwen2.5-32b"])
def test_multistep_engine_decode_matches_prefill_logits(arch):
    """Teacher-forced multi-step decode on the kernel path reproduces the
    full-sequence forward logits at every decoded position."""
    cfg = get_config(arch, reduced=True)
    cfg = cfg.replace(attention=cfg.attention.with_impl("xla_flash"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S0, T, MAX = 2, 16, 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + T), 0, cfg.vocab)

    logits_full, _ = lm.forward(params, cfg, toks)
    _, cache = make_prefill(cfg, MAX)(params, toks[:, :S0])
    decode = jax.jit(make_decode_step(cfg))
    for t in range(T):
        pos = jnp.full((B,), S0 + t, jnp.int32)
        got, cache = decode(params, toks[:, S0 + t : S0 + t + 1], cache, pos)
        want = logits_full[:, S0 + t]
        rel = float(jnp.abs(want - got[:, 0]).max()) / max(
            float(jnp.abs(want).max()), 1e-6
        )
        assert rel < 5e-3, f"{arch} step {t}: rel err {rel}"
