"""Cluster chaos suite: the multi-replica router under replica loss.

The contract (DESIGN.md §Cluster tier) extends the single-engine
robustness contract across replicas: every non-cancelled request reaches
exactly one terminal status even when a replica dies mid-flight, the
client-facing token stream carries no duplicated or reordered token
(at-most-once redelivery, asserted per uid against the router's emitted
ledger), and survivors leak no KV blocks.

Fast tests drive the router over deterministic fake replica clients whose
next token is a pure function of the full sequence — so a redelivered
request must reproduce the healthy run's stream bit-identically.  Slow
tests run 3 real ``PagedServeEngine`` replicas and kill / wedge /
NaN-poison them.
"""
import itertools
from collections import Counter

import pytest

from repro.serve import cluster, lifecycle
from repro.serve.cluster import (
    DEAD, DRAINED, DRAINING, HEALTHY, ClusterRouter, EngineReplica,
    LeastQueuePolicy, PowerOfTwoPolicy, ReplicaHandle, RoundRobinPolicy,
    make_policy,
)
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.lifecycle import COUNTER_KEYS, METRIC_KEYS, IncompleteRun


# ---------------------------------------------------------------------------
# Deterministic fake replica client
# ---------------------------------------------------------------------------


def next_token(seq: list[int]) -> int:
    """Pure function of the whole sequence — the fake's 'greedy model'.
    A replay from prompt + emitted sees the same sequence prefix, so it
    regenerates exactly the tokens the dead replica would have produced."""
    return (seq[-1] * 31 + 7 * len(seq)) % 1009


def expected_stream(prompt: list[int], n: int,
                    eos_id: int | None = None) -> list[int]:
    seq = list(prompt)
    out = []
    for _ in range(n):
        t = next_token(seq)
        seq.append(t)
        out.append(t)
        if eos_id is not None and t == eos_id:
            break
    return out


class _FakeReq:
    def __init__(self, uid, prompt, max_new, eos_id):
        self.uid = uid
        self.prompt = list(prompt)
        self.max_new_tokens = max_new
        self.eos_id = eos_id
        self.prompt_left = len(prompt)
        self.generated = []
        self.status = lifecycle.QUEUED
        self.degrade_group = 1


class FakeReplicaClient:
    """The replica-client surface over a deterministic toy engine:
    chunked prefill (``chunk`` prompt tokens per tick) and one decode
    token per tick, ``lanes`` requests at a time, FCFS."""

    def __init__(self, chunk=4, lanes=2, wedged=False):
        self._uid = itertools.count()
        self.reqs: dict[int, _FakeReq] = {}
        self.order: list[int] = []
        self.chunk = chunk
        self.lanes = lanes
        self.wedged = wedged
        self.steps = 0
        self._counters = Counter()

    # -- client surface --------------------------------------------------

    def submit(self, prompt, *, max_new_tokens, eos_id=None,
               deadline_ttft=None, deadline_e2e=None) -> int:
        if not prompt:
            raise ValueError("prompt must hold at least one token")
        uid = next(self._uid)
        self.reqs[uid] = _FakeReq(uid, prompt, max_new_tokens, eos_id)
        self.order.append(uid)
        return uid

    def cancel(self, uid) -> bool:
        r = self.reqs.get(uid)
        if r is None or lifecycle.is_terminal(r.status):
            return False
        r.status = lifecycle.CANCELLED
        self._counters["cancelled"] += 1
        return True

    def _live(self):
        return [self.reqs[u] for u in self.order
                if not lifecycle.is_terminal(self.reqs[u].status)]

    def step(self):
        self.steps += 1
        done = []
        if self.wedged:
            return done
        for r in self._live()[: self.lanes]:
            if r.prompt_left > 0:
                r.prompt_left -= self.chunk
                r.status = lifecycle.PREFILL
                if r.prompt_left > 0:
                    continue
            r.status = lifecycle.RUNNING
            seq = r.prompt + r.generated
            t = next_token(seq)
            r.generated.append(t)
            if (len(r.generated) >= r.max_new_tokens
                    or (r.eos_id is not None and t == r.eos_id)):
                r.status = lifecycle.DONE
                done.append(r)
        return done

    def has_work(self) -> bool:
        return bool(self._live())

    def queue_depth(self) -> int:
        return max(0, len(self._live()) - self.lanes)

    def degrade_level(self) -> int:
        return 0

    def counters(self) -> dict:
        return lifecycle.counters_view(self._counters)

    def pool_free(self):
        return None

    def lookup(self, uid):
        return self.reqs.get(uid)


class TickClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _drive(router, clock=None, max_ticks=500):
    for _ in range(max_ticks):
        router.tick()
        if clock is not None:
            clock.t += 1
        if not router.has_work():
            return
    raise AssertionError("router did not drain within max_ticks")


def _mk_router(n=3, policy="round_robin", faults=None, clock=None, **ckw):
    clients = [FakeReplicaClient(**ckw) for _ in range(n)]
    r = ClusterRouter(clients, policy=policy, faults=faults,
                      clock=clock or (lambda: 0.0))
    return r, clients


PROMPTS = [[3, 5, 8], [11, 4, 9, 2, 6], [7, 7], [21, 13, 5, 1],
           [2, 9, 4, 4, 8, 1], [5], [17, 3], [8, 8, 8, 2], [1, 2]]


def _submit_all(router, max_new=5):
    return [router.add_request(p, max_new_tokens=max_new) for p in PROMPTS]


def _assert_all_terminal(router, uids):
    for uid in uids:
        creq = router.request(uid)
        assert lifecycle.is_terminal(creq.status), (uid, creq.status)


# ---------------------------------------------------------------------------
# Satellite: frozen counters/metrics schema across engines + scheduler
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    import jax

    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged_engine(small_lm, **kw):
    from repro.serve.engine import PagedServeEngine

    cfg, params = small_lm
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return PagedServeEngine(cfg, params, **kw)


def _slot_engine(small_lm, **kw):
    from repro.serve.engine import ServeEngine

    cfg, params = small_lm
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    return ServeEngine(cfg, params, **kw)


def test_counters_schema_frozen_across_engines(small_lm):
    """The router's health model reads counters_snapshot() blindly:
    ServeEngine, PagedServeEngine, and Scheduler must report the exact
    canonical key set, zero-filled — silent key drift is a regression."""
    from repro.serve.scheduler import Scheduler, SchedulerConfig

    slot = _slot_engine(small_lm)
    paged = _paged_engine(small_lm)
    sched = Scheduler(SchedulerConfig(), clock=lambda: 0.0)
    for snap in (slot.counters_snapshot(), paged.counters_snapshot(),
                 sched.counters_snapshot()):
        assert set(snap) == set(COUNTER_KEYS)
        assert all(v == 0 for v in snap.values())
    # Counters that were incremented survive the freeze...
    slot.counters["shed"] += 2
    assert slot.counters_snapshot()["shed"] == 2
    # ...and off-schema keys cannot leak into the snapshot.
    slot.counters["brand_new_counter"] += 1
    assert "brand_new_counter" not in slot.counters_snapshot()


def test_metrics_schema_frozen_across_engines(small_lm):
    """metrics() rows from both engines carry exactly METRIC_KEYS; the
    router's rows are a superset (it adds rid / redeliveries)."""
    slot = _slot_engine(small_lm)
    paged = _paged_engine(small_lm)
    for eng in (slot, paged):
        eng.add_request([1, 2, 3], max_new_tokens=2)
        eng.run_to_completion(max_steps=100)
        rows = eng.metrics()
        assert rows, "engine finished no request"
        for row in rows:
            assert set(row) == set(METRIC_KEYS)
    router, _ = _mk_router(n=1)
    router.add_request([1, 2, 3], max_new_tokens=2)
    _drive(router)
    (row,) = router.metrics()
    assert set(METRIC_KEYS) < set(row)
    assert {"rid", "redeliveries"} <= set(row)


def test_cancel_parity_unknown_and_terminal_uids(small_lm):
    """Satellite: cancel(uid) returns False — and never raises — for
    unknown, negative, and already-terminal uids on BOTH engines; a live
    uid cancels exactly once."""
    for eng in (_slot_engine(small_lm), _paged_engine(small_lm)):
        assert eng.cancel(0) is False  # nothing submitted yet
        assert eng.cancel(-1) is False
        assert eng.cancel(10**9) is False
        uid = eng.add_request([1, 2, 3], max_new_tokens=4)
        assert eng.cancel(uid) is True  # queued
        assert eng.cancel(uid) is False  # already terminal
        done_uid = eng.add_request([1, 2, 3], max_new_tokens=2)
        eng.run_to_completion(max_steps=100)
        assert eng.cancel(done_uid) is False  # done
        snap = eng.counters_snapshot()
        assert snap["cancelled"] == 1


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------


class _DepthClient(FakeReplicaClient):
    def __init__(self, depth):
        super().__init__()
        self._depth = depth

    def queue_depth(self):
        return self._depth


def _handles(depths):
    return [ReplicaHandle(rid, _DepthClient(d))
            for rid, d in enumerate(depths)]


def test_make_policy_registry():
    assert isinstance(make_policy("round_robin"), RoundRobinPolicy)
    assert isinstance(make_policy("least_queue"), LeastQueuePolicy)
    assert isinstance(make_policy("p2c"), PowerOfTwoPolicy)
    p = LeastQueuePolicy()
    assert make_policy(p) is p
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("dartboard")


def test_round_robin_cycles_in_rid_order():
    hs = _handles([0, 0, 0])
    pol = RoundRobinPolicy()
    picks = [pol.choose(hs).rid for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    # a replica leaving the candidate set doesn't break the cycle
    picks = [pol.choose(hs[1:]).rid for _ in range(4)]
    assert set(picks) == {1, 2}


def test_least_queue_picks_shallowest():
    hs = _handles([5, 2, 9])
    assert LeastQueuePolicy().choose(hs).rid == 1
    # deterministic tie-break on rid
    hs = _handles([2, 2, 2])
    assert LeastQueuePolicy().choose(hs).rid == 0


def test_p2c_prefers_healthier_and_is_seeded():
    hs = _handles([0, 0, 0])
    hs[1]._fail_ewma = 50.0  # a failing replica scores near zero
    pol_a = PowerOfTwoPolicy(seed=7)
    pol_b = PowerOfTwoPolicy(seed=7)
    picks_a = [pol_a.choose(hs).rid for _ in range(40)]
    picks_b = [pol_b.choose(hs).rid for _ in range(40)]
    assert picks_a == picks_b, "same seed must route identically"
    # whenever the sick replica was sampled, the other candidate won
    assert picks_a.count(1) == 0
    assert set(picks_a) == {0, 2}


def test_health_score_signals():
    h = ReplicaHandle(0, _DepthClient(0))
    base = h.health_score()
    assert base == 1.0
    h.client._depth = 8  # deep queue → lower score
    assert h.health_score() < base
    h.client._depth = 0
    h.missed = 1  # missed heartbeat decays linearly toward death
    assert 0.0 < h.health_score() < 1.0
    h.missed = h.heartbeat_misses
    assert h.health_score() == 0.0
    h.missed = 0
    h.crashed = True
    assert h.health_score() == 0.0


def test_health_failure_ewma_decays():
    h = ReplicaHandle(0, _DepthClient(0))
    h.client._counters["failed_numeric"] += 4
    h.observe()  # delta of 4 lands in the EWMA
    sick = h.health_score()
    assert sick < 0.5
    for _ in range(8):
        h.observe()  # no new failures: halves every tick
    assert h.health_score() > sick
    assert h.health_score() > 0.9


# ---------------------------------------------------------------------------
# Failover: kill a replica mid-flight (fake replicas, exact determinism)
# ---------------------------------------------------------------------------


def test_failover_at_most_once_bit_identical():
    """The headline: kill replica 1 mid-flight; every request terminal,
    every DONE stream bit-identical to the healthy run (the fake's next
    token is a pure function of the sequence, so any duplicated, dropped,
    or reordered emission would diverge), redelivery counted."""
    healthy, _ = _mk_router()
    uids_h = _submit_all(healthy)
    _drive(healthy)
    want = {u: list(healthy.request(u).emitted) for u in uids_h}
    assert all(healthy.request(u).status == lifecycle.DONE for u in uids_h)
    for u, p in zip(uids_h, PROMPTS):
        assert want[u] == expected_stream(p, 5)

    faults = FaultInjector([FaultSpec("replica_crash", uid=1, after=2)])
    router, _ = _mk_router(faults=faults)
    uids = _submit_all(router)
    _drive(router)
    _assert_all_terminal(router, uids)
    snap = router.counters_snapshot()
    assert snap["replica_deaths"] == 1
    assert snap["redelivered"] > 0
    assert router.replica_states()[1] == DEAD
    redelivered = [u for u in uids if router.request(u).redeliveries > 0]
    assert redelivered, "the dead replica held no in-flight work"
    for u in uids:
        creq = router.request(u)
        assert creq.status == lifecycle.DONE
        assert creq.emitted == want[u], (
            f"uid {u} stream diverged (redelivered={creq.redeliveries})"
        )
        assert len(creq.emitted) <= creq.max_new_tokens


def test_failover_regenerates_unobserved_tokens_without_duplicates():
    """Tokens the dead replica generated but the router never observed
    are REgenerated on the survivor, not duplicated: the replay prompt
    carries only the emitted ledger."""
    router, clients = _mk_router(n=2, policy="round_robin")
    uid = router.add_request([3, 5, 8], max_new_tokens=6)
    creq = router.request(uid)
    router.tick()  # chunk 4 covers the 3-token prompt → first token
    router.tick()  # second token
    assert creq.emitted, "no token observed before the crash"
    observed = list(creq.emitted)
    # the replica generates one more token the router never harvests
    r = clients[0].lookup(creq.ruid)
    seq = r.prompt + r.generated
    r.generated.append(next_token(seq))
    # kill replica 0 before the next harvest
    router.faults = FaultInjector([FaultSpec("replica_crash", uid=0)])
    _drive(router)
    assert creq.status == lifecycle.DONE
    assert creq.redeliveries == 1
    assert creq.emitted[: len(observed)] == observed
    assert creq.emitted == expected_stream([3, 5, 8], 6), (
        "unobserved token was duplicated or dropped on replay"
    )


def test_failover_finishes_request_whose_budget_was_met():
    """A replica dying between generating the last token and finalizing:
    the ledger already satisfies the stop condition, so redelivery
    finalizes DONE instead of replaying — no survivor ever sees it."""
    router, clients = _mk_router(n=2)
    # budget met
    creq = cluster.ClusterRequest(99, [5], 2)
    creq.emitted = expected_stream([5], 2)
    router._all[99] = creq
    router._inflight[99] = creq
    router._redeliver(creq, [])
    assert creq.status == lifecycle.DONE
    assert creq.redeliveries == 0
    # eos already emitted
    ceos = cluster.ClusterRequest(100, [5], 8, eos_id=42)
    ceos.emitted = [7, 42]
    router._all[100] = ceos
    router._inflight[100] = ceos
    router._redeliver(ceos, [])
    assert ceos.status == lifecycle.DONE
    assert router.counters_snapshot()["redelivered"] == 0
    assert all(not c.reqs for c in clients), "stop-met replay hit a replica"


def test_heartbeat_detection_latency():
    """A crashed replica is declared dead exactly after heartbeat_misses
    missed ticks — not before, not later."""
    faults = FaultInjector([FaultSpec("replica_crash", uid=0)])
    router, _ = _mk_router(n=2, faults=faults, clock=None)
    router.heartbeat_misses = 3
    for h in router.replicas:
        h.heartbeat_misses = 3
    router.add_request([3, 5, 8, 9], max_new_tokens=8)
    router.tick()  # crash fires; miss 1
    assert router.replica_states()[0] == HEALTHY
    router.tick()  # miss 2
    assert router.replica_states()[0] == HEALTHY
    router.tick()  # miss 3 → dead
    assert router.replica_states()[0] == DEAD


def test_raising_step_is_treated_as_crash():
    class ExplodingClient(FakeReplicaClient):
        def step(self):
            raise RuntimeError("segfault, basically")

    router = ClusterRouter([ExplodingClient(), FakeReplicaClient()],
                           policy="round_robin", clock=lambda: 0.0)
    uid0 = router.add_request([3, 5, 8], max_new_tokens=3)  # → replica 0
    uid1 = router.add_request([7, 7], max_new_tokens=3)  # → replica 1
    _drive(router)
    assert router.replica_states()[0] == DEAD
    for uid in (uid0, uid1):
        assert router.request(uid).status == lifecycle.DONE
    assert router.request(uid0).redeliveries == 1


def test_all_replicas_dead_fails_inflight_and_rejects_new():
    faults = FaultInjector([
        FaultSpec("replica_crash", uid=0), FaultSpec("replica_crash", uid=1),
    ])
    router, _ = _mk_router(n=2, faults=faults)
    uid = router.add_request([3, 5, 8, 1, 1, 1, 1, 1], max_new_tokens=8)
    for _ in range(4):
        router.tick()
    assert router.request(uid).status == lifecycle.FAILED
    assert router.counters_snapshot()["failover_failed"] == 1
    late = router.add_request([4, 4], max_new_tokens=2)
    assert router.request(late).status == lifecycle.REJECTED
    assert router.counters_snapshot()["no_replica_rejects"] == 1
    assert not router.has_work()


def test_redelivery_respects_remaining_deadline():
    """A request whose e2e deadline lapsed while its replica was dying is
    expired at redelivery time, not replayed."""
    clock = TickClock()
    faults = FaultInjector([FaultSpec("replica_crash", uid=0, after=1)])
    router, _ = _mk_router(n=2, faults=faults, clock=clock)
    # long prompt: still prefilling when the crash lands
    uid = router.add_request([9] * 20, max_new_tokens=4, deadline_e2e=2)
    ok = router.add_request([7, 7], max_new_tokens=2)  # replica 1
    for _ in range(8):
        router.tick()
        clock.t += 1
    assert router.request(uid).status == lifecycle.EXPIRED
    assert router.request(ok).status == lifecycle.DONE


# ---------------------------------------------------------------------------
# Cancel propagation
# ---------------------------------------------------------------------------


def test_router_cancel_propagates_to_owning_replica():
    router, clients = _mk_router()
    uids = _submit_all(router, max_new=8)
    router.tick()
    target = router.request(uids[1])
    rid, ruid = target.rid, target.ruid
    assert router.cancel(uids[1]) is True
    assert target.status == lifecycle.CANCELLED
    assert clients[rid].reqs[ruid].status == lifecycle.CANCELLED
    assert router.cancel(uids[1]) is False  # already terminal
    assert router.cancel(10**9) is False  # unknown
    _drive(router)
    _assert_all_terminal(router, uids)
    assert router.counters_snapshot()["cancelled"] == 1


def test_cancelled_requests_are_not_redelivered():
    faults = FaultInjector([FaultSpec("replica_crash", uid=0, after=3)])
    router, _ = _mk_router(n=2, faults=faults)
    uid = router.add_request([9] * 12, max_new_tokens=8)  # → replica 0
    router.tick()
    assert router.cancel(uid) is True
    _drive(router, max_ticks=20)
    creq = router.request(uid)
    assert creq.status == lifecycle.CANCELLED
    assert creq.redeliveries == 0
    assert router.counters_snapshot()["redelivered"] == 0


# ---------------------------------------------------------------------------
# Draining
# ---------------------------------------------------------------------------


def test_drain_fences_admission_and_quiesces():
    router, clients = _mk_router()
    uids = _submit_all(router, max_new=6)
    router.tick()
    router.drain(1)
    assert router.replica_states()[1] == DRAINING
    submitted_before = len(clients[1].reqs)
    late = [router.add_request([4, 2], max_new_tokens=2) for _ in range(6)]
    assert len(clients[1].reqs) == submitted_before, (
        "a draining replica must not receive new work"
    )
    _drive(router)
    _assert_all_terminal(router, uids + late)
    assert all(router.request(u).status == lifecycle.DONE
               for u in uids + late)
    assert router.replica_states()[1] == DRAINED
    # double-drain is a no-op; resume returns it to rotation
    router.drain(1)
    assert router.counters_snapshot()["drains"] == 1
    router.resume(1)
    assert router.replica_states()[1] == HEALTHY


def test_drain_migrate_moves_inflight_bit_identically():
    healthy, _ = _mk_router()
    uids_h = _submit_all(healthy, max_new=6)
    _drive(healthy)
    want = {u: list(healthy.request(u).emitted) for u in uids_h}

    router, clients = _mk_router()
    uids = _submit_all(router, max_new=6)
    router.tick()
    moved = [c for c in map(router.request, uids)
             if c.rid == 1 and not lifecycle.is_terminal(c.status)]
    assert moved
    router.drain(1, migrate=True)
    snap = router.counters_snapshot()
    assert snap["migrated"] == len(moved)
    assert snap["redelivered"] == len(moved)
    for c in moved:
        assert c.rid != 1, "migrated request still owned by the drained replica"
    _drive(router)
    assert router.replica_states()[1] == DRAINED
    for u in uids:
        creq = router.request(u)
        assert creq.status == lifecycle.DONE
        assert creq.emitted == want[u], f"uid {u} diverged across migration"


def test_replace_dead_replica_restores_capacity():
    faults = FaultInjector([FaultSpec("replica_crash", uid=0, after=1)])
    router, _ = _mk_router(n=2, faults=faults)
    uids = _submit_all(router, max_new=4)
    _drive(router)
    assert router.replica_states()[0] == DEAD
    with pytest.raises(ValueError, match="dead"):
        router.drain(0)
    with pytest.raises(ValueError, match="dead"):
        router.resume(0)
    router.replace(0, FakeReplicaClient())
    assert router.replica_states()[0] == HEALTHY
    late = [router.add_request([4, 2], max_new_tokens=2) for _ in range(4)]
    _drive(router)
    assert all(router.request(u).status == lifecycle.DONE
               for u in uids + late)
    rids = {router.request(u).rid for u in late}
    assert 0 in rids, "replaced replica never rejoined the rotation"


# ---------------------------------------------------------------------------
# Health-aware routing under a wedged replica
# ---------------------------------------------------------------------------


def test_least_queue_routes_around_wedged_replica():
    """A wedged replica (steps but makes no progress — a stuck pool) piles
    up queue depth; the health-aware policies steer new work away while
    the blind round-robin keeps feeding it."""
    for policy, expect_skew in (("least_queue", True), ("round_robin", False)):
        clients = [FakeReplicaClient(), FakeReplicaClient(wedged=True),
                   FakeReplicaClient()]
        router = ClusterRouter(clients, policy=policy, clock=lambda: 0.0)
        landed = Counter()
        for i in range(24):
            uid = router.add_request([3 + i, 5], max_new_tokens=2)
            landed[router.request(uid).rid] += 1
            router.tick()
        if expect_skew:
            assert landed[1] <= 2, f"least_queue kept feeding the wedge: {landed}"
        else:
            assert landed[1] >= 7, landed
        # unwedge so the suite leaves nothing stuck, then drain
        clients[1].wedged = False
        _drive(router)


def test_p2c_health_weighting_prefers_clean_replica():
    clients = [FakeReplicaClient(), FakeReplicaClient()]
    router = ClusterRouter(clients, policy="p2c", policy_seed=3,
                           clock=lambda: 0.0)
    # replica 0 reports a failure burst through its counters
    clients[0]._counters["failed_numeric"] += 10
    router.replicas[0].observe()
    landed = Counter()
    for i in range(16):
        uid = router.add_request([2 + i], max_new_tokens=1)
        landed[router.request(uid).rid] += 1
    assert landed[1] > landed[0], landed
    _drive(router)


# ---------------------------------------------------------------------------
# Capability steering (ISSUE 9: mesh-capable replicas)
# ---------------------------------------------------------------------------


class _CappedClient(FakeReplicaClient):
    """A fake replica advertising a prompt-length capability, like a real
    ``EngineReplica`` over an engine with a bounded cache (``None`` =
    unlimited, the legacy surface)."""

    def __init__(self, cap, **kw):
        super().__init__(**kw)
        self.max_prompt_len = cap


def test_capability_steering_routes_long_prompts_to_capable_replica():
    """A prompt longer than a replica's advertised max_prompt_len never
    lands there: round-robin cycles over the CAPABLE candidates only,
    while short prompts still spread over everyone."""
    clients = [_CappedClient(4), _CappedClient(4), _CappedClient(None)]
    router = ClusterRouter(clients, policy="round_robin",
                           clock=lambda: 0.0)
    long_prompt = list(range(1, 13))  # 12 > 4
    long_uids = [router.add_request(long_prompt, max_new_tokens=3)
                 for _ in range(3)]
    for u in long_uids:
        assert router.request(u).rid == 2, (
            "long prompt routed to an incapable replica"
        )
    short_uids = [router.add_request([1, 2], max_new_tokens=2)
                  for _ in range(6)]
    assert {router.request(u).rid for u in short_uids} == {0, 1, 2}
    _drive(router)
    for u in long_uids:
        creq = router.request(u)
        assert creq.status == lifecycle.DONE
        assert creq.emitted == expected_stream(long_prompt, 3)
    assert router.counters_snapshot()["capability_rejects"] == 0


def test_capability_reject_when_no_replica_can_hold_prompt():
    clients = [_CappedClient(4), _CappedClient(6)]
    router = ClusterRouter(clients, policy="round_robin",
                           clock=lambda: 0.0)
    uid = router.add_request(list(range(10)), max_new_tokens=2)
    assert router.request(uid).status == lifecycle.REJECTED
    snap = router.counters_snapshot()
    assert snap["capability_rejects"] == 1
    assert snap["no_replica_rejects"] == 0  # replicas were routable
    assert not router.has_work()


def test_failover_replay_respects_capability():
    """Redelivery after a crash filters survivors by prompt+emitted length:
    the replay lands only on a replica that can hold it, and the stream
    stays bit-identical."""
    clients = [_CappedClient(64), _CappedClient(4), _CappedClient(64)]
    faults = FaultInjector([FaultSpec("replica_crash", uid=0, after=2)])
    router = ClusterRouter(clients, policy="round_robin", faults=faults,
                           clock=lambda: 0.0)
    prompt = list(range(3, 11))  # 8 tokens: only rids 0 and 2 can hold it
    uid = router.add_request(prompt, max_new_tokens=6)
    assert router.request(uid).rid == 0
    _drive(router)
    creq = router.request(uid)
    assert creq.status == lifecycle.DONE
    assert creq.redeliveries == 1
    assert creq.rid == 2, "replay landed on an incapable replica"
    assert creq.emitted == expected_stream(prompt, 6)


def test_failover_fails_when_no_capable_survivor():
    """If the only replica that could hold a request dies and no survivor
    is capable, the request FAILS (failover_failed) — it is never wedged
    into a replica that would reject or corrupt it — while short work on
    the survivor keeps completing."""
    clients = [_CappedClient(64), _CappedClient(4)]
    faults = FaultInjector([FaultSpec("replica_crash", uid=0, after=1)])
    router = ClusterRouter(clients, policy="round_robin", faults=faults,
                           clock=lambda: 0.0)
    uid = router.add_request(list(range(8)), max_new_tokens=6)
    assert router.request(uid).rid == 0
    ok = router.add_request([1, 2], max_new_tokens=2)
    _drive(router, max_ticks=60)
    assert router.request(uid).status == lifecycle.FAILED
    assert router.counters_snapshot()["failover_failed"] == 1
    assert router.request(ok).status == lifecycle.DONE


def test_engine_replica_advertises_max_prompt_len(small_lm):
    """The real-engine surface: a slot engine advertises max_len, a paged
    engine min(max_len, capacity − 1) — the numbers add_request actually
    enforces."""
    slot = _slot_engine(small_lm, max_len=64)
    paged = _paged_engine(small_lm, max_len=64, block_size=8)
    assert EngineReplica(slot).max_prompt_len() == 64
    assert EngineReplica(paged).max_prompt_len() == min(
        64, paged.capacity_tokens - 1
    )
    # a client with no capability surface routes as unlimited
    assert ClusterRouter._capacity(
        ReplicaHandle(0, FakeReplicaClient())
    ) is None


# ---------------------------------------------------------------------------
# run_to_completion / misc
# ---------------------------------------------------------------------------


def test_run_to_completion_raises_incomplete_run_on_wedge():
    router, _ = _mk_router(n=1, wedged=True)
    uid = router.add_request([1, 2], max_new_tokens=2)
    with pytest.raises(IncompleteRun) as ei:
        router.run_to_completion(max_ticks=10)
    assert uid in ei.value.uids


def test_router_counter_schema_frozen():
    router, _ = _mk_router()
    snap = router.counters_snapshot()
    assert set(snap) == set(cluster.ROUTER_COUNTER_KEYS)
    assert all(v == 0 for v in snap.values())
    assert set(router.cluster_counters()) == set(COUNTER_KEYS)


def test_add_request_validation_propagates():
    router, _ = _mk_router()
    with pytest.raises(ValueError):
        router.add_request([], max_new_tokens=2)
    assert not router.has_work()
    assert router.counters_snapshot()["routed"] == 0


# ---------------------------------------------------------------------------
# Reference-bound regression gate (benchmarks/regress.py)
# ---------------------------------------------------------------------------


def _regress():
    """Import benchmarks.regress (namespace package off the repo root,
    which tests/conftest.py does not put on sys.path)."""
    import os
    import sys

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    if root not in (os.path.abspath(p) for p in sys.path):
        sys.path.insert(0, root)
    from benchmarks import regress

    return regress


def test_regress_bound_checker(tmp_path):
    import json

    regress = _regress()
    Bound, check_bound, check_all = (
        regress.Bound, regress.check_bound, regress.check_all)

    records = [
        {"kind": "policy", "goodput": 0.2},  # wrong kind: never selected
        {"kind": "summary", "kill_goodput_retention": 0.9, "policy": "rr"},
    ]
    ok = Bound(path="BENCH_x.json", kind="summary",
               metric="kill_goodput_retention", floor=0.85)
    assert check_bound(records, ok) == []
    tight = Bound(path="BENCH_x.json", kind="summary",
                  metric="kill_goodput_retention", floor=0.95)
    (msg,) = check_bound(records, tight)
    assert "0.900" in msg and "0.950" in msg
    missing_metric = Bound(path="BENCH_x.json", kind="summary",
                           metric="nope", floor=0.5)
    (msg,) = check_bound(records, missing_metric)
    assert "lacks" in msg
    no_match = Bound(path="BENCH_x.json", kind="summary", metric="x",
                     floor=0.5, match=(("policy", "p2c"),))
    (msg,) = check_bound(records, no_match)
    assert "no kind=" in msg

    # end-to-end over files: a good file passes, a missing file fails
    good = tmp_path / "BENCH_x.json"
    good.write_text(json.dumps(records))
    assert check_all((ok,), root=str(tmp_path)) == []
    assert check_all((tight,), root=str(tmp_path))
    gone = Bound(path="BENCH_gone.json", kind="summary", metric="m",
                 floor=0.0)
    (msg,) = check_all((gone,), root=str(tmp_path))
    assert "unreadable" in msg


def test_regress_committed_bounds_hold():
    """The committed BENCH files must satisfy the recorded floors — the
    same check CI runs after the benchmark smoke pass."""
    assert _regress().check_all() == []


# ---------------------------------------------------------------------------
# Real engines: 3-replica cluster chaos (slow)
# ---------------------------------------------------------------------------


REAL_PROMPTS = [list(range(3, 11)), list(range(5, 17)), list(range(2, 8)),
                list(range(20, 29)), list(range(40, 45)), list(range(6, 18))]


def _real_router(small_lm, n=3, *, faults=None, engine_faults=None,
                 policy="round_robin", **ekw):
    engines = [
        _paged_engine(
            small_lm,
            faults=None if engine_faults is None else engine_faults.get(i),
            **ekw,
        )
        for i in range(n)
    ]
    router = ClusterRouter(engines, policy=policy, faults=faults)
    return router, engines


def _run_real(router, max_new=5):
    uids = [router.add_request(p, max_new_tokens=max_new)
            for p in REAL_PROMPTS]
    router.run_to_completion(max_ticks=600)
    return uids


@pytest.mark.slow
def test_real_cluster_kill_replica_mid_flight(small_lm):
    """ISSUE 7 acceptance: 3 real paged replicas, mixed-length workload,
    replica 1 killed mid-flight.  Every request terminal; requests that
    never touched the dead replica are BIT-IDENTICAL to the healthy run;
    redelivered requests never duplicate or reorder a token (their
    pre-crash emitted prefix is preserved exactly and the total stream
    length honors the budget); survivors leak no KV blocks."""
    healthy, _ = _real_router(small_lm)
    uids_h = _run_real(healthy)
    want = {u: list(healthy.request(u).emitted) for u in uids_h}
    assert all(healthy.request(u).status == lifecycle.DONE for u in uids_h)

    faults = FaultInjector([FaultSpec("replica_crash", uid=1, after=3)])
    router, engines = _real_router(small_lm, faults=faults)
    free0 = {i: e.cache.pool.num_free for i, e in enumerate(engines)}
    uids = _run_real(router)

    assert router.replica_states()[1] == DEAD
    snap = router.counters_snapshot()
    assert snap["replica_deaths"] == 1
    assert snap["redelivered"] > 0
    redelivered = [u for u in uids if router.request(u).redeliveries > 0]
    assert redelivered, "the dead replica held no in-flight work"
    for u in uids:
        creq = router.request(u)
        assert creq.status == lifecycle.DONE, (u, creq.status)
        assert len(creq.emitted) == creq.max_new_tokens
        if creq.redeliveries == 0:
            assert creq.emitted == want[u], (
                f"survivor uid {u} diverged under the replica kill"
            )
        else:
            # At-most-once: the pre-crash prefix is emitted exactly once
            # and never reordered; the regenerated tail may round-trip a
            # different kernel path (chunked replay vs decode), so exact
            # equality is asserted only on the fake-engine suite.
            k = creq.base
            assert creq.emitted[:k] == want[u][:k], (
                f"redelivered uid {u} duplicated or reordered its prefix"
            )
    # survivors' pools drain clean (the dead replica's state is garbage)
    for i, e in enumerate(engines):
        if i != 1:
            assert e.cache.pool.num_free == free0[i], (
                f"replica {i} leaked KV blocks"
            )


@pytest.mark.slow
def test_real_cluster_wedge_and_nan_quarantine(small_lm):
    """One replica's pool wedges (persistent pool_exhausted → its engine
    watchdog fails the victim), another NaN-poisons one request (numeric
    quarantine) — the cluster keeps serving, only the two victims fail,
    and no replica leaks blocks."""
    engine_faults = {
        0: FaultInjector([FaultSpec("pool_exhausted", uid=0, times=-1)]),
        1: FaultInjector([FaultSpec("nan_logits", uid=0, after=1,
                                    times=-1)]),
    }
    router, engines = _real_router(small_lm, engine_faults=engine_faults)
    free0 = {i: e.cache.pool.num_free for i, e in enumerate(engines)}
    uids = _run_real(router)
    # round-robin: cluster uid i → replica i%3, engine-local uid i//3 == 0
    # for the first three — so cluster uids 0 and 1 are the two victims.
    by_uid = {u: router.request(u) for u in uids}
    assert by_uid[uids[0]].status == lifecycle.FAILED  # wedged pool
    assert by_uid[uids[1]].status == lifecycle.FAILED  # NaN storm
    for u in uids[2:]:
        assert by_uid[u].status == lifecycle.DONE, (u, by_uid[u].status)
    agg = router.cluster_counters()
    assert agg["failed_numeric"] >= 1
    assert agg["watchdog_fails"] >= 1
    for i, e in enumerate(engines):
        assert e.cache.pool.num_free == free0[i], f"replica {i} leaked"
    # the failure burst shows up in the health model
    assert router.health()[2] >= max(router.health()[0],
                                     router.health()[1])


@pytest.mark.slow
def test_real_cluster_mixed_slot_and_paged_replicas(small_lm):
    """The replica surface covers both engine kinds: a slot engine and a
    paged engine serve one cluster, and draining the paged replica moves
    admission to the slot one."""
    engines = [_slot_engine(small_lm), _paged_engine(small_lm)]
    router = ClusterRouter(engines, policy="round_robin")
    uids = [router.add_request(p, max_new_tokens=4)
            for p in REAL_PROMPTS[:4]]
    router.drain(1)
    late = router.add_request(REAL_PROMPTS[4], max_new_tokens=4)
    assert router.request(late).rid == 0
    router.run_to_completion(max_ticks=600)
    for u in uids + [late]:
        assert router.request(u).status == lifecycle.DONE
    assert router.replica_states()[1] == DRAINED
