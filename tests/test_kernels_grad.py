"""Gradient checks for the custom_vjp Pallas attention ops (interpret mode).

``jax.grad`` through ``kernels.ops.flash_attention`` must match the gradient
of the naive softmax oracle; through ``kernels.ops.distr_attention`` it must
match the pure-JAX ``core.distr_attention`` under the same fixed permutations
(proj_seed shared).  Sweeps causal/non-causal, GQA q_per_kv > 1, ragged N
not divisible by the block, shared_kv_perm, and the mean estimator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistrConfig
from repro.core.distr_attention import distr_attention as core_distr
from repro.kernels import ops, ref


def _qkv(seed, b, hq, hkv, n, nk, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, nk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, nk, d)).astype(dtype)
    return q, k, v


def _loss(attn_fn, d):
    """Non-uniform cotangent so dO varies per output element."""
    w = jnp.cos(jnp.arange(d, dtype=jnp.float32))

    def loss(q, k, v):
        return (attn_fn(q, k, v).astype(jnp.float32) * w).sum()

    return loss


def _check_grads(got, want, tol):
    for name, g, w in zip("qkv", got, want):
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            atol=tol, rtol=tol, err_msg=f"d{name} mismatch",
        )


FLASH_GRAD_CASES = [
    # (b, hq, hkv, n, nk, d, dtype, causal)
    (1, 1, 1, 128, 128, 64, jnp.float32, False),
    (2, 4, 4, 128, 128, 64, jnp.float32, True),
    (2, 8, 2, 128, 128, 64, jnp.float32, True),    # GQA
    (1, 2, 2, 100, 100, 32, jnp.float32, True),    # ragged N (100 % 64 != 0)
    (1, 2, 2, 128, 256, 64, jnp.float32, False),   # rectangular
    (2, 4, 2, 128, 128, 64, jnp.bfloat16, True),   # bf16 + GQA
]


@pytest.mark.parametrize("b,hq,hkv,n,nk,d,dtype,causal", FLASH_GRAD_CASES)
def test_flash_grad_vs_reference(b, hq, hkv, n, nk, d, dtype, causal):
    q, k, v = _qkv(0, b, hq, hkv, n, nk, d, dtype)
    kernel = _loss(
        lambda q, k, v: ops.flash_attention(
            q, k, v, causal=causal, block_q=64, block_k=64
        ), d,
    )
    oracle = _loss(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=causal), d
    )
    got = jax.grad(kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    _check_grads(got, want, tol)


DISTR_GRAD_CASES = [
    # (b, hq, hkv, n, d, g, dtype, causal, cfg_kw)
    (1, 1, 1, 128, 64, 2, jnp.float32, False, {}),
    (2, 4, 4, 128, 64, 2, jnp.float32, True, {}),
    (2, 8, 2, 128, 64, 4, jnp.float32, True, {}),            # GQA + G*=4
    (1, 2, 2, 100, 64, 2, jnp.float32, True, {}),            # ragged N (100 % 64 != 0)
    (2, 4, 2, 128, 64, 2, jnp.float32, True, {"shared_kv_perm": True}),
    (1, 2, 2, 128, 64, 2, jnp.float32, True, {"estimator": "mean"}),
    (2, 4, 4, 128, 64, 2, jnp.bfloat16, True, {}),           # bf16
]


@pytest.mark.parametrize("b,hq,hkv,n,d,g,dtype,causal,cfg_kw", DISTR_GRAD_CASES)
def test_distr_grad_vs_core(b, hq, hkv, n, d, g, dtype, causal, cfg_kw):
    q, k, v = _qkv(1, b, hq, hkv, n, n, d, dtype)
    cfg = DistrConfig(group_size=g, block_q=64, block_k=64, **cfg_kw)
    kernel = _loss(
        lambda q, k, v: ops.distr_attention(q, k, v, cfg, causal=causal), d
    )
    core = _loss(lambda q, k, v: core_distr(q, k, v, cfg, causal=causal), d)
    got = jax.grad(kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(core, argnums=(0, 1, 2))(q, k, v)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    _check_grads(got, want, tol)


def test_distr_grad_straight_through_permutation():
    """No gradient may flow into the LSH stage: dQ must live entirely in the
    sampled columns' scatter image (for the sample estimator, each Q column
    outside the per-block sampled set gets exactly zero gradient)."""
    q, k, v = _qkv(2, 1, 1, 1, 64, 64, 64, jnp.float32)
    cfg = DistrConfig(group_size=2, block_q=64, block_k=64)
    loss = _loss(
        lambda q, k, v: ops.distr_attention(q, k, v, cfg, causal=False), 64
    )
    dq = jax.grad(loss)(q, k, v)
    nonzero_cols = int((jnp.abs(dq[0, 0]).sum(axis=0) > 0).sum())
    assert nonzero_cols == 64 // cfg.group_size


def test_train_step_runs_on_kernel_path():
    """A full train step differentiates through the pallas_distr impl —
    the checkpoint-scan XLA path is no longer load-bearing for training."""
    from repro.configs import get_config
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import make_train_step
    from repro.models import lm

    cfg = get_config("minicpm-2b", reduced=True)
    cfg = cfg.replace(attention=cfg.attention.with_impl("pallas_distr"))
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=1, total_steps=2)
    step = make_train_step(cfg, opt_cfg)

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    from repro.train.optimizer import adamw_init

    opt_state = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 65), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    params2, _, metrics = step(params, opt_state, batch, jnp.zeros((), jnp.int32))
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0.0
