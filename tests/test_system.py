"""End-to-end behaviour tests for the paper's system.

The paper's claim chain: (1) DistrAttention approximates exact attention
closely, (2) it slots into a full training/serving stack without changing
shapes or adding parameters, (3) a model trained with it converges like the
exact-attention model (paper Fig. 8 / §4.3-4.4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.train.data import SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer


def test_distr_is_dropin_same_params_same_shapes():
    """Paper §4.3: 'neither the output shape nor token number is changed;
    no additional parameters are introduced'."""
    cfg_exact = get_config("minicpm-2b", reduced=True)
    cfg_exact = cfg_exact.replace(attention=cfg_exact.attention.with_impl("xla_flash"))
    cfg_distr = get_config("minicpm-2b", reduced=True)  # distr by default

    p1 = lm.init_params(jax.random.PRNGKey(0), cfg_exact)
    p2 = lm.init_params(jax.random.PRNGKey(0), cfg_distr)
    s1 = jax.tree_util.tree_map(lambda x: x.shape, p1)
    s2 = jax.tree_util.tree_map(lambda x: x.shape, p2)
    assert s1 == s2  # identical parameter tree

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg_exact.vocab)
    l1, _ = lm.forward(p1, cfg_exact, toks)
    l2, _ = lm.forward(p2, cfg_distr, toks)
    assert l1.shape == l2.shape
    # approximation quality at random init: logits strongly correlated and
    # top-1 predictions agree far above the 1/vocab chance level
    a = l1.astype(jnp.float32).reshape(-1)
    b = l2.astype(jnp.float32).reshape(-1)
    corr = float(jnp.corrcoef(jnp.stack([a, b]))[0, 1])
    assert corr > 0.5, corr
    agree = float((l1.argmax(-1) == l2.argmax(-1)).mean())
    assert agree > 10.0 / cfg_exact.vocab, agree


@pytest.mark.slow
def test_training_with_distr_tracks_exact(tmp_path):
    """Fig. 8 analogue: loss curves of exact vs DistrAttention training stay
    close on the synthetic LM task."""
    losses = {}
    for name, impl in (("exact", "xla_flash"), ("distr", "distr")):
        cfg = get_config("minicpm-2b", reduced=True)
        cfg = cfg.replace(attention=cfg.attention.with_impl(impl))
        opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=40)
        data = SyntheticLMData(cfg.vocab, batch=8, seq_len=64, seed=0)
        tr = Trainer(cfg, opt, data, workdir=str(tmp_path / name),
                     log_every=1000, ckpt_every=1000)
        hist = tr.run(30)
        losses[name] = [h["loss"] for h in hist]
    # both converge
    assert losses["exact"][-1] < losses["exact"][0]
    assert losses["distr"][-1] < losses["distr"][0]
    # final losses within 10% of each other
    assert abs(losses["distr"][-1] - losses["exact"][-1]) / losses["exact"][-1] < 0.10


@pytest.mark.slow
def test_train_then_serve_roundtrip(tmp_path):
    """Train a model, checkpoint, reload, and serve it — full lifecycle."""
    cfg = get_config("qwen1.5-4b", reduced=True)
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    data = SyntheticLMData(cfg.vocab, batch=4, seq_len=32, seed=1)
    tr = Trainer(cfg, opt, data, workdir=str(tmp_path), log_every=1000,
                 ckpt_every=1000)
    tr.run(10)

    from repro.train import checkpoint as ckpt

    tmpl = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    _, params, _, _ = ckpt.load_checkpoint(str(tmp_path / "checkpoints"), tmpl)
    eng = ServeEngine(cfg, params, max_slots=2, max_len=64)
    eng.add_request([1, 2, 3, 4], max_new_tokens=5)
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].generated) == 5


def test_long_context_decode_ssm_constant_state():
    """SSM decode state is O(1) in sequence length — the property that
    qualifies mamba2/zamba2 for the long_500k cell."""
    from repro.serve.kv_cache import cache_struct

    cfg = get_config("mamba2-130m", reduced=True)
    small = cache_struct(cfg, 1, 1024)
    large = cache_struct(cfg, 1, 524288)
    assert small["ssm"].shape == large["ssm"].shape
    assert small["conv"].shape == large["conv"].shape
