"""HLO cost walker: validated against hand-computable modules."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis as A


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_shape_bytes():
    assert A._shape_bytes("f32", "4,8") == 128
    assert A._shape_bytes("bf16", "10") == 20
    assert A._shape_bytes("pred", "") == 1


def test_scan_trip_count_multiplication():
    def f(w, x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = A.hlo_cost(_compiled_text(f, w, x))
    assert cost["flops"] == pytest.approx(7 * 2 * 128**3, rel=0.02)


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = A.hlo_cost(_compiled_text(f, w, x))
    assert cost["flops"] == pytest.approx(15 * 2 * 64**3, rel=0.02)


def test_einsum_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    cost = A.hlo_cost(_compiled_text(f, a, b))
    assert cost["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.02)


def test_roofline_terms_dataclass():
    t = A.RooflineTerms(
        compute_s=1.0, memory_s=2.0, collective_s=0.5,
        flops_per_dev=1, hbm_bytes_per_dev=1, coll_bytes_per_dev=1,
        coll_by_op={},
    )
    assert t.dominant == "memory"
    assert t.step_time_s == 2.0


def test_model_flops_conventions():
    from repro.configs import SHAPES, get_config

    cfg = get_config("qwen2.5-32b")
    train = A.model_flops(cfg, SHAPES["train_4k"], active=30_000_000_000)
    decode = A.model_flops(cfg, SHAPES["decode_32k"], active=30_000_000_000)
    assert train == 6.0 * 30e9 * 256 * 4096
    assert decode == 2.0 * 30e9 * 128  # one token per sequence


def test_active_params_moe_discount():
    import jax

    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("llama4-scout-17b-a16e")
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    total, active = A.active_params(cfg, shapes)
    assert total > 100e9
    # top-1 of 16 experts + shared ⇒ far fewer active than total
    assert active < 0.3 * total
