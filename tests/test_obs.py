"""Observability layer: tracing, typed metrics, validators, clock audit.

The contracts under test (DESIGN.md §Observability):

  * trace determinism — on an injected fake clock, span timestamps and
    durations are bit-deterministic; ring eviction never corrupts a span
    that is still open; Chrome export round-trips through JSON and the
    structural validator.
  * metrics ↔ counters bit-consistency — every frozen counter key
    (lifecycle / router / train.elastic schemas) appears in its registry
    exactly once, with values equal to ``counters_snapshot()`` verbatim;
    a request's trace end-event args equal its ``metrics()`` row after a
    JSON round-trip (the "reconstruct terminal status + timing from the
    trace" acceptance).
  * tracing off = free — the NullRecorder's per-call overhead is bounded
    by a benchmark assertion, so leaving instrumentation sites
    unconditional costs nothing measurable.
  * clock audit — no serve/train module reads wall time directly; every
    time read flows through the injectable obs.clock discipline.
"""
import json
import os
import time

import pytest

from repro.obs import (
    NULL_RECORDER,
    MetricsRegistry,
    NullRecorder,
    TraceRecorder,
    achieved_fraction,
    get_recorder,
    perf_clock,
    resolve_clock,
    roofline_lower_bound_s,
    router_registry,
    serving_registry,
    set_recorder,
    train_registry,
    use_recorder,
    utilization_columns,
)
from repro.obs.validate import validate_chrome_trace, validate_metrics_snapshot
from repro.serve import lifecycle
from repro.serve.cluster import ROUTER_COUNTER_KEYS
from repro.serve.lifecycle import COUNTER_KEYS, METRIC_KEYS
from repro.train.elastic import COUNTER_KEYS as TRAIN_COUNTER_KEYS

# Reuse the chaos/cluster/train fakes — the obs layer binds duck-typed to
# the same public surfaces, so the fakes exercise the identical code paths.
from test_chaos import FakeEngine, FakeReq, TickClock, _sched, drive
from test_cluster import FakeReplicaClient, PROMPTS, _drive, _mk_router
from test_train_chaos import FakeTrainer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class StepClock:
    """Fake clock that advances by a fixed step on every read — makes
    span begin/end timestamps bit-deterministic."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        t, self.t = self.t, self.t + self.step
        return t


# ---------------------------------------------------------------------------
# obs.clock: the injectable-clock discipline
# ---------------------------------------------------------------------------


def test_resolve_clock_defaults_to_perf_clock():
    assert resolve_clock(None) is perf_clock
    tick = TickClock()
    assert resolve_clock(tick) is tick


def test_clock_audit_serve_train_never_read_time_directly():
    """Grep-enforced: no module under src/repro/serve or src/repro/train
    (or obs itself, outside obs/clock.py) calls time.time/perf_counter/
    monotonic or even imports time — all time reads must flow through the
    injectable clock so chaos tests stay tick-deterministic."""
    roots = [os.path.join(SRC, "repro", d) for d in ("serve", "train", "obs")]
    whitelist = {os.path.join(SRC, "repro", "obs", "clock.py")}
    needles = ("import time", "time.time(", "time.perf_counter",
               "time.monotonic")
    offenders = []
    for root in roots:
        for dirpath, _, files in os.walk(root):
            for fname in files:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                if path in whitelist:
                    continue
                with open(path) as f:
                    src = f.read()
                for needle in needles:
                    if needle in src:
                        offenders.append((os.path.relpath(path, SRC), needle))
    assert not offenders, (
        f"direct wall-time reads outside obs/clock.py: {offenders}"
    )


# ---------------------------------------------------------------------------
# obs.trace: recorder semantics
# ---------------------------------------------------------------------------


def test_span_nesting_deterministic_on_fake_clock():
    """Nested sync spans on a step clock produce exact, repeatable
    timestamps: inner closes first (LIFO), outer's duration covers it."""
    def build():
        rec = TraceRecorder(clock=StepClock())
        with rec.span("outer", step=1):
            with rec.span("inner"):
                pass
        return list(rec.events)

    evs = build()
    assert [e["name"] for e in evs] == ["inner", "outer"]
    inner, outer = evs
    # StepClock reads: outer t0=0, inner t0=1, inner end=2, outer end=3.
    assert (inner["t"], inner["dur"]) == (1.0, 1.0)
    assert (outer["t"], outer["dur"]) == (0.0, 3.0)
    assert outer["args"] == {"step": 1}
    assert build() == evs  # bit-deterministic across runs


def test_ring_eviction_never_corrupts_open_spans():
    """Flooding the ring past maxlen while a span is open evicts completed
    events (counted in .dropped) but the open span still closes intact."""
    rec = TraceRecorder(clock=StepClock(), maxlen=4)
    with rec.span("long_lived"):
        for i in range(10):
            rec.instant("flood", i=i)
        # mid-flight: the open span exports as an unclosed "B" event
        doc = rec.to_chrome()
        assert [e for e in doc["traceEvents"] if e["ph"] == "B"]
    # 10 instants through a 4-slot ring drop 6; the span's own completion
    # event displaces a 7th — but the span itself survives (it lived on
    # the open stack, not in the ring, until it closed).
    assert rec.dropped == 7
    names = [e["name"] for e in rec.events]
    assert "long_lived" in names, "open span lost to ring eviction"
    assert not rec._open


def test_async_spans_namespaced_ids():
    """ns() hands each component a distinct namespace so engine-local uid
    counters cannot collide across replicas."""
    rec = TraceRecorder(clock=StepClock())
    ns_a, ns_b = rec.ns(), rec.ns()
    assert ns_a != ns_b
    rec.begin("request", f"{ns_a}:0", uid=0)
    rec.begin("request", f"{ns_b}:0", uid=0)
    rec.end("request", f"{ns_a}:0", status="done")
    rec.end("request", f"{ns_b}:0", status="failed")
    ids = [e["id"] for e in rec.events]
    assert len(set(ids)) == 2
    assert validate_chrome_trace(rec.to_chrome()) == []


def test_chrome_export_round_trips_and_validates(tmp_path):
    """save() → json.load → structural validator: every event taxonomy
    (sync X, async b/e, instant i, still-open B) conforms."""
    rec = TraceRecorder(clock=StepClock())
    ns = rec.ns()
    rec.begin("request", f"{ns}:7", uid=7)
    with rec.span("prefill", uid=7):
        rec.instant("first_token", uid=7)
    rec.end("request", f"{ns}:7", uid=7, status="done")
    open_span = rec.span("decode")
    open_span.__enter__()  # deliberately left open
    path = tmp_path / "trace.json"
    rec.save(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    phases = sorted(e["ph"] for e in doc["traceEvents"])
    assert phases == ["B", "X", "b", "e", "i"]
    ts_units = {e["name"]: e["ts"] for e in doc["traceEvents"]}
    assert ts_units["prefill"] == 1e6  # seconds → microseconds
    assert doc["otherData"]["dropped_events"] == 0
    open_span.__exit__(None, None, None)


def test_trace_validator_rejects_malformed_docs():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{}]}) != []
    # end without a begin for the same (name, id)
    orphan = {"traceEvents": [
        {"name": "r", "ph": "e", "ts": 0, "pid": 0, "tid": 0,
         "id": "1:1", "cat": "async"},
    ]}
    assert any("end without begin" in p for p in validate_chrome_trace(orphan))
    # complete event lacking dur
    no_dur = {"traceEvents": [
        {"name": "s", "ph": "X", "ts": 0, "pid": 0, "tid": 0},
    ]}
    assert any("dur" in p for p in validate_chrome_trace(no_dur))


def test_global_recorder_install_and_scoping():
    assert get_recorder() is NULL_RECORDER
    rec = TraceRecorder(clock=StepClock())
    with use_recorder(rec):
        assert get_recorder() is rec
        with use_recorder(None):
            assert get_recorder() is NULL_RECORDER
        assert get_recorder() is rec
    assert get_recorder() is NULL_RECORDER
    set_recorder(rec)
    try:
        assert get_recorder() is rec
    finally:
        set_recorder(None)
    assert get_recorder() is NULL_RECORDER


def test_null_recorder_is_inert():
    n = NullRecorder()
    assert n.enabled is False and NULL_RECORDER.enabled is False
    with n.span("anything", big=list(range(10))):
        n.begin("r", "1:1", uid=1)
        n.end("r", "1:1", uid=1)
        n.instant("x")
    assert n.to_chrome()["traceEvents"] == []


def test_null_recorder_overhead_unmeasurable():
    """Acceptance: tracing disabled by default at zero measurable
    overhead.  The disabled path (one method call returning a shared
    no-op context manager) must stay within a generous per-call budget —
    catches anyone adding allocation or formatting to the hot path."""
    n = NULL_RECORDER
    iters = 50_000
    t0 = time.perf_counter()
    for i in range(iters):
        with n.span("decode", n_active=4):
            pass
        n.instant("tick", i=i)
    per_call_us = (time.perf_counter() - t0) / (2 * iters) * 1e6
    assert per_call_us < 25.0, (
        f"NullRecorder costs {per_call_us:.2f}us/call — no longer free"
    )


# ---------------------------------------------------------------------------
# obs.metrics: registry semantics + Prometheus/JSON export
# ---------------------------------------------------------------------------


def test_registry_type_and_name_validation():
    reg = MetricsRegistry()
    reg.counter("requests", "total requests")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("requests")
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.counter("c").inc(-1)
    with pytest.raises(ValueError, match="ascend"):
        reg.histogram("h", buckets=(2.0, 1.0))
    # a bound schema cannot collide with an existing typed metric
    reg.counter("eng_shed")
    with pytest.raises(ValueError, match="already registered"):
        reg.bind_counters("eng", lambda: {"shed": 0})


def test_histogram_observe_and_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 5.0))
    for v in (0.5, 0.7, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [2, 1, 1]
    assert h.cumulative() == [2, 3, 4]
    assert h.count == 4 and h.sum == pytest.approx(104.2)


def test_prometheus_text_golden():
    """Exact text exposition: HELP/TYPE lines, cumulative buckets with a
    +Inf terminal, _sum/_count — byte-for-byte."""
    reg = MetricsRegistry()
    reg.bind_counters("eng", lambda: {"shed": 3}, help="frozen")
    reg.counter("rows", "rows emitted").inc(2)
    reg.gauge("depth", "queue depth").set(1.5)
    h = reg.histogram("ttft_s", "time to first token", buckets=(0.5, 2.0))
    h.observe(0.1)
    h.observe(7.0)
    assert reg.to_prometheus() == (
        "# HELP eng_shed frozen\n"
        "# TYPE eng_shed counter\n"
        "eng_shed 3\n"
        "# HELP rows rows emitted\n"
        "# TYPE rows counter\n"
        "rows 2\n"
        "# HELP depth queue depth\n"
        "# TYPE depth gauge\n"
        "depth 1.5\n"
        "# HELP ttft_s time to first token\n"
        "# TYPE ttft_s histogram\n"
        'ttft_s_bucket{le="0.5"} 1\n'
        'ttft_s_bucket{le="2"} 1\n'
        'ttft_s_bucket{le="+Inf"} 2\n'
        "ttft_s_sum 7.1\n"
        "ttft_s_count 2\n"
    )


def test_snapshot_schema_validates_and_pulls_live():
    reg = MetricsRegistry()
    source = {"shed": 0}
    reg.bind_counters("eng", lambda: dict(source))
    reg.histogram("lat", buckets=(1.0,)).observe(0.2)
    source["shed"] = 5  # bound counters re-pull at export time
    snap = reg.snapshot()
    assert validate_metrics_snapshot(snap) == []
    assert snap["counters"]["eng_shed"] == 5.0
    assert validate_metrics_snapshot({"schema": 2}) != []
    bad = reg.snapshot()
    bad["histograms"]["lat"]["count"] = 99
    assert any("count != sum" in p for p in validate_metrics_snapshot(bad))


# ---------------------------------------------------------------------------
# Frozen-schema consistency: registries over engines / router / trainer
# ---------------------------------------------------------------------------


def _counter_names(reg, prefix):
    return [n for n, _, _ in reg._bound_samples() if n.startswith(prefix)]


def test_scheduler_registry_every_frozen_key_exactly_once():
    """serving_registry over the paged scheduler path: every
    lifecycle.COUNTER_KEYS key appears exactly once, valued verbatim from
    counters_snapshot() — and a second bind of the same schema raises."""
    eng = FakeEngine()
    sched = _sched(eng, max_waiting=2)
    for r in [FakeReq(uid) for uid in range(4)]:
        sched.submit(r)
    drive(sched, eng)

    class _Surface:  # scheduler + the gauges serving_registry expects
        counters_snapshot = sched.counters_snapshot
        metrics = sched.metrics

        @staticmethod
        def queue_depth():
            return len(sched.waiting)

        @staticmethod
        def degrade_level():
            return 0

    reg = serving_registry(_Surface)
    names = _counter_names(reg, "serve_")
    assert sorted(names) == sorted(f"serve_{k}" for k in COUNTER_KEYS)
    assert len(names) == len(set(names)), "a frozen key bound twice"
    snap = reg.snapshot()
    counters = sched.counters_snapshot()
    for k in COUNTER_KEYS:
        assert snap["counters"][f"serve_{k}"] == float(counters[k])
    assert snap["counters"]["serve_shed"] == 2.0
    with pytest.raises(ValueError, match="already registered"):
        reg.bind_counters("serve", sched.counters_snapshot)


def test_router_registry_every_frozen_key_exactly_once():
    router, _ = _mk_router(n=2)
    for p in PROMPTS[:4]:
        router.add_request(p, max_new_tokens=3)
    _drive(router)
    reg = router_registry(router)
    rnames = _counter_names(reg, "router_")
    cnames = _counter_names(reg, "cluster_")
    assert sorted(rnames) == sorted(f"router_{k}" for k in ROUTER_COUNTER_KEYS)
    assert sorted(cnames) == sorted(f"cluster_{k}" for k in COUNTER_KEYS)
    snap = reg.snapshot()
    assert validate_metrics_snapshot(snap) == []
    for k, v in router.counters_snapshot().items():
        assert snap["counters"][f"router_{k}"] == float(v)
    for k, v in router.cluster_counters().items():
        assert snap["counters"][f"cluster_{k}"] == float(v)
    assert snap["counters"]["router_routed"] == 4.0
    # completed requests landed in the TTFT histogram
    assert snap["histograms"]["cluster_ttft_s"]["count"] == 4


class SnapFakeTrainer(FakeTrainer):
    """FakeTrainer + the counters_snapshot surface the real Trainer has
    (the supervisor provides its own when it wraps one)."""

    def counters_snapshot(self):
        from repro.train.elastic import counters_view

        return counters_view(self.counters)


def test_train_registry_every_frozen_key_exactly_once():
    ft = SnapFakeTrainer()
    ft.counters["nan_skips"] = 2
    for _ in range(3):
        ft.step_once()
    ft.history[-1]["sec"] = 0.1
    reg = train_registry(ft)
    names = _counter_names(reg, "train_")
    assert sorted(names) == sorted(f"train_{k}" for k in TRAIN_COUNTER_KEYS)
    snap = reg.snapshot()
    assert validate_metrics_snapshot(snap) == []
    for k, v in ft.counters_snapshot().items():
        assert snap["counters"][f"train_{k}"] == float(v)
    assert snap["gauges"]["train_step"] == 3
    assert snap["histograms"]["train_step_time_s"]["count"] == 1


def test_train_registry_over_supervisor_merges_counters():
    from repro.train.supervisor import TrainSupervisor

    ft = FakeTrainer()
    sup = TrainSupervisor(ft, num_workers=2)
    sup.run(4)
    reg = train_registry(sup)
    snap = reg.snapshot()
    for k, v in sup.counters_snapshot().items():
        assert snap["counters"][f"train_{k}"] == float(v)
    assert snap["gauges"]["train_step"] == 4  # gauge reads the inner trainer


# ---------------------------------------------------------------------------
# Acceptance: trace ↔ metrics bit-consistency through the serve stack
# ---------------------------------------------------------------------------


def _request_ends(rec, name="request"):
    """Terminal async end-events from a recorder, keyed by uid, after a
    JSON round-trip (what a trace consumer actually reads)."""
    doc = json.loads(json.dumps(rec.to_chrome()))
    return {
        e["args"]["uid"]: e["args"]
        for e in doc["traceEvents"]
        if e["ph"] == "e" and e["name"] == name
    }


def test_scheduler_trace_reconstructs_metrics_rows_bit_exact():
    """A chaos-style run with tracing on: every request's lifecycle span
    closes with args equal to its metrics() row — terminal status and
    per-phase timing reconstruct from the trace alone, bit-consistently
    (both built by the same _metric_row builder)."""
    clock = TickClock()
    eng = FakeEngine()
    rec = TraceRecorder(clock=clock)
    with use_recorder(rec):
        sched = _sched(eng, max_waiting=3, clock=clock)
    reqs = [FakeReq(uid, deadline_e2e=100) for uid in range(5)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng, clock=clock)
    rows = {m["uid"]: m for m in sched.metrics()}
    ends = _request_ends(rec)
    assert set(ends) == set(rows) == set(range(5))
    for uid, row in rows.items():
        assert ends[uid] == json.loads(json.dumps(row)), (
            f"trace end-args diverge from metrics() for uid {uid}"
        )
        assert set(row) == set(METRIC_KEYS)
    # the shed requests (bounded queue of 3) are terminal in the trace too
    shed = [u for u, r in rows.items() if r["status"] == lifecycle.REJECTED]
    assert len(shed) == 2
    # every request span opened exactly once and closed exactly once
    doc = rec.to_chrome()
    begins = [e for e in doc["traceEvents"]
              if e["ph"] == "b" and e["name"] == "request"]
    assert len(begins) == 5
    assert validate_chrome_trace(doc) == []


def test_cluster_trace_reconstructs_metrics_rows_bit_exact():
    """Same acceptance at the cluster tier: crequest end-events equal the
    router's metrics() rows (which add rid/redeliveries) bit-exactly."""
    clock = TickClock()
    rec = TraceRecorder(clock=clock)
    clients = [FakeReplicaClient() for _ in range(2)]
    from repro.serve.cluster import ClusterRouter

    router = ClusterRouter(clients, clock=clock, trace=rec)
    for p in PROMPTS[:5]:
        router.add_request(p, max_new_tokens=3)
    _drive(router, clock=clock)
    rows = {m["uid"]: m for m in router.metrics()}
    ends = _request_ends(rec, name="crequest")
    assert set(ends) == set(rows)
    for uid, row in rows.items():
        assert ends[uid] == json.loads(json.dumps(row))
        assert {"rid", "redeliveries"} <= set(row)
    assert validate_chrome_trace(rec.to_chrome()) == []


def test_trainer_step_spans_on_fake_trainer_clock():
    """Trainer-side spans: supervisor remesh instants ride the recorder
    the supervisor was constructed with."""
    from repro.faults import FaultInjector, FaultSpec
    from repro.train.supervisor import TrainSupervisor

    rec = TraceRecorder(clock=StepClock())
    inj = FaultInjector([FaultSpec("worker_loss", uid=1, after=3, times=-1)])
    sup = TrainSupervisor(FakeTrainer(), num_workers=3, max_missed=2,
                          faults=inj, trace=rec)
    sup.run(8)
    names = [e["name"] for e in rec.events]
    assert "worker_loss" in names and "remesh" in names
    assert validate_chrome_trace(rec.to_chrome()) == []


# ---------------------------------------------------------------------------
# obs.utilization: measured-vs-roofline columns
# ---------------------------------------------------------------------------


def test_roofline_lower_bound_is_max_of_compute_and_memory():
    # compute-bound: flops term dominates
    assert roofline_lower_bound_s(1e12, 1.0, peak_flops=1e12, hbm_bw=1e12) \
        == pytest.approx(1.0)
    # memory-bound: bytes term dominates
    assert roofline_lower_bound_s(1.0, 1e12, peak_flops=1e12, hbm_bw=1e12) \
        == pytest.approx(1.0)


def test_achieved_fraction_bounds_and_validation():
    lb = roofline_lower_bound_s(2e12, 1.0, peak_flops=1e12, hbm_bw=1e12)
    assert achieved_fraction(lb, 2e12, 1.0, peak_flops=1e12, hbm_bw=1e12) \
        == pytest.approx(1.0)  # measured == bound → util 1.0
    assert achieved_fraction(2 * lb, 2e12, 1.0, peak_flops=1e12,
                             hbm_bw=1e12) == pytest.approx(0.5)
    with pytest.raises(ValueError):
        achieved_fraction(0.0, 1.0, 1.0)


def test_utilization_columns_from_cost_model():
    from repro.roofline.analysis import decode_attention_cost

    cost = decode_attention_cost(4, 8, 2, 64, 512, 64, block_k=64)
    cols = utilization_columns(cost, 1000.0)  # 1ms measured
    assert set(cols) == {"roofline_flops", "roofline_hbm_bytes",
                        "roofline_lower_bound_us", "roofline_util"}
    assert 0.0 < cols["roofline_util"] <= 1.0
    assert cols["roofline_lower_bound_us"] < 1000.0


# ---------------------------------------------------------------------------
# Regress gate: tolerance bands + per-backend keying (benchmarks/regress.py)
# ---------------------------------------------------------------------------


def _regress():
    import sys

    root = os.path.join(os.path.dirname(__file__), "..")
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import regress

    return regress


def test_regress_ceiling_band_and_backend_keying():
    regress = _regress()
    records = [
        {"live_length": 64, "roofline_util": 0.5, "backend": "cpu"},
        {"live_length": 64, "roofline_util": 2.0, "backend": "tpu"},
    ]
    band = regress.Bound(path="B.json", kind=None, metric="roofline_util",
                         floor=1e-9, ceiling=1.0,
                         match=(("live_length", 64),), backend="cpu")
    assert regress.check_bound(records, band) == []
    # the impossible tpu row is invisible to the cpu-keyed bound...
    tpu = regress.Bound(path="B.json", kind=None, metric="roofline_util",
                        floor=1e-9, ceiling=1.0,
                        match=(("live_length", 64),), backend="tpu")
    (msg,) = regress.check_bound(records, tpu)
    assert "> ceiling 1.000" in msg
    # ...and a selector with no matching backend reports it
    gpu = regress.Bound(path="B.json", kind=None, metric="roofline_util",
                        floor=0.0, backend="gpu")
    (msg,) = regress.check_bound(records, gpu)
    assert "no kind=None record" in msg and "'backend': 'gpu'" in msg


def test_regress_kind_none_matches_unkinded_rows():
    regress = _regress()
    records = [{"devices": 8, "hops": 36, "backend": "cpu"},
               {"kind": "summary", "ratio": 2.0, "backend": "cpu"}]
    b = regress.Bound(path="B.json", kind=None, metric="hops",
                      floor=8.0, ceiling=36.0, match=(("devices", 8),),
                      backend="cpu")
    assert regress.check_bound(records, b) == []
    # schema-stamp style bound: kind=None + empty match covers every row
    stamp = regress.Bound(path="B.json", kind=None, metric="hops", floor=1.0)
    (msg,) = regress.check_bound(records, stamp)
    assert "lacks" in msg  # the summary row has no hops field


def test_regress_committed_bounds_include_utilization_band():
    """At least one committed bound is a per-backend utilization band on
    BENCH_decode.json (floor > 0, ceiling ≤ 1) — the acceptance that the
    regress gate now bounds measured-vs-roofline achieved fraction."""
    regress = _regress()
    util = [b for b in regress.BOUNDS
            if b.metric == "roofline_util" and b.path == "BENCH_decode.json"]
    assert util, "no utilization bound committed"
    for b in util:
        assert b.floor > 0 and b.ceiling is not None and b.ceiling <= 1.0
        assert b.backend == "cpu"
    files = {b.path for b in regress.BOUNDS}
    assert files == {
        "BENCH_attention_bwd.json", "BENCH_autotune.json",
        "BENCH_cluster.json", "BENCH_decode.json", "BENCH_mesh.json",
        "BENCH_ring.json", "BENCH_serving.json", "BENCH_train_chaos.json",
    }, "regress gate must cover every committed BENCH family"
