"""Paged KV subsystem: block-table kernel parity, pool/scheduler
invariants, paged-vs-contiguous engine-path parity (ISSUE 5 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import grouping
from repro.core.api import attend_decode
from repro.models import lm
from repro.serve import paged
from repro.serve.engine import PagedServeEngine
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.serve_step import make_decode_step, make_paged_step, make_prefill


def _random_pool_case(key, b, hkv, d, bs, mb, dtype=jnp.float32):
    """Pools + a shuffled (non-contiguous) block table per request."""
    ks = jax.random.split(key, 3)
    p = 1 + b * mb  # + reserved garbage block 0
    k_pool = jax.random.normal(ks[0], (p, hkv, bs, d), jnp.float32).astype(dtype)
    v_pool = jax.random.normal(ks[1], (p, hkv, bs, d), jnp.float32).astype(dtype)
    ids = np.arange(1, p, dtype=np.int32)
    np.random.RandomState(0).shuffle(ids)
    bt = jnp.asarray(ids.reshape(b, mb))
    return k_pool, v_pool, bt, ks[2]


def _gather(pool, bt):
    g = jnp.take(pool, bt, axis=0)  # (B, mb, Hkv, bs, d)
    b, mb, hkv, bs, d = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, mb * bs, d)


# ---------------------------------------------------------------------------
# Kernel-level parity (ops.paged_decode_attention vs gathered oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("q_per_kv", [1, 4])
def test_paged_kernel_matches_gathered_oracle(dtype, q_per_kv):
    """Ragged lengths (incl. block-boundary crossings and single-token) over
    shuffled physical blocks equal the contiguous decode oracle."""
    from repro.kernels import ops, ref

    b, hkv, d, bs, mb = 4, 2, 32, 8, 4
    k_pool, v_pool, bt, kq = _random_pool_case(
        jax.random.PRNGKey(0), b, hkv, d, bs, mb, dtype
    )
    q = jax.random.normal(kq, (b, hkv * q_per_kv, 1, d), jnp.float32).astype(dtype)
    # exact block multiple, mid-block, crossing, and single-token lengths
    lengths = jnp.asarray([16, 13, 25, 1], jnp.int32)
    out = ops.paged_decode_attention(
        q, k_pool, v_pool, block_tables=bt, lengths=lengths
    )
    want = ref.decode_attention_ref(
        q.astype(jnp.float32),
        _gather(k_pool, bt).astype(jnp.float32),
        _gather(v_pool, bt).astype(jnp.float32),
        lengths,
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want), rtol=tol, atol=tol
    )


def test_paged_kernel_banded_window():
    """q_len > 1 (chunked prefill): row i sees positions
    < length − (q_len − 1 − i), matching the contiguous kernel's band."""
    from repro.kernels import ops

    b, hkv, d, bs, mb, ql = 2, 2, 32, 8, 4, 4
    k_pool, v_pool, bt, kq = _random_pool_case(
        jax.random.PRNGKey(1), b, hkv, d, bs, mb
    )
    q = jax.random.normal(kq, (b, 4, ql, d), jnp.float32)
    lengths = jnp.asarray([17, 9], jnp.int32)
    out = ops.paged_decode_attention(
        q, k_pool, v_pool, block_tables=bt, lengths=lengths
    )
    from repro.core.flash_reference import reference_attention

    k_c, v_c = _gather(k_pool, bt), _gather(v_pool, bt)
    for bi in range(b):
        for i in range(ql):
            mask = (
                jnp.arange(mb * bs)[None, :]
                < int(lengths[bi]) - (ql - 1 - i)
            )
            want = reference_attention(
                q[bi : bi + 1, :, i : i + 1], k_c[bi : bi + 1],
                v_c[bi : bi + 1], kv_mask=mask,
            )
            np.testing.assert_allclose(
                np.asarray(out[bi : bi + 1, :, i : i + 1]), np.asarray(want),
                rtol=2e-5, atol=2e-5,
            )


def test_paged_kernel_window_overhanging_capacity():
    """Regression: a padded chunk window whose lengths = pos + w overhangs
    the table capacity must NOT shift live rows' causal bands (a wholesale
    capacity clamp used to drop their most recent context — including
    their own token)."""
    from repro.kernels import ops
    from repro.core.flash_reference import reference_attention

    b, hkv, d, bs, mb, ql = 1, 2, 32, 8, 2, 4  # capacity 16
    k_pool, v_pool, bt, kq = _random_pool_case(
        jax.random.PRNGKey(5), b, hkv, d, bs, mb
    )
    q = jax.random.normal(kq, (b, 4, ql, d), jnp.float32)
    pos, live = 13, 2  # live rows at positions 13, 14; rows 2-3 padded
    lengths = jnp.asarray([pos + ql], jnp.int32)  # 17 > capacity
    out = ops.paged_decode_attention(
        q, k_pool, v_pool, block_tables=bt, lengths=lengths
    )
    k_c, v_c = _gather(k_pool, bt), _gather(v_pool, bt)
    for t in range(live):
        mask = jnp.arange(mb * bs)[None, :] < (pos + t + 1)  # own band
        want = reference_attention(
            q[:, :, t : t + 1], k_c, v_c, kv_mask=mask
        )
        np.testing.assert_allclose(
            np.asarray(out[:, :, t : t + 1]), np.asarray(want),
            rtol=2e-5, atol=2e-5,
        )


def test_paged_kernel_fused_variant():
    """Fused-K̂ pool (d/G* score width) through the block table equals the
    reference dispatch on the gathered fused cache."""
    b, hkv, q_per_kv, d, g, bs, mb = 2, 2, 2, 32, 2, 8, 3
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    p = 1 + b * mb
    k_pool = jax.random.normal(ks[0], (p, hkv, bs, d), jnp.float32)
    v_pool = jax.random.normal(ks[1], (p, hkv, bs, d), jnp.float32)
    perm = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[2], h), d)
        for h in range(hkv)
    ]).astype(jnp.int32)
    kf_pool = grouping.fuse_columns(k_pool, perm[None], g)
    ids = np.arange(1, p, dtype=np.int32)
    np.random.RandomState(1).shuffle(ids)
    bt = jnp.asarray(ids.reshape(b, mb))
    q = jax.random.normal(ks[3], (b, hkv * q_per_kv, 1, d), jnp.float32)
    lengths = jnp.asarray([11, 24], jnp.int32)
    scale = 1.0 / (d**0.5)

    from repro.core.api import AttentionConfig

    out = attend_decode(
        q, None, v_pool, AttentionConfig(impl="pallas_flash"),
        lengths=lengths, k_fused=kf_pool, perm=perm, group_size=g,
        scale=scale, block_tables=bt,
    )
    want = attend_decode(
        q, None, v_pool, AttentionConfig(impl="reference"),
        lengths=lengths, k_fused=kf_pool, perm=perm, group_size=g,
        scale=scale, block_tables=bt,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# Block pool + cache invariants
# ---------------------------------------------------------------------------


def test_block_pool_invariants():
    pool = paged.BlockPool(5, 8)  # 4 allocatable (block 0 reserved)
    assert pool.num_free == 4
    got = pool.alloc(4)
    assert 0 not in got and len(set(got)) == 4
    with pytest.raises(paged.PoolExhausted):
        pool.alloc(1)
    pool.free(got[0])
    assert pool.num_free == 1
    with pytest.raises(ValueError):
        pool.free(got[0])  # double free
    # refcounting: a shared block survives its first free
    pool.incref(got[1])
    pool.free(got[1])
    assert pool.refcount(got[1]) == 1 and pool.num_free == 1
    pool.free(got[1])
    assert pool.num_free == 2
    # the garbage block is never handed out and never freed
    pool.free(0)
    assert pool.refcount(0) == 1


def test_shared_prefix_blocks_are_reused_and_refcounted():
    cfg = get_config("minicpm-2b", reduced=True)
    cache = paged.PagedKVCache(cfg, 8, 8, dtype=jnp.float32)
    cache.allocate_to(0, 20)  # 3 blocks
    covered = cache.share_prefix(0, 1, 20)
    assert covered == 16  # whole blocks only (2×8), partial third not shared
    assert cache.tables[1] == cache.tables[0][:2]
    free_before = cache.pool.num_free
    cache.free(0)  # shared blocks stay alive through uid 1
    assert cache.pool.num_free == free_before + 1  # only the partial block
    cache.free(1)
    assert cache.pool.num_free == cache.pool.num_blocks - 1


def test_evict_restore_roundtrip_preserves_kv():
    cfg = get_config("minicpm-2b", reduced=True)
    cache = paged.PagedKVCache(cfg, 8, 8, dtype=jnp.float32)
    cache.allocate_to(7, 20)
    table = list(cache.tables[7])
    marker = jnp.arange(
        np.prod(cache.pools["k"].shape), dtype=jnp.float32
    ).reshape(cache.pools["k"].shape)
    cache.pools["k"] = marker
    want = np.asarray(jnp.take(marker, jnp.asarray(table), axis=1))
    cache.evict_to_host(7, 20)
    assert 7 not in cache.tables
    assert cache.pool.num_free == cache.pool.num_blocks - 1
    cache.restore(7)
    got = np.asarray(
        jnp.take(cache.pools["k"], jnp.asarray(cache.tables[7]), axis=1)
    )
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Scheduler invariants (fake engine: policy only, no model)
# ---------------------------------------------------------------------------


class _FakeReq:
    def __init__(self, uid, n_prompt, max_new):
        self.uid = uid
        self.prompt = list(range(1, n_prompt + 1))
        self.max_new_tokens = max_new
        self.eos_id = None
        self.generated = []
        self.done = False


class _FakeEngine:
    """Implements the scheduler's primitive surface over a bare BlockPool —
    exercises admission/preemption/restore policy without touching a
    model (no jit, milliseconds per test)."""

    def __init__(self, num_blocks, block_size, max_batch, capacity_tokens):
        self.pool = paged.BlockPool(num_blocks, block_size)
        self.bs = block_size
        self.max_batch = max_batch
        self.capacity_tokens = capacity_tokens
        self.ids: dict[int, list[int]] = {}  # uid → held block ids
        self.evicted_uids: set[int] = set()
        self.scheduler = None
        self.first_token_order: list[int] = []

    def free_lane(self):
        return next(
            l for l in range(self.max_batch)
            if l not in self.scheduler.running
        )

    def alloc(self, entry, n_tokens):
        need = -(-n_tokens // self.bs) - len(self.ids.get(entry.uid, []))
        if need <= 0:
            return True
        try:
            got = self.pool.alloc(need)
        except paged.PoolExhausted:
            return False
        self.ids.setdefault(entry.uid, []).extend(got)
        return True

    def can_admit(self, entry):
        need = -(-min(len(entry.req.prompt) + 1, self.capacity_tokens)
                 // self.bs)
        return self.pool.num_free >= need

    def holds_blocks(self, entry):
        return bool(self.ids.get(entry.uid))

    def evict(self, entry):
        for b in self.ids.pop(entry.uid):
            self.pool.free(b)
        self.evicted_uids.add(entry.uid)

    def restore(self, entry):
        blocks = -(-max(entry.length, 1) // self.bs)
        try:
            self.ids[entry.uid] = self.pool.alloc(blocks)
        except paged.PoolExhausted:
            return False
        return True

    def release(self, entry):
        for b in self.ids.pop(entry.uid, []):
            self.pool.free(b)

    def sample_one(self, logits):
        self.first_token_order.append(int(logits))
        return 1

    def prefill_chunk_run(self, entry, chunk):
        return entry.uid  # "logits" = uid, recorded at first-token sampling

    def decode_tick(self, running):
        return np.full((self.max_batch,), 1, np.int64)


def _fake_engine(num_blocks, block_size, max_batch, capacity):
    return _FakeEngine(num_blocks, block_size, max_batch, capacity)


def test_scheduler_no_starvation_and_fcfs_first_tokens():
    """Many requests through a tight pool: everyone finishes, first tokens
    are produced in arrival order (FCFS), and no block is leaked."""
    eng = _fake_engine(num_blocks=7, block_size=8, max_batch=3, capacity=32)
    sched = Scheduler(
        SchedulerConfig(max_batch=3, prefill_chunk=8), clock=lambda: 0.0
    )
    eng.scheduler = sched
    for uid in range(8):
        sched.submit(_FakeReq(uid, n_prompt=10, max_new=5))
    for _ in range(400):
        sched.tick(eng)
        if not sched.has_work():
            break
    assert not sched.has_work(), "a request starved"
    assert len(sched.done) == 8
    assert all(len(e.req.generated) == 5 for e in sched.done)
    assert eng.first_token_order == sorted(eng.first_token_order)
    assert eng.pool.num_free == eng.pool.num_blocks - 1  # nothing leaked


def test_scheduler_lifo_self_preempts_newest_grower():
    """When the GROWING request is itself the newest block holder, LIFO
    preemption must evict it — never an older request's memory (the
    documented head-of-line guarantee)."""
    eng = _fake_engine(num_blocks=6, block_size=8, max_batch=2, capacity=40)
    sched = Scheduler(
        SchedulerConfig(max_batch=2, prefill_chunk=32), clock=lambda: 0.0
    )
    eng.scheduler = sched
    # old: 3 blocks, first growth (→ 4 blocks) only at its 8th decode tick
    sched.submit(_FakeReq(0, n_prompt=17, max_new=12))
    # new: 2 blocks, grows past 16 at its 7th tick — one tick EARLIER, with
    # zero free blocks and itself the newest holder
    sched.submit(_FakeReq(1, n_prompt=10, max_new=10))
    for _ in range(100):
        sched.tick(eng)
        if not sched.has_work():
            break
    assert len(sched.done) == 2
    assert all(len(e.req.generated) == e.req.max_new_tokens
               for e in sched.done)
    assert 0 not in eng.evicted_uids, "LIFO evicted the FCFS-oldest request"
    assert 1 in eng.evicted_uids, "the newest grower should self-preempt"
    assert eng.pool.num_free == eng.pool.num_blocks - 1


def test_scheduler_requeue_preserves_arrival_order():
    """A just-preempted runner must re-enter the queue at its uid (arrival)
    position — behind an older evicted request already waiting — so
    restores happen FCFS."""
    from repro.serve.scheduler import Entry

    sched = Scheduler(SchedulerConfig(), clock=lambda: 0.0)
    e0 = Entry(req=_FakeReq(0, 4, 4), evicted=True)
    e5 = Entry(req=_FakeReq(5, 4, 4))
    sched.waiting.extend([e0, e5])
    e2 = Entry(req=_FakeReq(2, 4, 4))
    sched._requeue(e2)
    assert [e.uid for e in sched.waiting] == [0, 2, 5]


def test_scheduler_preempts_and_resumes_under_pressure():
    """Pool holds ~2 live requests; 4 submitted: preemption must trigger,
    and preempted requests must finish with their full token count."""
    eng = _fake_engine(num_blocks=9, block_size=8, max_batch=4, capacity=32)
    sched = Scheduler(
        SchedulerConfig(max_batch=4, prefill_chunk=8), clock=lambda: 0.0
    )
    eng.scheduler = sched
    for uid in range(4):
        sched.submit(_FakeReq(uid, n_prompt=10, max_new=16))
    for _ in range(400):
        sched.tick(eng)
        if not sched.has_work():
            break
    assert len(sched.done) == 4
    assert all(len(e.req.generated) == 16 for e in sched.done)
    assert eng.evicted_uids, "pressure run never preempted"
    assert eng.pool.num_free == eng.pool.num_blocks - 1


# ---------------------------------------------------------------------------
# Engine-path parity + end-to-end (ISSUE 5 acceptance)
# ---------------------------------------------------------------------------


def test_paged_decode_matches_contiguous_ring_path():
    """Acceptance: f32 logits allclose across ≥ 8 generated tokens vs the
    contiguous ring-cache decode, on a GQA config, with the request's KV
    spanning ≥ 3 pool blocks; plus a second, shorter (ragged) lane decoded
    in the same paged batch."""
    cfg = get_config("qwen2.5-32b", reduced=True)  # GQA: Hq > Hkv
    cfg = cfg.replace(attention=cfg.attention.with_impl("pallas_flash"))
    assert cfg.n_heads > cfg.n_kv_heads
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab)
    toks_b = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0, cfg.vocab)
    n_a, n_b = 12, 5  # ragged pair
    bs, mb = 8, 4  # request A spans 3 blocks by the end

    # contiguous ring path, one request at a time
    def contiguous_logits(tok_stream, n):
        _, cache = make_prefill(cfg, mb * bs)(params, tok_stream[:, :n])
        cache["length"] = jnp.asarray([n], jnp.int32)
        dec = make_decode_step(cfg)
        outs = []
        for i in range(n, n + 8):
            lg, cache = dec(params, tok_stream[:, i : i + 1], cache,
                            jnp.asarray([i], jnp.int32))
            outs.append(np.asarray(lg[:, 0], np.float32))
        return outs

    want_a = contiguous_logits(toks, n_a)
    want_b = contiguous_logits(toks_b, n_b)

    # paged path: chunked prefill then a 2-lane batched decode
    cache = paged.PagedKVCache(cfg, 1 + 2 * mb, bs, dtype=jnp.float32)
    chunk = make_paged_step(cfg, 8)
    dec = make_paged_step(cfg, 1)
    for uid, (stream, n) in enumerate(((toks, n_a), (toks_b, n_b))):
        done = 0
        while done < n:
            c = min(8, n - done)
            cache.allocate_to(uid, done + c)
            bt = cache.table_array([uid], mb)
            tk = np.zeros((1, 8), np.int32)
            tk[0, :c] = np.asarray(stream[0, done : done + c])
            _, cache.pools = chunk(
                params, jnp.asarray(tk), cache.pools, bt,
                jnp.asarray([done], jnp.int32), jnp.asarray([c], jnp.int32),
            )
            done += c
    lengths = [n_a, n_b]
    streams = [toks, toks_b]
    for step in range(8):
        pos = jnp.asarray([lengths[0] + step, lengths[1] + step], jnp.int32)
        cache.allocate_to(0, int(pos[0]) + 1)
        cache.allocate_to(1, int(pos[1]) + 1)
        bt = cache.table_array([0, 1], mb)
        tk = jnp.stack([
            streams[0][0, int(pos[0])], streams[1][0, int(pos[1])]
        ])[:, None]
        lg, cache.pools = dec(
            params, tk, cache.pools, bt, pos, jnp.asarray([1, 1], jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(lg[0:1, 0], np.float32), want_a[step],
            rtol=1e-4, atol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(lg[1:2, 0], np.float32), want_b[step],
            rtol=1e-4, atol=1e-4,
        )
    assert len(cache.tables[0]) >= 3  # spanned ≥ 3 pool blocks


def test_paged_engine_continuous_batching_end_to_end():
    """More requests than lanes; mixed lengths; every request completes
    with full token counts and TTFT metrics recorded."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServeEngine(cfg, params, max_batch=3, max_len=64,
                           block_size=8, prefill_chunk=8)
    for i in range(5):
        eng.add_request(list(range(1 + i, 4 + 2 * i)), max_new_tokens=4)
    # max_new_tokens=1 finishes on the prefill-sampled token — exactly one
    # generated token, no decode tick (slot-engine contract).
    eng.add_request([9, 9, 9], max_new_tokens=1)
    done = eng.run_to_completion(max_steps=200)
    assert len(done) == 6
    by_new = sorted(len(r.generated) for r in done)
    assert by_new == [1, 4, 4, 4, 4, 4]
    m = eng.metrics()
    assert len(m) == 6 and all(x["ttft_s"] is not None for x in m)
    assert eng.cache.pool.num_free == eng.cache.pool.num_blocks - 1


@pytest.mark.slow
def test_paged_engine_preemption_identical_continuations():
    """A pool sized for ~2 live requests forces preemption; generations
    must equal the unpressured run token-for-token (whole-request host
    eviction + restore) and the pool must be fully reclaimed."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    def run(num_blocks):
        eng = PagedServeEngine(
            cfg, params, max_batch=4, max_len=32, block_size=8,
            num_blocks=num_blocks, prefill_chunk=8,
        )
        for i in range(4):
            eng.add_request([2 + i] * 10, max_new_tokens=12)
        done = eng.run_to_completion(max_steps=300)
        return eng, {r.uid: r.generated for r in done}

    eng_tight, gen_tight = run(num_blocks=1 + 8)
    eng_roomy, gen_roomy = run(num_blocks=1 + 4 * 4)
    assert len(gen_tight) == 4
    assert gen_tight == gen_roomy
    assert sum(x["n_preemptions"] for x in eng_tight.metrics()) > 0
    assert eng_tight.cache.pool.num_free == eng_tight.cache.pool.num_blocks - 1


def test_paged_engine_rejects_overlong_prompt_and_bad_pool():
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServeEngine(cfg, params, max_batch=2, max_len=32,
                           block_size=8, prefill_chunk=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(40)))
    with pytest.raises(ValueError, match="full request"):
        PagedServeEngine(cfg, params, max_batch=2, max_len=32, block_size=8,
                         num_blocks=3, prefill_chunk=8)


# ---------------------------------------------------------------------------
# Windowed decode past capacity (ISSUE 9 satellite)
# ---------------------------------------------------------------------------


def test_paged_windowed_decode_matches_slot_sliding_window():
    """Decode past the table capacity recycles the request's HEAD blocks in
    place (write at ``pos mod capacity``, attend the last ``capacity``
    tokens) — logits equal the slot engine's sliding-window decode
    (make_decode_step) with ``max_len == capacity``, step for step."""
    cfg = get_config("qwen2.5-32b", reduced=True)  # exact impl for parity
    cfg = cfg.replace(attention=cfg.attention.with_impl("pallas_flash"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    bs, mb = 8, 2  # capacity 16
    n, steps = 10, 12  # decode positions 10..21 — wraps at 16
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (1, n + steps), 0, cfg.vocab
    )

    # slot path: contiguous ring cache of exactly `capacity` slots
    _, cache = make_prefill(cfg, mb * bs)(params, toks[:, :n])
    cache["length"] = jnp.asarray([n], jnp.int32)
    dec_slot = make_decode_step(cfg)
    want = []
    for i in range(n, n + steps):
        lg, cache = dec_slot(
            params, toks[:, i : i + 1], cache, jnp.asarray([i], jnp.int32)
        )
        want.append(np.asarray(lg[:, 0], np.float32))

    # paged path: same capacity through the block table, decoded past it
    pcache = paged.PagedKVCache(cfg, 1 + mb, bs, dtype=jnp.float32)
    chunk = make_paged_step(cfg, 8)
    done = 0
    while done < n:
        c = min(8, n - done)
        pcache.allocate_to(0, done + c)
        bt = pcache.table_array([0], mb)
        tk = np.zeros((1, 8), np.int32)
        tk[0, :c] = np.asarray(toks[0, done : done + c])
        _, pcache.pools = chunk(
            params, jnp.asarray(tk), pcache.pools, bt,
            jnp.asarray([done], jnp.int32), jnp.asarray([c], jnp.int32),
        )
        done += c
    pcache.allocate_to(0, mb * bs)  # full table; further growth is a no-op
    bt = pcache.table_array([0], mb)
    dec = make_paged_step(cfg, 1)
    for step in range(steps):
        lg, pcache.pools = dec(
            params, toks[:, n + step : n + step + 1], pcache.pools, bt,
            jnp.asarray([n + step], jnp.int32), jnp.asarray([1], jnp.int32),
        )
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32), want[step],
            rtol=1e-4, atol=1e-4,
        )


def test_paged_engine_decode_crosses_capacity():
    """``max_new_tokens`` may cross the table capacity: the request is
    accepted (only PROMPTS are capacity-bound) and decodes its full budget
    by recycling head blocks instead of being force-finished at the
    capacity bound."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServeEngine(cfg, params, max_batch=2, max_len=16,
                           block_size=8, prefill_chunk=8)
    assert eng.capacity_tokens == 16
    uid = eng.add_request([3, 1, 4, 1, 5, 9], max_new_tokens=20)  # 6+20 > 16
    done = eng.run_to_completion(max_steps=200)
    (req,) = done
    assert req.uid == uid
    assert len(req.generated) == 20
    assert eng.cache.pool.num_free == eng.cache.pool.num_blocks - 1
