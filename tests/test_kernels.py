"""Pallas kernel sweeps: shapes × dtypes × causal vs pure-jnp oracles
(interpret mode on CPU; the same calls run compiled on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistrConfig
from repro.kernels import ops, ref


def _qkv(seed, b, hq, hkv, n, nk, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, nk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, nk, d)).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # (b, hq, hkv, n, nk, d, dtype, causal)
    (1, 1, 1, 128, 128, 64, jnp.float32, False),
    (2, 4, 4, 128, 128, 64, jnp.float32, True),
    (2, 8, 2, 128, 128, 64, jnp.float32, True),   # GQA
    (1, 2, 2, 192, 192, 32, jnp.float32, True),   # non-multiple of block
    (1, 2, 2, 128, 256, 64, jnp.float32, False),  # rectangular
    (2, 4, 4, 128, 128, 64, jnp.bfloat16, True),  # bf16
]


@pytest.mark.parametrize("b,hq,hkv,n,nk,d,dtype,causal", FLASH_CASES)
def test_flash_kernel_vs_oracle(b, hq, hkv, n, nk, d, dtype, causal):
    q, k, v = _qkv(0, b, hq, hkv, n, nk, d, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


DISTR_CASES = [
    (1, 1, 1, 128, 64, 2, jnp.float32, False),
    (2, 4, 4, 128, 64, 2, jnp.float32, True),
    (2, 8, 2, 128, 64, 4, jnp.float32, True),    # GQA + G*=4
    (1, 2, 2, 192, 32, 2, jnp.float32, True),    # padding path
    (2, 4, 4, 128, 64, 2, jnp.bfloat16, True),
]


@pytest.mark.parametrize("b,hq,hkv,n,d,g,dtype,causal", DISTR_CASES)
def test_distr_kernel_vs_oracle(b, hq, hkv, n, d, g, dtype, causal):
    q, k, v = _qkv(1, b, hq, hkv, n, n, d, dtype)
    cfg = DistrConfig(group_size=g, block_q=64, block_k=64)
    out = ops.distr_attention(q, k, v, cfg, causal=causal)
    want = ref.distr_attention_ref(q, k, v, cfg, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_distr_kernel_estimators_and_shared_perm():
    q, k, v = _qkv(2, 2, 4, 2, 128, 128, 64, jnp.float32)
    for kw in (dict(estimator="mean"), dict(shared_kv_perm=True)):
        cfg = DistrConfig(group_size=2, block_q=64, block_k=64, **kw)
        out = ops.distr_attention(q, k, v, cfg, causal=True)
        want = ref.distr_attention_ref(q, k, v, cfg, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


SSD_CASES = [
    (1, 64, 2, 16, 1, 8, 32, jnp.float32),
    (2, 128, 4, 32, 2, 16, 32, jnp.float32),
    (2, 96, 4, 32, 2, 16, 32, jnp.float32),   # padding (96 % 32 == 0, chunk 64)
    (1, 128, 4, 32, 1, 16, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("b,n,h,p,g,s,chunk,dtype", SSD_CASES)
def test_ssd_kernel_vs_oracle(b, n, h, p, g, s, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (b, n, h, p)).astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, n, h)))
    bm = jax.random.normal(ks[2], (b, n, g, s)).astype(dtype)
    c = jax.random.normal(ks[3], (b, n, g, s)).astype(dtype)
    out = ops.ssd(x, a, bm, c, chunk=chunk)
    want = ref.ssd_ref(x, a, bm, c)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_attention_cost_model_sanity():
    c_exact = ops.attention_cost(1, 8, 4096, 4096, 128)
    c_distr = ops.attention_cost(1, 8, 4096, 4096, 128, group_size=2)
    # QK flops halve; PV unchanged; fusion adds appear.
    assert c_distr["qk_flops"] == c_exact["qk_flops"] / 2
    assert c_distr["pv_flops"] == c_exact["pv_flops"]
    assert c_distr["fusion_adds"] > 0 and c_exact["fusion_adds"] == 0
    # total MXU work strictly decreases — the paper's speedup source.
    assert c_distr["mxu_flops"] < c_exact["mxu_flops"]


def test_attention_cost_io_bytes():
    b, h, n, d = 1, 8, 4096, 128
    w = 2
    c_exact = ops.attention_cost(b, h, n, n, d)
    # Exact: Q + K + V reads + O write, nothing zeroed out.
    assert c_exact["hbm_bytes"] == w * (4 * b * h * n * d)
    # Distr adds only the sampled Q̂ stream (d/G* extra columns); K̂ stays
    # in VMEM and must not contribute.
    c_distr = ops.attention_cost(b, h, n, n, d, group_size=2)
    assert c_distr["hbm_bytes"] == c_exact["hbm_bytes"] + w * b * h * n * (d // 2)


def test_attention_cost_backward_terms():
    c_exact = ops.attention_cost(1, 8, 4096, 4096, 128, causal=True)
    c_distr = ops.attention_cost(1, 8, 4096, 4096, 128, causal=True, group_size=2)
    # Backward does strictly more MXU work than forward (5 matmul family vs
    # 2, with S recomputed in both backward kernels).
    for c in (c_exact, c_distr):
        assert c["bwd_mxu_flops"] > c["mxu_flops"]
        assert c["fwd_bwd_mxu_flops"] == c["mxu_flops"] + c["bwd_mxu_flops"]
        assert c["bwd_hbm_bytes"] > 0
    # The paper's reduction survives the backward: score-space matmuls
    # (4 of 7) contract over d/G*.
    assert c_distr["bwd_mxu_flops"] < c_exact["bwd_mxu_flops"]
    assert c_distr["fwd_bwd_mxu_flops"] < c_exact["fwd_bwd_mxu_flops"]
