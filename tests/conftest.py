"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces 512 host devices (and only in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # the container has no hypothesis and pip installs are off-limits
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test wall-clock limit"
    )


# -- per-test timeout ------------------------------------------------------
# The chaos suite (tests/test_chaos.py) must fail loudly, not hang CI, when
# a fault wedges the scheduler.  pytest-timeout is used when installed; the
# container image ships without it, so fall back to SIGALRM (main thread,
# POSIX) with the same opt-out env knob.

_HAVE_PYTEST_TIMEOUT = False
try:  # pragma: no cover - depends on the environment
    import pytest_timeout  # noqa: F401

    _HAVE_PYTEST_TIMEOUT = True
except ModuleNotFoundError:
    pass

_DEFAULT_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "900"))


def _timeout_for(item) -> float:
    m = item.get_closest_marker("timeout")
    if m is not None and m.args:
        return float(m.args[0])
    return _DEFAULT_TIMEOUT


if not _HAVE_PYTEST_TIMEOUT and hasattr(__import__("signal"), "SIGALRM"):
    import signal

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        seconds = _timeout_for(item)
        if seconds <= 0:
            yield
            return

        def _alarm(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded {seconds:.0f}s "
                "(REPRO_TEST_TIMEOUT / @pytest.mark.timeout)"
            )

        prev = signal.signal(signal.SIGALRM, _alarm)
        signal.setitimer(signal.ITIMER_REAL, seconds)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, prev)


def pytest_collection_modifyitems(config, items):
    """Tier-1 (`pytest -x -q`) skips slow tests (multi-step engine decodes);
    an explicit marker expression (`pytest -m slow`) still runs them."""
    if config.getoption("-m"):
        return
    skip_slow = pytest.mark.skip(reason="slow: opt in with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
