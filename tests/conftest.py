"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces 512 host devices (and only in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
