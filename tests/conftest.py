"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces 512 host devices (and only in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # the container has no hypothesis and pip installs are off-limits
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


def pytest_collection_modifyitems(config, items):
    """Tier-1 (`pytest -x -q`) skips slow tests (multi-step engine decodes);
    an explicit marker expression (`pytest -m slow`) still runs them."""
    if config.getoption("-m"):
        return
    skip_slow = pytest.mark.skip(reason="slow: opt in with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
