"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces 512 host devices (and only in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # the container has no hypothesis and pip installs are off-limits
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
