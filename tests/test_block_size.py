"""Block-size selection model (paper §3.3.1, TPU re-derivation)."""
from repro.core.block_size import (
    LANE,
    TpuSpec,
    enumerate_block_sizes,
    io_count,
    select_block_sizes,
    working_set_bytes,
)


def test_io_count_prefers_large_l():
    """The paper's I(l, m): independent of m, monotonically better in l."""
    n, d = 4096, 128
    ios = [io_count(l, n, d) for l in (128, 256, 512)]
    assert ios[0] > ios[1] > ios[2]


def test_selection_is_aligned_and_fits():
    for d in (32, 64, 128, 256):
        for g in (1, 2):
            l, m = select_block_sizes(d, group_size=g)
            assert l % LANE == 0 and m % LANE == 0
            assert working_set_bytes(l, m, d, group_size=g) <= int(
                TpuSpec().vmem_bytes * TpuSpec().usable_fraction
            )


def test_selection_maximises_l_first():
    """Mirrors the paper's rule: among legal configs, chosen l is maximal,
    and m is maximal given that l."""
    for d in (64, 128):
        l, m = select_block_sizes(d)
        legal = enumerate_block_sizes(d)
        max_l = max(x[0] for x in legal)
        assert l == max_l
        assert m == max(x[1] for x in legal if x[0] == l)


def test_distr_grouping_frees_vmem():
    """G*>1 shrinks the score-stage working set ⇒ same-or-larger blocks."""
    d = 256
    l1, m1 = select_block_sizes(d, group_size=1, max_l=2048, max_m=2048)
    l2, m2 = select_block_sizes(d, group_size=2, max_l=2048, max_m=2048)
    assert (l2, m2) >= (l1, m1)


def test_working_set_components():
    base = working_set_bytes(128, 128, 128)
    with_distr = working_set_bytes(128, 128, 128, group_size=2)
    # distr adds q̂ and k̂ buffers
    assert with_distr > base
