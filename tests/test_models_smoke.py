"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment req.)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, input_specs
from repro.models import lm
from repro.train.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step


def _batch(cfg, b=2, s=64):
    key = jax.random.PRNGKey(7)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.frontend == "patch_stub":
        npatch = min(cfg.num_patch_tokens, s // 2)
        batch["patches"] = jax.random.normal(key, (b, npatch, cfg.d_model))
        batch["tokens"] = batch["tokens"][:, : s - npatch]
        batch["labels"] = batch["labels"][:, : s - npatch]
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = lm.forward(
        params, cfg, batch["tokens"],
        patches=batch.get("patches"), frames=batch.get("frames"),
    )
    assert logits.shape[:2] == batch["labels"].shape
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
    opt_state = adamw_init(params)
    step = make_train_step(cfg, opt_cfg)
    new_params, new_opt, metrics = jax.jit(step)(
        params, opt_state, _batch(cfg), jnp.asarray(1)
    )
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params,
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


def test_full_configs_match_assignment():
    dims = {
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-130m": (24, 768, 12, 12, 0, 50280),
    }
    for arch, (l, d, h, kv, ff, v) in dims.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (l, d, h, kv, ff, v), arch
    # MoE / MLA / SSM extras
    ds = get_config("deepseek-v2-236b")
    assert (ds.n_experts, ds.moe_top_k, ds.kv_lora_rank) == (160, 6, 512)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.moe_top_k) == (16, 1)
    mb = get_config("mamba2-130m")
    assert mb.ssm_state == 128 and mb.is_attention_free
    zb = get_config("zamba2-7b")
    assert zb.ssm_state == 64 and zb.family == "hybrid"


def test_shape_skips_match_design():
    long = SHAPES["long_500k"]
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        supported = cfg.supports_shape(long)
        assert supported == (cfg.family in ("ssm", "hybrid")), arch
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cfg.supports_shape(SHAPES[s])


def test_input_specs_cover_all_cells():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not cfg.supports_shape(shape):
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for v in specs.values():
                assert v.shape[0] == shape.global_batch
