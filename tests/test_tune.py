"""Block-size autotuner (repro.tune): cache round-trip/key stability,
deterministic fake-timer tuning, pruner safety, numerical parity between
default and tuned blocks, and REPRO_TUNE=measure end-to-end dispatch."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AttentionConfig, attend, reference_attention
from repro.core.api import attend_decode
from repro.core.block_size import enumerate_block_sizes, io_count
from repro.kernels import ops
from repro.tune import (
    Autotuner,
    BlockSizes,
    TuneCache,
    cache_key,
    decode_candidates,
    pair_candidates,
    reset_autotuner,
    seq_bucket,
)


@pytest.fixture(autouse=True)
def _isolate_tuner(monkeypatch, tmp_path):
    """Every test gets a private cache path and a fresh singleton; the
    process-wide tuner is restored afterwards."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    reset_autotuner(None)
    yield
    reset_autotuner(None)


def _fake_timer_table(table):
    """Deterministic timer: seconds looked up per candidate."""

    def timer(run_fn, cand):
        del run_fn
        return table[cand]

    return timer


def _analytic_fake_timer(d, n):
    """Deterministic 'measurement' consistent with the analytic model:
    monotone in the paper's I/O count (larger l cheaper), with a small
    preference for larger m (fewer grid steps)."""

    def timer(run_fn, cand):
        del run_fn
        l, m = cand
        return io_count(l, n, d) + (n // m)

    return timer


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def test_cache_key_stability():
    kw = dict(backend="cpu:interpret", dtype="float32", d=64, group_size=2,
              n=300, causal=True)
    k1 = cache_key("flash_fwd", **kw)
    assert k1 == cache_key("flash_fwd", **kw)  # deterministic
    # bucketed: nearby lengths share the entry, bucket boundaries split it
    assert k1 == cache_key("flash_fwd", **{**kw, "n": 511})
    assert k1 != cache_key("flash_fwd", **{**kw, "n": 513})
    # every other field is load-bearing
    for field, val in [("backend", "tpu:compiled"), ("dtype", "bfloat16"),
                       ("d", 128), ("group_size", 1), ("causal", False)]:
        assert k1 != cache_key("flash_fwd", **{**kw, field: val})
    assert k1 != cache_key("flash_dq", **kw)


def test_cache_roundtrip_persists(tmp_path):
    path = str(tmp_path / "cache.json")
    c = TuneCache(path)
    entry = {"kernel": "flash_fwd", "best": [256, 256], "table": []}
    c.put("some|key", entry)
    assert c.get("some|key") == entry
    # a brand-new instance reads the persisted file
    c2 = TuneCache(path)
    assert c2.get("some|key") == entry
    # and the file is valid JSON on disk
    assert json.load(open(path))["some|key"]["best"] == [256, 256]


def test_cache_merge_on_save(tmp_path):
    """A stale in-memory snapshot must not clobber entries another process
    wrote to the shared cache file (the warm-once pattern)."""
    path = str(tmp_path / "shared.json")
    a, b = TuneCache(path), TuneCache(path)
    assert b.get("anything") is None  # b snapshots the (empty) file
    a.put("ka", {"best": [1, 1]})
    b.put("kb", {"best": [2, 2]})  # b's save merges, not overwrites
    assert set(json.load(open(path))) == {"ka", "kb"}


def test_partial_pin_gets_static_default(monkeypatch):
    """Pinning one block dim must not graft the free dim from a
    jointly-tuned pair: the free dim falls back to the static 128 and no
    sweep runs (a raising timer would abort any measurement)."""
    monkeypatch.setenv("REPRO_TUNE", "measure")

    def no_sweeps(run_fn, cand):
        raise AssertionError("partial pin must not trigger a sweep")

    reset_autotuner(Autotuner(timer=no_sweeps))
    from repro.core.api import resolve_attention_blocks
    from repro.core.distr_attention import DistrConfig

    bs = resolve_attention_blocks(
        AttentionConfig(impl="pallas_flash", block_q=256, block_k=None),
        d=64, n_q=512,
    )
    assert bs.fwd() == (256, 128)
    dcfg = DistrConfig(group_size=2, block_q=32, block_k=None).resolved(64, 512)
    assert (dcfg.block_q, dcfg.block_k) == (32, 128)


def test_corrupt_cache_quarantined(tmp_path):
    """A torn/corrupt cache file must not crash the loader (engine
    construction warms through it): it is moved aside to ``.corrupt`` and
    later saves start from a clean slate."""
    path = tmp_path / "c.json"
    path.write_text('{"half": [128,')  # a writer died mid-write
    c = TuneCache(str(path))
    assert c.get("anything") is None  # tolerated, not raised
    assert (tmp_path / "c.json.corrupt").exists()  # quarantined for autopsy
    assert not path.exists()
    c.put("k", {"best": [128, 128]})
    assert json.load(open(path))["k"]["best"] == [128, 128]
    # the quarantined bytes were preserved untouched
    assert (tmp_path / "c.json.corrupt").read_text() == '{"half": [128,'


def test_corrupt_cache_non_utf8_quarantined(tmp_path):
    """Torn writes are not always valid UTF-8: those must quarantine too,
    or the first save()'s merge-on-save re-read would crash."""
    path = tmp_path / "c.json"
    path.write_bytes(b"\xff\xfe{\"torn\": ")
    c = TuneCache(str(path))
    assert c.get("anything") is None
    assert (tmp_path / "c.json.corrupt").exists()
    c.put("k", {"best": [128, 128]})  # save() must not crash
    assert json.load(open(path))["k"]["best"] == [128, 128]


def test_cache_load_tolerates_unreadable_path(tmp_path):
    """open() failing with an OSError other than FileNotFoundError (e.g.
    the path is a directory) degrades to an empty cache, not a crash."""
    d = tmp_path / "a_directory"
    d.mkdir()
    assert TuneCache(str(d)).get("k") is None


def test_cache_env_override(monkeypatch, tmp_path):
    p = tmp_path / "elsewhere.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(p))
    c = TuneCache()
    c.put("k", {"best": [128, 128]})
    assert p.exists()


# ---------------------------------------------------------------------------
# Tuning decisions
# ---------------------------------------------------------------------------


def test_fake_timer_determinism(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "measure")
    d, n = 64, 256
    cands = pair_candidates(d, n=n)
    table = {c: 1.0 + ((7 * c[0] + c[1]) % 13) for c in cands}
    want = min(table, key=lambda c: table[c])
    picks = []
    for _ in range(2):  # fresh tuner each time: decided from cache/timer only
        tuner = Autotuner(timer=_fake_timer_table(table))
        picks.append(tuner.resolve_pair("flash_fwd", d=d, n=n))
    assert picks[0] == picks[1] == want


def test_pruner_never_drops_measured_best(monkeypatch):
    """With a measurement consistent with the analytic objective, the top-K
    analytic pruning keeps the candidate that full-space measurement would
    pick — the pruner only cuts cost, not quality."""
    monkeypatch.setenv("REPRO_TUNE", "measure")
    for d in (64, 128, 256):
        for g in (1, 2):
            n = 512
            timer = _analytic_fake_timer(d, n)
            nb = seq_bucket(n)
            full = {
                (min(l, nb), min(m, nb))
                for l, m, _ in enumerate_block_sizes(
                    d, group_size=g, max_l=1024, max_m=1024
                )
            }
            best_full = min(full, key=lambda c: timer(None, c))
            pruned = pair_candidates(d, n=n, group_size=g)
            assert best_full in pruned, (d, g, best_full, pruned)
            tuner = Autotuner(timer=timer)
            pick = tuner.resolve_pair("flash_fwd", d=d, n=n, group_size=g)
            assert pick == best_full


def test_candidates_include_default_and_fit(monkeypatch):
    for d in (64, 256):
        cands = pair_candidates(d, n=4096)
        assert (128, 128) in cands
        assert all(l % 128 == 0 and m % 128 == 0 for l, m in cands)
    assert all(bk <= 256 for bk in decode_candidates(200))


def test_modes(monkeypatch):
    tuner = Autotuner(timer=_fake_timer_table({}))
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert tuner.resolve_pair("flash_fwd", d=64, n=1024) == (128, 128)
    assert tuner.resolve_decode(d=64, n=1024) == 128
    monkeypatch.setenv("REPRO_TUNE", "analytic")
    l, m = tuner.resolve_pair("flash_fwd", d=64, n=4096)
    assert l >= 128 and m >= 128 and l % 128 == 0 and m % 128 == 0
    # the analytic rule at d=64 picks a larger-than-default tile
    assert (l, m) != (128, 128)
    monkeypatch.setenv("REPRO_TUNE", "bogus")
    with pytest.raises(ValueError):
        tuner.resolve_pair("flash_fwd", d=64, n=128)


def test_measured_entry_cached_and_reused(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNE", "measure")
    path = str(tmp_path / "c.json")
    calls = []

    def timer(run_fn, cand):
        calls.append(cand)
        return float(sum(cand) if isinstance(cand, tuple) else cand)

    t1 = Autotuner(cache=TuneCache(path), timer=timer)
    p1 = t1.resolve_pair("flash_fwd", d=64, n=256)
    n_calls = len(calls)
    assert n_calls > 0
    # second tuner, same cache file: pure lookup, no timing
    t2 = Autotuner(cache=TuneCache(path), timer=timer)
    assert t2.resolve_pair("flash_fwd", d=64, n=256) == p1
    assert len(calls) == n_calls


def test_distr_bwd_block_k_pinned_block_q(monkeypatch, tmp_path):
    """The distr backward sweeps block_k only: block_q is the LSH grouping
    granularity and stays pinned (asserted in the resolver), in every
    mode."""
    from repro.tune.autotune import distr_bwd_candidates

    # candidate space: only m varies, the default 128 is always present
    cands = distr_bwd_candidates(64, block_q=128, n=512, group_size=2)
    assert 128 in cands and len(cands) >= 2

    monkeypatch.setenv("REPRO_TUNE", "measure")
    path = str(tmp_path / "bwd.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)

    def timer(run_fn, cand):  # prefers the largest block_k
        return 1.0 / (int(cand) if not isinstance(cand, tuple)
                      else cand[0] * cand[1])

    tuner = Autotuner(cache=TuneCache(path), timer=timer)
    for kernel in ("distr_dq", "distr_dkv"):
        bq, bk = tuner.resolve_distr_bwd(
            kernel, block_q=128, d=64, n=256, group_size=2, causal=True,
        )
        assert bq == 128  # the pin
        assert bk == max(distr_bwd_candidates(
            64, block_q=128, n=256, group_size=2))
    # keys are per-kernel and carry the pinned l
    keys = set(json.load(open(path)))
    assert any("distr_dq@l=128" in key for key in keys)
    assert any("distr_dkv@l=128" in key for key in keys)

    # off/analytic: the fwd block_k carries over, still pinned
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert tuner.resolve_distr_bwd(
        "distr_dq", block_q=128, d=64, n=256, fwd_block_k=256
    ) == (128, 256)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distr_bwd_parity_default_vs_tuned(dtype):
    """An independently-chosen backward block_k changes performance, never
    gradients (explicit ``block_k_bwd`` pin exercises the same path the
    measure-mode resolution feeds)."""
    from dataclasses import replace as dc_replace

    from repro.core.distr_attention import DistrConfig

    q, k, v = _qkv(dtype, n=256, d=64)
    base_cfg = DistrConfig(group_size=2, block_q=128, block_k=128)
    tuned_cfg = dc_replace(base_cfg, block_k_bwd=64)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2

    def grads(cfg):
        return jax.grad(
            lambda q, k, v: ops.distr_attention(
                q, k, v, cfg, causal=True
            ).astype(jnp.float32).sum(),
            argnums=(0, 1, 2),
        )(q, k, v)

    for a, b in zip(grads(base_cfg), grads(tuned_cfg)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=tol, rtol=tol,
        )


def test_distr_bwd_lazy_measure_resolution(monkeypatch, tmp_path):
    """Under REPRO_TUNE=measure, grad-tracing a distr op sweeps the
    backward block_k keys lazily (fwd-only dispatch must not), and the
    gradients stay exact."""
    monkeypatch.setenv("REPRO_TUNE", "measure")
    path = str(tmp_path / "lazy.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)

    def timer(run_fn, cand):
        return float(int(cand) if not isinstance(cand, tuple)
                     else sum(cand))

    reset_autotuner(Autotuner(cache=TuneCache(path), timer=timer))
    from repro.core.distr_attention import DistrConfig

    q, k, v = _qkv(jnp.float32, n=256, d=64)
    cfg = DistrConfig(group_size=2, block_q=128, block_k=128)
    ops.distr_attention(q, k, v, cfg, causal=True)  # fwd only
    kernels = {e["kernel"] for e in json.load(open(path)).values()} \
        if os.path.exists(path) else set()
    assert "distr_dq" not in kernels and "distr_dkv" not in kernels

    g_meas = jax.grad(
        lambda q: ops.distr_attention(q, k, v, cfg, causal=True).sum()
    )(q)
    kernels = {e["kernel"] for e in json.load(open(path)).values()}
    assert {"distr_dq", "distr_dkv"} <= kernels

    monkeypatch.setenv("REPRO_TUNE", "off")
    reset_autotuner(None)
    g_off = jax.grad(
        lambda q: ops.distr_attention(q, k, v, cfg, causal=True).sum()
    )(q)
    np.testing.assert_allclose(
        np.asarray(g_meas), np.asarray(g_off), atol=5e-5, rtol=5e-5
    )


# ---------------------------------------------------------------------------
# Numerical parity: tuned blocks change performance, never results
# ---------------------------------------------------------------------------


def _qkv(dtype, n=256, d=32, hq=2, hkv=1):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, hq, n, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (1, hkv, n, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (1, hkv, n, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_parity_default_vs_tuned(dtype):
    q, k, v = _qkv(dtype)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    base = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    tuned = ops.flash_attention(
        q, k, v, causal=True, blocks=BlockSizes(block_q=256, block_k=64)
    )
    np.testing.assert_allclose(
        np.asarray(base, np.float32), np.asarray(tuned, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bwd_parity_default_vs_tuned(dtype):
    q, k, v = _qkv(dtype)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2

    def loss(blocks):
        def f(q, k, v):
            return ops.flash_attention(
                q, k, v, causal=True, blocks=blocks
            ).astype(jnp.float32).sum()

        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    g_base = loss(BlockSizes(128, 128))
    g_tuned = loss(
        BlockSizes(block_q=128, block_k=128, block_q_dq=64, block_k_dq=256,
                   block_q_dkv=256, block_k_dkv=64)
    )
    for a, b in zip(g_base, g_tuned):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=tol, rtol=tol,
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_parity_default_vs_tuned(dtype):
    d, s = 32, 256
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 2, 1, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (2, 1, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (2, 1, s, d), jnp.float32).astype(dtype)
    lens = jnp.asarray([100, 256], jnp.int32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    base = ops.decode_attention(q, k, v, lengths=lens, block_k=128)
    tuned = ops.decode_attention(q, k, v, lengths=lens, block_k=64)
    np.testing.assert_allclose(
        np.asarray(base, np.float32), np.asarray(tuned, np.float32),
        atol=tol, rtol=tol,
    )


# ---------------------------------------------------------------------------
# End-to-end: REPRO_TUNE=measure through attend / attend_decode
# ---------------------------------------------------------------------------


def test_measure_mode_end_to_end(monkeypatch, tmp_path):
    """attend/attend_decode with block_q=None sweep, cache, and stay exact."""
    monkeypatch.setenv("REPRO_TUNE", "measure")
    path = str(tmp_path / "e2e.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)

    # fake timer that prefers the largest tiles: deterministic, no wall clock
    def timer(run_fn, cand):
        if isinstance(cand, tuple):
            return 1.0 / (cand[0] * cand[1])
        return 1.0 / cand

    reset_autotuner(Autotuner(cache=TuneCache(path), timer=timer))

    q, k, v = _qkv(jnp.float32, n=200, d=32)
    cfg = AttentionConfig(impl="pallas_flash")  # block_q/block_k auto
    out = attend(q, k, v, cfg, causal=True)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
    )
    # fwd-only dispatch must NOT have swept the backward kernels...
    cache = json.load(open(path))
    assert {e["kernel"] for e in cache.values()} == {"flash_fwd"}
    # ...they resolve lazily when grad tracing reaches the op.
    jax.grad(
        lambda q: attend(q, k, v, cfg, causal=True).sum()
    )(q)

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    qd = jax.random.normal(ks[0], (2, 2, 1, 32), jnp.float32)
    kc = jax.random.normal(ks[1], (2, 1, 128, 32), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 1, 128, 32), jnp.float32)
    lens = jnp.asarray([60, 128], jnp.int32)
    od = attend_decode(qd, kc, vc, cfg, lengths=lens)
    odr = attend_decode(
        qd, kc, vc, AttentionConfig(impl="reference"), lengths=lens
    )
    np.testing.assert_allclose(
        np.asarray(od), np.asarray(odr), atol=2e-5, rtol=2e-5
    )

    cache = json.load(open(path))
    kernels = {e["kernel"] for e in cache.values()}
    assert {"flash_fwd", "flash_dq", "flash_dkv", "decode"} <= kernels
    # the fake timer prefers big tiles ⇒ the tuned fwd pick differs from 128²
    fwd = [e for e in cache.values() if e["kernel"] == "flash_fwd"][0]
    assert tuple(fwd["best"]) != (128, 128)

    # decode split tuning is independent of a pinned fwd pair: pinning the
    # prefill tiles still auto-resolves block_k_decode (fresh cache ⇒ the
    # only new key is the decode one).
    path2 = str(tmp_path / "e2e2.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path2)
    reset_autotuner(Autotuner(cache=TuneCache(path2), timer=timer))
    pinned = AttentionConfig(impl="pallas_flash", block_q=256, block_k=256)
    attend_decode(qd, kc, vc, pinned, lengths=lens)
    assert {e["kernel"] for e in json.load(open(path2)).values()} == {"decode"}


# ---------------------------------------------------------------------------
# Paged-decode pool-block tuning (ISSUE 5: tuner key for the paged split)
# ---------------------------------------------------------------------------


def test_paged_decode_resolution_modes(monkeypatch):
    from repro.tune.autotune import paged_block_candidates

    tuner = Autotuner(timer=_fake_timer_table({}))
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert tuner.resolve_paged_decode(d=64, n=1024) == 128
    monkeypatch.setenv("REPRO_TUNE", "analytic")
    bs = tuner.resolve_paged_decode(d=64, n=1024)
    assert bs in paged_block_candidates(1024)


def test_paged_decode_measure_caches_and_shapes_engine(monkeypatch, tmp_path):
    """measure-mode sweep runs the real paged kernel per candidate, persists
    under the ``paged_decode`` key, and a PagedServeEngine construction
    (warm_paged_engine) resolves its pool block size from that cache."""
    monkeypatch.setenv("REPRO_TUNE", "measure")
    path = str(tmp_path / "paged.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    cands = [64, 128, 256, 512]
    table = {c: 1.0 if c != 256 else 0.5 for c in cands}
    tuner = Autotuner(cache=TuneCache(path), timer=_fake_timer_table(table))
    reset_autotuner(tuner)
    bs = tuner.resolve_paged_decode(d=32, n=512, dtype="bfloat16")
    assert bs == 256
    cache = json.load(open(path))
    assert any(k.startswith("paged_decode|") for k in cache)

    # the engine's construction warm-up resolves from the same cache
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import PagedServeEngine

    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServeEngine(cfg, params, max_batch=2, max_len=512)
    assert eng.block_size == 256
    assert eng.tuned_blocks["paged_decode"] == 256
