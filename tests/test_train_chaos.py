"""Train-side chaos suite: verified checkpoints, anomaly rollback, and the
elastic supervisor, driven fault-by-fault through the shared injector
(repro.faults TRAIN_POINTS).

Fast half: the shared fault catalog + serve shim, the frozen counter schema,
checkpoint integrity/fallback/GC on tiny numpy pytrees, AnomalyDetector and
StragglerTracker units, and the TrainSupervisor over a lightweight fake
trainer.  Slow half (@pytest.mark.slow): every recovery path end-to-end on a
real reduced-config Trainer — spike rollback, persistent-spike halt, torn
checkpoint resume, NaN skip/halt, emergency saves, resume equivalence, and
supervisor worker-loss recovery matching an uninterrupted run bit-for-bit.
"""
import os
from collections import Counter

import numpy as np
import pytest

from repro.faults import (
    NULL_INJECTOR,
    POINTS,
    SERVE_POINTS,
    TRAIN_POINTS,
    FaultInjector,
    FaultSpec,
)
from repro.train import checkpoint as ckpt
from repro.train.anomaly import AnomalyConfig, AnomalyDetector, AnomalyHalt
from repro.train.elastic import (
    COUNTER_KEYS,
    StragglerPolicy,
    StragglerTracker,
    counters_view,
)
from repro.train.supervisor import NoSurvivorsError, TrainSupervisor


# ---------------------------------------------------------------------------
# shared fault machinery: catalog, shim, counted triggers
# ---------------------------------------------------------------------------

def test_fault_catalog_is_split_per_domain():
    assert set(TRAIN_POINTS) == {
        "ckpt_torn_write", "nan_grad", "loss_spike",
        "worker_loss", "slow_worker", "data_shard_corrupt",
    }
    assert POINTS == SERVE_POINTS + TRAIN_POINTS
    assert not set(SERVE_POINTS) & set(TRAIN_POINTS)


def test_serve_faults_is_a_shim_over_shared_module():
    """serve.faults re-exports the SAME objects — specs built through either
    import path are interchangeable."""
    from repro.serve import faults as serve_faults

    assert serve_faults.FaultInjector is FaultInjector
    assert serve_faults.FaultSpec is FaultSpec
    assert serve_faults.NULL_INJECTOR is NULL_INJECTOR
    assert serve_faults.POINTS is POINTS


def test_unknown_fault_point_rejected():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("disk_on_fire")


def test_counted_trigger_window_and_uid():
    inj = FaultInjector([FaultSpec("nan_grad", after=2, times=2)])
    fired = [inj.fires("nan_grad") is not None for _ in range(6)]
    assert fired == [False, False, True, True, False, False]

    # uid-restricted specs only count consults for their uid
    inj = FaultInjector([FaultSpec("worker_loss", uid=3, after=1)])
    assert inj.fires("worker_loss", uid=0) is None
    assert inj.fires("worker_loss", uid=3) is None  # hit 0 < after
    assert inj.fires("worker_loss", uid=0) is None  # doesn't consume uid=3
    assert inj.fires("worker_loss", uid=3) is not None

    # exhausted specs stay exhausted across rollback replays
    assert inj.fires("worker_loss", uid=3) is None


# ---------------------------------------------------------------------------
# frozen counter schema
# ---------------------------------------------------------------------------

def test_counter_schema_frozen():
    """Regression-freeze the robustness counter schema (the training analog
    of serve.lifecycle.COUNTER_KEYS) — extending it is a deliberate act."""
    assert COUNTER_KEYS == (
        "nan_skips",
        "rollbacks",
        "anomaly_halts",
        "torn_ckpt_fallbacks",
        "data_corrupt_batches",
        "emergency_saves",
        "emergency_save_failures",
        "remesh_events",
        "worker_deaths",
        "straggler_flags",
    )


def test_counters_view_zero_fills_and_drops_unknown():
    view = counters_view(Counter({"rollbacks": 2, "not_a_counter": 9}))
    assert set(view) == set(COUNTER_KEYS)
    assert view["rollbacks"] == 2
    assert view["nan_skips"] == 0
    assert "not_a_counter" not in view


# ---------------------------------------------------------------------------
# checkpoint integrity: manifest, fallback, GC, tags
# ---------------------------------------------------------------------------

def _tiny_params(shift=0.0):
    return {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3) + shift,
        "b": np.full((3,), shift, np.float32),
    }


def _torn(uid=None, times=1):
    return FaultInjector([FaultSpec("ckpt_torn_write", uid=uid, times=times)])


def test_manifest_written_and_verifies(tmp_path):
    path = ckpt.save_checkpoint(str(tmp_path), 7, _tiny_params())
    assert os.path.exists(os.path.join(path, ckpt.MANIFEST_NAME))
    assert ckpt.verify_checkpoint(path) == []
    assert ckpt.latest_verified_name(str(tmp_path)) == "step_00000007"


def test_verify_catches_bit_flip(tmp_path):
    path = ckpt.save_checkpoint(str(tmp_path), 1, _tiny_params())
    ppath = os.path.join(path, "params.npz")
    with open(ppath, "r+b") as f:
        f.seek(os.path.getsize(ppath) - 20)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))
    problems = ckpt.verify_checkpoint(path)
    assert problems  # checksum mismatch or torn archive, depending on offset
    assert not ckpt.is_verified(path)


def test_injected_torn_write_fails_verification(tmp_path):
    path = ckpt.save_checkpoint(str(tmp_path), 3, _tiny_params(),
                                faults=_torn())
    assert not ckpt.is_verified(path)
    # the directory still LOOKS complete — that's the point
    assert os.path.exists(os.path.join(path, "meta.json"))


def test_resume_falls_back_over_torn_latest(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tiny_params(1.0), keep=10)
    ckpt.save_checkpoint(d, 2, _tiny_params(2.0), keep=10)
    ckpt.save_checkpoint(d, 3, _tiny_params(3.0), keep=10, faults=_torn(uid=3))
    step, params, _, meta = ckpt.load_checkpoint(d, _tiny_params())
    assert step == 2
    assert meta["_fallback_skipped"] == 1
    assert meta["_name"] == "step_00000002"
    np.testing.assert_array_equal(params["b"], np.full((3,), 2.0, np.float32))


def test_explicit_corrupt_step_raises(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tiny_params(), keep=10)
    ckpt.save_checkpoint(d, 2, _tiny_params(), keep=10, faults=_torn(uid=2))
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.load_checkpoint(d, _tiny_params(), step=2)


def test_all_corrupt_raises(tmp_path):
    d = str(tmp_path)
    inj = _torn(times=-1)
    for s in (1, 2, 3):
        ckpt.save_checkpoint(d, s, _tiny_params(), keep=10, faults=inj)
    with pytest.raises(ckpt.CheckpointCorrupt, match="no verified checkpoint"):
        ckpt.load_checkpoint(d, _tiny_params())


def test_gc_never_deletes_last_verified(tmp_path):
    """keep=2 would normally drop step 10, but it is the only checkpoint
    that verifies — GC must protect it."""
    d = str(tmp_path)
    inj = FaultInjector([FaultSpec("ckpt_torn_write", after=1, times=-1)])
    ckpt.save_checkpoint(d, 10, _tiny_params(), keep=2, faults=inj)
    for s in (20, 30, 40):
        ckpt.save_checkpoint(d, s, _tiny_params(), keep=2, faults=inj)
    assert ckpt.list_checkpoints(d) == [10, 30, 40]
    assert ckpt.latest_verified_name(d) == "step_00000010"
    step, _, _, meta = ckpt.load_checkpoint(d, _tiny_params())
    assert step == 10 and meta["_fallback_skipped"] == 2


def test_tagged_save_never_clobbers_and_untagged_preferred(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 5, _tiny_params(), data_state={"step": 1},
                         keep=10)
    ckpt.save_checkpoint(d, 5, _tiny_params(), data_state={"step": 2},
                         keep=10, tag="emergency")
    names = ckpt.list_checkpoint_names(d)
    assert names == ["step_00000005-emergency", "step_00000005"]
    step, _, _, meta = ckpt.load_checkpoint(d, _tiny_params())
    assert step == 5
    assert meta["_name"] == "step_00000005"  # untagged wins at equal step
    assert meta["data_state"] == {"step": 1}
    with pytest.raises(ValueError, match="filename-safe"):
        ckpt.checkpoint_name(5, tag="not/safe")


def test_verify_false_loads_pre_manifest_checkpoint(tmp_path):
    d = str(tmp_path)
    path = ckpt.save_checkpoint(d, 4, _tiny_params(4.0))
    os.remove(os.path.join(path, ckpt.MANIFEST_NAME))  # legacy layout
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.load_checkpoint(d, _tiny_params())
    step, params, _, _ = ckpt.load_checkpoint(d, _tiny_params(), verify=False)
    assert step == 4
    np.testing.assert_array_equal(params["b"], np.full((3,), 4.0, np.float32))


# ---------------------------------------------------------------------------
# anomaly detector units
# ---------------------------------------------------------------------------

_CFG = AnomalyConfig(warmup=5, z_threshold=4.0, min_rel_increase=0.25)


def _feed_stable(det, n=12, base=1.0):
    jitter = [0.0, 0.01, -0.01, 0.02, -0.02]
    for i in range(n):
        assert det.update(base + jitter[i % 5], base + jitter[(i + 2) % 5]) is None


def test_spike_flags_after_warmup():
    det = AnomalyDetector(_CFG)
    _feed_stable(det)
    report = det.update(10.0, 1.0)
    assert report is not None and "loss_z" in report
    assert report["loss_z"] > _CFG.z_threshold


def test_warmup_suppresses_early_spikes():
    det = AnomalyDetector(AnomalyConfig(warmup=10, z_threshold=4.0))
    for _ in range(3):
        assert det.update(1.0, 1.0) is None
    assert det.update(50.0, 1.0) is None  # still inside warmup


def test_detector_is_one_sided():
    det = AnomalyDetector(_CFG)
    _feed_stable(det)
    assert det.update(0.01, 1.0) is None  # a loss cliff downward never flags


def test_spike_not_absorbed_into_stats():
    det = AnomalyDetector(_CFG)
    _feed_stable(det)
    assert det.update(10.0, 1.0) is not None
    # the spike did not drag the baseline up: it flags again immediately,
    # and a normal sample right after is clean
    assert det.update(10.0, 1.0) is not None
    assert det.update(1.0, 1.0) is None


def test_flat_plateau_needs_relative_increase():
    """Zero variance makes every z infinite — min_rel_increase is the
    backstop that keeps femto-jitter from flagging."""
    det = AnomalyDetector(_CFG)
    for _ in range(10):
        assert det.update(1.0, 1.0) is None
    assert det.update(1.1, 1.0) is None  # +10% < min_rel_increase
    assert det.update(1.5, 1.0) is not None  # +50%, z=inf


def test_grad_norm_spikes_flag_independently():
    det = AnomalyDetector(_CFG)
    _feed_stable(det)
    report = det.update(1.0, 25.0)
    assert report is not None and list(report) == ["grad_norm_z"]


def test_disabled_detector_never_flags():
    det = AnomalyDetector(AnomalyConfig(enabled=False, warmup=0))
    for _ in range(5):
        assert det.update(1e9, 1e9) is None


# ---------------------------------------------------------------------------
# straggler tracker units
# ---------------------------------------------------------------------------

def test_straggler_escalates_at_patience():
    tr = StragglerTracker(StragglerPolicy(threshold=2.0, patience=3))
    times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0}
    assert tr.observe(times) == ([3], [])
    assert tr.observe(times) == ([3], [])
    assert tr.observe(times) == ([3], [3])  # streak reaches patience
    assert tr.observe(times) == ([3], [])  # escalates exactly once


def test_straggler_streak_clears_on_fast_step():
    tr = StragglerTracker(StragglerPolicy(threshold=2.0, patience=2))
    slow = {0: 1.0, 1: 1.0, 2: 8.0}
    fast = {0: 1.0, 1: 1.0, 2: 1.0}
    assert tr.observe(slow) == ([2], [])
    assert tr.observe(fast) == ([], [])  # one slow step is forgiven
    assert tr.observe(slow) == ([2], [])
    assert tr.observe(slow) == ([2], [2])


def test_straggler_forget_resets_state():
    tr = StragglerTracker(StragglerPolicy(threshold=2.0, patience=2))
    tr.observe({0: 1.0, 1: 9.0, 2: 1.0})
    tr.forget(1)
    assert tr.observe({0: 1.0, 1: 9.0, 2: 1.0}) == ([1], [])  # streak restarted


# ---------------------------------------------------------------------------
# supervisor over a fake trainer
# ---------------------------------------------------------------------------

class FakeTrainer:
    """The Trainer surface the supervisor needs, with instant steps and an
    in-memory 'checkpoint' at every ckpt_every-th step."""

    def __init__(self, ckpt_every=5):
        self.step = 0
        self.counters = Counter()
        self.history = []
        self.ckpt_every = ckpt_every
        self._ckpt_step = 0
        self.restores = []

    def step_once(self):
        self.step += 1
        rec = {"step": self.step, "loss": 1.0}
        self.history.append(rec)
        if self.step % self.ckpt_every == 0:
            self._ckpt_step = self.step
        return rec

    def restore_from_checkpoint(self, *, restore_data=True):
        self.restores.append(self.step)
        self.step = self._ckpt_step
        self.history = [r for r in self.history if r["step"] <= self.step]
        return self.step


def test_supervisor_healthy_run_is_quiet():
    sup = TrainSupervisor(FakeTrainer(), num_workers=4)
    hist = sup.run(10)
    assert len(hist) == 10 and sup.ticks == 10
    assert sup.events == [] and sup.alive == [0, 1, 2, 3]
    assert all(v == 0 for v in sup.counters_snapshot().values())
    assert sup.mesh_plan == ((4, 1), ("data", "model"))


def test_supervisor_worker_loss_remesh_and_restore():
    ft = FakeTrainer(ckpt_every=5)
    inj = FaultInjector([FaultSpec("worker_loss", uid=2, after=6, times=-1)])
    sup = TrainSupervisor(ft, num_workers=4, max_missed=2, faults=inj)
    sup.run(12)
    snap = sup.counters_snapshot()
    assert snap["worker_deaths"] == 1 and snap["remesh_events"] == 1
    # worker 2 stops beating on tick 7 and crosses max_missed=2 that same
    # tick (a beat-then-count detector carries one standing miss), so the
    # remesh+restore lands with the trainer at step 6 → back to the step-5
    # snapshot
    assert ft.restores == [6]
    assert sup.alive == [0, 1, 3]
    assert sup.mesh_plan == ((3, 1), ("data", "model"))
    # every shard reassigned exactly once across the survivors
    shards = sorted(s for v in sup.shard_assignment.values() for s in v)
    assert shards == list(range(sup.num_shards))
    assert set(sup.shard_assignment) == {0, 1, 3}
    kinds = [e["kind"] for e in sup.events]
    assert kinds == ["worker_loss", "remesh"]
    # trainer resumed from the step-5 snapshot and still reached the target
    assert ft.step == 12 and [r["step"] for r in ft.history] == list(range(1, 13))


def test_supervisor_straggler_excluded_via_death_path():
    ft = FakeTrainer(ckpt_every=4)
    inj = FaultInjector([FaultSpec("slow_worker", uid=1, times=-1, delay=9.0)])
    sup = TrainSupervisor(
        ft, num_workers=4, max_missed=2, faults=inj,
        straggler_policy=StragglerPolicy(threshold=2.0, patience=2),
    )
    sup.run(12)
    snap = sup.counters_snapshot()
    assert snap["straggler_flags"] >= 2
    assert snap["worker_deaths"] == 1 and snap["remesh_events"] == 1
    assert sup.alive == [0, 2, 3]
    kinds = [e["kind"] for e in sup.events]
    assert kinds == ["straggler_excluded", "remesh"]


def test_supervisor_no_survivors_raises():
    inj = FaultInjector([FaultSpec("worker_loss", times=-1)])  # uid=None: all
    sup = TrainSupervisor(FakeTrainer(), num_workers=3, max_missed=1,
                          faults=inj)
    with pytest.raises(NoSurvivorsError):
        sup.run(5)


def test_supervisor_tick_budget_bounds_pathology():
    class StuckTrainer(FakeTrainer):
        def step_once(self):
            return None  # e.g. every step consumed by rollbacks

    sup = TrainSupervisor(StuckTrainer(), num_workers=2)
    with pytest.raises(RuntimeError, match="exhausted"):
        sup.run(5, max_ticks=7)
    assert sup.ticks == 7


def test_supervisor_snapshot_merges_trainer_counters():
    ft = FakeTrainer()
    ft.counters["nan_skips"] = 2
    sup = TrainSupervisor(ft, num_workers=2)
    sup.counters["remesh_events"] = 1
    snap = sup.counters_snapshot()
    assert tuple(snap) == COUNTER_KEYS
    assert snap["nan_skips"] == 2 and snap["remesh_events"] == 1


def test_supervisor_rejects_empty_worker_set():
    with pytest.raises(ValueError):
        TrainSupervisor(FakeTrainer(), num_workers=0)


# ---------------------------------------------------------------------------
# slow: every recovery path end-to-end on a real reduced-config Trainer
# ---------------------------------------------------------------------------

def _make_trainer(workdir, *, batch=2, seq=16, lr=1e-3, total=40, seed=0,
                  **kw):
    from repro.configs import get_config
    from repro.train.data import SyntheticLMData
    from repro.train.optimizer import OptimizerConfig
    from repro.train.trainer import Trainer

    cfg = get_config("minicpm-2b", reduced=True)
    opt = OptimizerConfig(peak_lr=lr, warmup_steps=2, total_steps=total)
    data = SyntheticLMData(cfg.vocab, batch, seq, seed=seed)
    return Trainer(cfg, opt, data, workdir=workdir, log_every=1000, **kw)


_LOOSE = AnomalyConfig(warmup=3, z_threshold=6.0)


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_loss_spike_rolls_back_and_continues(tmp_path):
    inj = FaultInjector([FaultSpec("loss_spike", after=8)])
    tr = _make_trainer(str(tmp_path), ckpt_every=5, anomaly=_LOOSE,
                       faults=inj)
    hist = tr.run(15)
    snap = tr.counters_snapshot()
    assert snap["rollbacks"] == 1 and snap["anomaly_halts"] == 0
    # rolled back to the step-5 checkpoint, then re-trained through the
    # window on the advanced data stream — one coherent trajectory
    assert [r["step"] for r in hist] == list(range(1, 16))
    assert all(np.isfinite(r["loss"]) for r in hist)


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_persistent_spike_exhausts_rollbacks_and_halts(tmp_path):
    inj = FaultInjector([FaultSpec("loss_spike", after=6, times=-1)])
    cfg = AnomalyConfig(warmup=3, z_threshold=6.0, max_rollbacks=2)
    tr = _make_trainer(str(tmp_path), ckpt_every=5, anomaly=cfg, faults=inj)
    with pytest.raises(AnomalyHalt):
        tr.run(15)
    snap = tr.counters_snapshot()
    assert snap["rollbacks"] == 2 and snap["anomaly_halts"] == 1
    # the halt left a tagged forensic checkpoint, never clobbering the
    # periodic one at the same step
    names = ckpt.list_checkpoint_names(os.path.join(str(tmp_path),
                                                    "checkpoints"))
    assert any(n.endswith("-anomaly-halt") for n in names)
    assert snap["emergency_saves"] == 0  # AnomalyHalt skips the emergency path


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_torn_checkpoint_resume_falls_back(tmp_path):
    inj = FaultInjector([FaultSpec("ckpt_torn_write", uid=8)])
    tr = _make_trainer(str(tmp_path), ckpt_every=4, faults=inj)
    while tr.step < 8:
        tr.step_once()
    # saves landed at 0 (baseline), 4 (good) and 8 (torn); abandon the run
    ckpt_dir = os.path.join(str(tmp_path), "checkpoints")
    assert ckpt.list_checkpoints(ckpt_dir) == [0, 4, 8]
    assert not ckpt.is_verified(os.path.join(ckpt_dir, "step_00000008"))

    tr2 = _make_trainer(str(tmp_path), ckpt_every=4)
    assert tr2.step == 4  # resumed past the torn latest
    assert tr2.counters_snapshot()["torn_ckpt_fallbacks"] == 1


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_nan_grad_skipped_and_counted(tmp_path):
    inj = FaultInjector([FaultSpec("nan_grad", after=3)])
    tr = _make_trainer(str(tmp_path), ckpt_every=100, anomaly=_LOOSE,
                       faults=inj)
    hist = tr.run(6)
    snap = tr.counters_snapshot()
    assert snap["nan_skips"] == 1 and snap["rollbacks"] == 0
    assert len(hist) == 6
    # the poisoned step recorded a non-finite loss but training continued,
    # and the suppressed update left the next steps finite
    assert not np.isfinite(hist[3]["loss"])
    assert all(np.isfinite(hist[i]["loss"]) for i in (2, 4, 5))


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_nan_policy_halt_saves_tagged_checkpoint(tmp_path):
    inj = FaultInjector([FaultSpec("nan_grad", after=2)])
    tr = _make_trainer(str(tmp_path), ckpt_every=100, nan_policy="halt",
                       faults=inj)
    with pytest.raises(FloatingPointError):
        tr.run(6)
    names = ckpt.list_checkpoint_names(os.path.join(str(tmp_path),
                                                    "checkpoints"))
    assert "step_00000002-nan-halt" in names
    assert tr.counters_snapshot()["nan_skips"] == 1


class _CrashingData:
    """Wraps a dataset; next_batch raises once the wrapped stream has
    yielded ``crash_after`` batches — models a dying data reader."""

    def __init__(self, inner, crash_after):
        self.inner = inner
        self.crash_after = crash_after
        self._served = 0

    def next_batch(self):
        if self._served >= self.crash_after:
            raise RuntimeError("data reader died")
        self._served += 1
        return self.inner.next_batch()

    def state(self):
        return self.inner.state()

    def restore(self, state):
        self.inner.restore(state)


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_emergency_save_is_tagged_and_failures_are_logged(tmp_path, capsys,
                                                          monkeypatch):
    tr = _make_trainer(str(tmp_path), ckpt_every=3)
    tr.dataset = _CrashingData(tr.dataset, crash_after=6)
    with pytest.raises(RuntimeError, match="data reader died"):
        tr.run(10)
    snap = tr.counters_snapshot()
    assert snap["emergency_saves"] == 1 and snap["emergency_save_failures"] == 0
    names = ckpt.list_checkpoint_names(os.path.join(str(tmp_path),
                                                    "checkpoints"))
    # tag suffix: the emergency save at step 6 coexists with the periodic
    # checkpoint written at the same step — never clobbered
    assert "step_00000006" in names and "step_00000006-emergency" in names

    # a failing emergency save is logged + counted, never swallowed
    def _boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt, "save_checkpoint", _boom)
    with pytest.raises(RuntimeError, match="data reader died"):
        tr.run(10)
    assert tr.counters_snapshot()["emergency_save_failures"] == 1
    assert "EMERGENCY SAVE FAILED" in capsys.readouterr().out


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_resume_after_kill_matches_uninterrupted_run(tmp_path):
    """Periodic-checkpoint kill: train 12 straight vs. kill at step 7 and
    resume from the step-5 checkpoint — identical per-step loss history."""
    straight = _make_trainer(str(tmp_path / "a"), ckpt_every=5)
    for _ in range(12):
        straight.step_once()

    killed = _make_trainer(str(tmp_path / "b"), ckpt_every=5)
    for _ in range(7):
        killed.step_once()
    assert [r["step"] for r in killed.history[:5]] == list(range(1, 6))

    resumed = _make_trainer(str(tmp_path / "b"), ckpt_every=5)
    assert resumed.step == 5
    while resumed.step < 12:
        resumed.step_once()
    want = [(r["step"], r["loss"]) for r in straight.history[5:]]
    got = [(r["step"], r["loss"]) for r in resumed.history]
    assert got == want  # bit-identical, not approximately equal


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_resume_from_emergency_checkpoint_matches_uninterrupted(tmp_path):
    """Emergency-checkpoint kill: no periodic save ever landed, the crash
    path's -emergency save is the resume point."""
    straight = _make_trainer(str(tmp_path / "a"), ckpt_every=100)
    for _ in range(12):
        straight.step_once()

    crashed = _make_trainer(str(tmp_path / "b"), ckpt_every=100)
    crashed.dataset = _CrashingData(crashed.dataset, crash_after=8)
    with pytest.raises(RuntimeError):
        crashed.run(12)
    names = ckpt.list_checkpoint_names(os.path.join(str(tmp_path / "b"),
                                                    "checkpoints"))
    assert "step_00000008-emergency" in names

    resumed = _make_trainer(str(tmp_path / "b"), ckpt_every=100)
    assert resumed.step == 8
    while resumed.step < 12:
        resumed.step_once()
    want = [(r["step"], r["loss"]) for r in straight.history[8:]]
    got = [(r["step"], r["loss"]) for r in resumed.history]
    assert got == want


@pytest.mark.slow
@pytest.mark.timeout(300)
def test_supervisor_worker_loss_recovery_matches_uninterrupted(tmp_path):
    """The full elastic loop: worker dies mid-run, supervisor remeshes and
    restores from the verified checkpoint — and because checkpoints are
    mesh-agnostic and the data stream deterministic, the recovered loss
    history is IDENTICAL to an uninterrupted run."""
    plain = _make_trainer(str(tmp_path / "a"), ckpt_every=5)
    for _ in range(14):
        plain.step_once()

    tr = _make_trainer(str(tmp_path / "b"), ckpt_every=5)
    inj = FaultInjector([FaultSpec("worker_loss", uid=2, after=7, times=-1)])
    sup = TrainSupervisor(tr, num_workers=4, max_missed=2, faults=inj)
    hist = sup.run(14)
    snap = sup.counters_snapshot()
    assert snap["worker_deaths"] == 1 and snap["remesh_events"] == 1
    assert sup.alive == [0, 1, 3]
    assert [(r["step"], r["loss"]) for r in hist] == \
        [(r["step"], r["loss"]) for r in plain.history]


@pytest.mark.slow
@pytest.mark.timeout(600)
def test_data_shard_corrupt_caught_by_anomaly_guard(tmp_path):
    """A corrupt shard's scrambled labels push the loss back toward
    log(vocab); after warmup that excursion z-flags and the rollback
    re-trains past the window on the advanced stream."""
    inj = FaultInjector([FaultSpec("data_shard_corrupt", after=39)])
    cfg = AnomalyConfig(warmup=10, z_threshold=3.0, min_rel_increase=0.06,
                        max_rollbacks=3)
    tr = _make_trainer(str(tmp_path), batch=4, seq=32, lr=3e-3, total=60,
                       ckpt_every=10, anomaly=cfg, faults=inj)
    hist = tr.run(45)
    snap = tr.counters_snapshot()
    assert snap["data_corrupt_batches"] == 1
    assert snap["rollbacks"] == 1 and snap["anomaly_halts"] == 0
    assert [r["step"] for r in hist] == list(range(1, 46))
    # the run recovered: post-rollback training kept converging
    assert hist[-1]["loss"] < 6.0
