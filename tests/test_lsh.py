"""LSH hashing unit + property tests (paper §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lsh


def test_projection_shape_and_values():
    proj = lsh.make_projection(jax.random.PRNGKey(0), 64)
    assert proj.shape == (lsh.N_PRIME, 64)
    assert set(np.unique(np.asarray(proj))) <= {-1.0, 1.0}


def test_inverse_gray_is_bijection_16bit():
    codes = jnp.arange(2**16, dtype=jnp.uint32)
    decoded = np.asarray(lsh.inverse_gray(codes))
    assert len(np.unique(decoded & 0xFFFF)) == 2**16


def test_inverse_gray_adjacent_ranks_differ_one_bit():
    # gray(r) ^ gray(r+1) has exactly one bit set; inverse_gray inverts gray.
    r = np.arange(2**12, dtype=np.uint32)
    gray = r ^ (r >> 1)
    dec = np.asarray(lsh.inverse_gray(jnp.asarray(gray)))
    assert np.array_equal(dec, r)


@pytest.mark.parametrize("method", ["sign_gray", "proj_morton"])
def test_hash_columns_shape_determinism(method):
    key = jax.random.PRNGKey(1)
    block = jax.random.normal(key, (3, 2, 32, 64))
    proj = lsh.make_projection(jax.random.PRNGKey(0), 32)
    h1 = lsh.hash_columns(block, proj, method)
    h2 = lsh.hash_columns(block, proj, method)
    assert h1.shape == (3, 2, 64)
    assert jnp.array_equal(h1, h2)


@pytest.mark.parametrize("method", ["sign_gray", "proj_morton"])
def test_permutation_is_valid(method):
    block = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 128))
    proj = lsh.make_projection(jax.random.PRNGKey(0), 16)
    perm = lsh.lsh_permutation(block, proj, method)
    for p in np.asarray(perm).reshape(-1, 128):
        assert sorted(p.tolist()) == list(range(128))


def test_similar_columns_group_together():
    """Duplicated columns must receive adjacent hash ranks."""
    key = jax.random.PRNGKey(3)
    half = jax.random.normal(key, (32, 32))
    block = jnp.concatenate([half, half], axis=1)  # d=64, dup pairs (i, i+32)
    proj = lsh.make_projection(jax.random.PRNGKey(0), 32)
    h = np.asarray(lsh.hash_columns(block, proj, "sign_gray"))
    assert np.array_equal(h[:32], h[32:])  # identical columns → identical hash


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_inverse_gray_roundtrip_property(x):
    g = np.uint32(x ^ (x >> 1))
    decoded = int(lsh.inverse_gray(jnp.asarray([g], jnp.uint32))[0]) & 0xFFFFFFFF
    assert decoded == x  # compare unsigned (hash is int32-typed)
