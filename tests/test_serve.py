"""Serving: prefill/decode parity vs full forward, engine, sampler, fused
decode cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.core import grouping
from repro.models import lm
from repro.serve import kv_cache
from repro.serve.engine import ServeEngine
from repro.serve.sampler import sample
from repro.serve.serve_step import make_decode_step, make_prefill


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    """Prefill S tokens then decode token S ⇒ logits equal the full forward
    at position S (exact attention; fp32 reduced configs)."""
    cfg = get_config(arch, reduced=True)
    cfg = cfg.replace(attention=cfg.attention.with_impl("reference"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S, MAX = 2, 32, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    kwargs = {}
    if cfg.frontend == "patch_stub":
        kwargs["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, S, cfg.d_model), jnp.float32
        )
    logits_full, _ = lm.forward(params, cfg, toks, **kwargs)
    want = logits_full[:, -1]
    _, cache = make_prefill(cfg, MAX)(params, toks[:, :S], **kwargs)
    npre = 8 if cfg.frontend == "patch_stub" else 0
    pos = jnp.full((B,), S + npre, jnp.int32)
    got, _ = make_decode_step(cfg)(params, toks[:, S : S + 1], cache, pos)
    rel = float(jnp.abs(want - got[:, 0]).max()) / max(
        float(jnp.abs(want).max()), 1e-6
    )
    assert rel < 5e-3, f"{arch}: rel err {rel}"


def test_decode_positions_are_per_slot():
    """Continuous batching: slots at different positions decode correctly."""
    cfg = get_config("qwen1.5-4b", reduced=True)
    cfg = cfg.replace(attention=cfg.attention.with_impl("reference"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, MAX = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 24), 0, cfg.vocab)
    decode = make_decode_step(cfg)

    # slot 0 prefilled with 8 tokens, slot 1 with 16 (same stream prefix)
    _, cache8 = make_prefill(cfg, MAX)(params, toks[:, :8])
    _, cache16 = make_prefill(cfg, MAX)(params, toks[:, :16])
    mixed = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[:, :1] if a.ndim > 1 and a.shape[1] == B else a[:1],
                                      b[:, 1:2] if b.ndim > 1 and b.shape[1] == B else b[1:2]],
                                     axis=1 if a.ndim > 1 and a.shape[1] == B else 0),
        cache8, cache16,
    )
    pos = jnp.asarray([8, 16], jnp.int32)
    nxt = jnp.stack([toks[0, 8], toks[1, 16]])[:, None]
    got, _ = decode(params, nxt, mixed, pos)

    want0, _ = decode(params, toks[:, 8:9], cache8, jnp.full((B,), 8, jnp.int32))
    want1, _ = decode(params, toks[:, 16:17], cache16, jnp.full((B,), 16, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(got[0, 0]), np.asarray(want0[0, 0]), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(got[1, 0]), np.asarray(want1[1, 0]), rtol=1e-4, atol=1e-4
    )


def test_engine_continuous_batching_more_requests_than_slots():
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=2, max_len=64)
    for i in range(5):
        eng.add_request([1 + i, 2, 3], max_new_tokens=4)
    done = eng.run_to_completion()
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)


def test_engine_greedy_deterministic():
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, max_slots=1, max_len=64)
        eng.add_request([5, 6, 7], max_new_tokens=6)
        outs.append(eng.run_to_completion()[0].generated)
    assert outs[0] == outs[1]


def test_sampler_modes():
    logits = jnp.asarray([[0.1, 2.0, -1.0, 0.5]])
    assert int(sample(logits)[0]) == 1  # greedy
    t = sample(logits, rng=jax.random.PRNGKey(0), temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 3)  # top-2 restricted


def test_sampler_top_p_nucleus():
    """p = [0.6, 0.3, 0.1]: top_p=0.7 keeps {0, 1} (cum mass before token 2
    is 0.9 ≥ 0.7); a tiny top_p still keeps the argmax."""
    p = jnp.asarray([[0.6, 0.3, 0.1]])
    logits = jnp.log(p)
    seen = {
        int(sample(logits, rng=jax.random.PRNGKey(s), temperature=1.0,
                   top_p=0.7)[0])
        for s in range(200)
    }
    assert seen == {0, 1}
    assert int(sample(logits, rng=jax.random.PRNGKey(0), temperature=1.0,
                      top_p=1e-6)[0]) == 0


def test_sampler_greedy_ignores_truncation_knobs():
    """temperature=0 is exact greedy whatever top_k/top_p say — the engine
    plumbing must not perturb deterministic decoding."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    want = jnp.argmax(logits, axis=-1)
    got = sample(logits, rng=jax.random.PRNGKey(0), temperature=0.0,
                 top_k=3, top_p=0.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_sampling_knobs_greedy_equivalence():
    """ServeEngine(temperature=0, top_k=..., top_p=...) generates exactly
    the plain greedy engine's tokens (satellite: sampler plumbing)."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    outs = []
    for kw in ({}, {"temperature": 0.0, "top_k": 2, "top_p": 0.5}):
        eng = ServeEngine(cfg, params, max_slots=1, max_len=64, **kw)
        eng.add_request([5, 6, 7], max_new_tokens=6)
        outs.append(eng.run_to_completion()[0].generated)
    assert outs[0] == outs[1]


def test_engine_rejects_prompt_longer_than_max_len():
    """Regression: a prompt longer than max_len used to crash inside
    _admit with a numpy shape error (`toks[0, :n] = prompt` against the
    clamped bucket); it must fail cleanly at submission."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(17)), max_new_tokens=2)
    with pytest.raises(ValueError, match="at least one token"):
        eng.add_request([], max_new_tokens=2)
    # boundary: exactly max_len still admits and decodes
    eng.add_request(list(range(1, 17)), max_new_tokens=2)
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].generated) == 2


def test_fused_k_cache_layout_and_accuracy():
    """Beyond-paper fused-K̂ decode cache: bytes shrink by 1/G* on K and the
    approximate scores track the exact ones."""
    import dataclasses

    cfg = get_config("qwen2.5-32b", reduced=True)
    cfg = cfg.replace(
        attention=dataclasses.replace(
            cfg.attention, impl="reference", distr_decode=True
        )
    )
    struct = kv_cache.cache_struct(cfg, 2, 32)
    assert "k_fused" in struct
    g = cfg.attention.distr.group_size
    assert struct["k_fused"].shape[-1] == struct["k"].shape[-1] // g

    perms = kv_cache.static_perms(cfg, n_layers=1)[0]  # (Hkv, dh)
    k = jax.random.normal(jax.random.PRNGKey(3), (2, cfg.n_kv_heads, 8, cfg.head_dim_))
    q = jax.random.normal(
        jax.random.PRNGKey(4), (2, cfg.n_heads, 1, cfg.head_dim_)
    )
    k_f = grouping.fuse_columns(k.astype(jnp.float32), perms[None], g)
    q_s = kv_cache.sample_q(q, perms, g, cfg.n_heads // cfg.n_kv_heads)
    s_approx = jnp.einsum("bhnd,bhmd->bhnm", q_s,
                          jnp.repeat(k_f, cfg.n_heads // cfg.n_kv_heads, 1))
    s_exact = jnp.einsum("bhnd,bhmd->bhnm", q,
                         jnp.repeat(k, cfg.n_heads // cfg.n_kv_heads, 1))
    # random static perms: unbiased estimate, bounded deviation on gaussian
    err = float(jnp.abs(s_approx - s_exact).mean()) / float(jnp.abs(s_exact).mean())
    assert err < 1.5


def test_engine_sliding_window_past_max_len():
    """Ring-cache engines keep decoding past max_len: the ring write evicts
    the oldest token, the kernels attend over the live window
    min(length, max_len), and a request can generate more tokens than the
    cache holds (ROADMAP: sliding-window eviction)."""
    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 16
    eng = ServeEngine(cfg, params, max_slots=1, max_len=max_len)
    eng.add_request([3, 1, 4, 1, 5], max_new_tokens=20)
    wrapped = False
    for _ in range(64):
        eng.step()
        if eng.active:
            total = int(np.asarray(eng.cache["length"])[0])
            wrapped = wrapped or total > max_len
        if not eng.active and not eng.pending:
            break
    done = eng.finished
    assert len(done) == 1 and len(done[0].generated) == 20
    assert wrapped, "generation never crossed the cache capacity"
    assert all(0 <= t < cfg.vocab for t in done[0].generated)


def test_sliding_window_decode_matches_manual_window():
    """Past wrap, a decode step attends over exactly the last S tokens'
    cached K/V.  With a single layer the cached K/V of token i depend only
    on its embedding + position (no attention feeds the projections), so
    the wrapped ring must equal a manually-assembled window cache."""
    cfg = get_config("minicpm-2b", reduced=True)
    cfg = cfg.replace(n_layers=1,
                      attention=cfg.attention.with_impl("reference"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    S, BIG = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 14), 0, cfg.vocab)
    decode = make_decode_step(cfg)

    # Run A: ring of capacity 8 — prefill 4 tokens, decode 4..12 (wraps at
    # position 8), then the step under test decodes token 13.
    _, ring = make_prefill(cfg, S)(params, toks[:, :4])
    ring["length"] = jnp.asarray([4], jnp.int32)
    for i in range(4, 13):
        _, ring = decode(params, toks[:, i : i + 1], ring,
                         jnp.asarray([i], jnp.int32))
    got, _ = decode(params, toks[:, 13:14], ring, jnp.asarray([13], jnp.int32))

    # Run B: unbounded cache of 16 over the same stream, then copy the last
    # S tokens (5..12) into their ring slots (p mod S) by hand.
    _, big = make_prefill(cfg, BIG)(params, toks[:, :4])
    big["length"] = jnp.asarray([4], jnp.int32)
    for i in range(4, 13):
        _, big = decode(params, toks[:, i : i + 1], big,
                        jnp.asarray([i], jnp.int32))
    manual = {key: jnp.zeros_like(val) for key, val in ring.items()}
    for p in range(5, 13):
        for key in ("k", "v"):
            manual[key] = manual[key].at[:, :, :, p % S, :].set(
                big[key][:, :, :, p, :]
            )
    manual["length"] = jnp.asarray([13], jnp.int32)
    want, _ = decode(params, toks[:, 13:14], manual,
                     jnp.asarray([13], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(got[:, 0]), np.asarray(want[:, 0]), rtol=2e-3, atol=2e-3
    )
