"""Minimal deterministic stand-in for ``hypothesis`` (see conftest.py).

The container does not ship hypothesis and nothing may be pip-installed, so
when the real library is absent this module is registered under the
``hypothesis`` / ``hypothesis.strategies`` names.  It implements exactly the
subset the test-suite uses — ``@given`` + ``@settings`` with ``integers`` /
``sampled_from`` / ``.map`` strategies — by drawing ``max_examples``
pseudo-random examples from a fixed-seed PRNG, so runs stay reproducible.
No shrinking, no example database: a failing example fails the test directly
with its drawn arguments in the traceback.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, f):
        return _Strategy(lambda rnd: f(self._draw(rnd)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elements))


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(f):
        f._max_examples = max_examples
        return f

    return deco


def given(*strategies):
    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 10)
            rnd = random.Random(0xD157A)
            for _ in range(n):
                drawn = tuple(s._draw(rnd) for s in strategies)
                f(*args, *drawn, **kwargs)

        # Hide the drawn parameters from pytest's fixture resolution, as the
        # real hypothesis does: strategies fill the rightmost params, any
        # leading params stay visible (fixtures).
        params = list(inspect.signature(f).parameters.values())
        wrapper.__signature__ = inspect.Signature(
            params[: len(params) - len(strategies)]
        )
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from
