"""Sampling/fusion unit + hypothesis property tests (paper §3.1-3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import grouping


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


def test_fuse_columns_matches_manual():
    x = _rand(0, (4, 8))
    perm = jnp.asarray([3, 1, 0, 2, 7, 5, 6, 4], jnp.int32)[None]
    out = grouping.fuse_columns(x[None], perm, 2)[0]
    manual = np.stack(
        [
            np.asarray(x)[:, [3, 1]].sum(1),
            np.asarray(x)[:, [0, 2]].sum(1),
            np.asarray(x)[:, [7, 5]].sum(1),
            np.asarray(x)[:, [6, 4]].sum(1),
        ],
        axis=1,
    )
    np.testing.assert_allclose(np.asarray(out), manual, rtol=1e-6)


def test_sample_columns_picks_first_of_group():
    x = _rand(1, (4, 8))
    perm = jnp.arange(8, dtype=jnp.int32)[None]
    out = grouping.sample_columns(x[None], perm, 4)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x)[:, [0, 4]])


def test_group_size_one_is_pure_permutation():
    x = _rand(2, (5, 16))
    perm = jax.random.permutation(jax.random.PRNGKey(9), 16)[None].astype(jnp.int32)
    fused = grouping.fuse_columns(x[None], perm, 1)[0]
    sampled = grouping.sample_columns(x[None], perm, 1)[0]
    np.testing.assert_allclose(np.asarray(fused), np.asarray(x)[:, np.asarray(perm[0])])
    np.testing.assert_allclose(np.asarray(sampled), np.asarray(fused))


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 4).map(lambda k: 2**k),  # group size
    st.integers(0, 100),
)
def test_fusion_preserves_total_sum(g, seed):
    """Σ_j k̂_j == Σ_i k_i — fusion is a partition of the d columns."""
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 6, d))
    perm = jax.random.permutation(
        jax.random.PRNGKey(seed + 1), d
    )[None, None].astype(jnp.int32)
    perm = jnp.broadcast_to(perm, (3, 1, d)).reshape(3, d)[:, None, :]
    fused = grouping.fuse_columns(x, jnp.broadcast_to(perm[:, 0], (3, d)), g)
    np.testing.assert_allclose(
        np.asarray(fused.sum(-1)), np.asarray(x.sum(-1)), rtol=2e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 50))
def test_mean_estimator_matches_fuse_over_g(seed):
    g, d = 4, 32
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 5, d))
    perm = jnp.broadcast_to(
        jax.random.permutation(jax.random.PRNGKey(seed + 1), d).astype(jnp.int32),
        (2, d),
    )
    mean = grouping.mean_columns(x, perm, g)
    fuse = grouping.fuse_columns(x, perm, g)
    np.testing.assert_allclose(np.asarray(mean) * g, np.asarray(fuse), rtol=1e-6)
