"""Int8 error-feedback gradient compression contracts."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.train.compression import compress, decompress, ef_step, init_residuals


def test_compress_roundtrip_bounds():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3.0
    q, scale = compress(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(decompress(q, scale) - g)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_contract():
    """(deq + new_residual) == (g + old_residual): nothing is lost."""
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    r = jax.random.normal(jax.random.PRNGKey(2), (64,)) * 0.1
    (q, scale), new_r = ef_step(g, r)
    np.testing.assert_allclose(
        np.asarray(decompress(q, scale) + new_r), np.asarray(g + r), rtol=1e-5,
        atol=1e-6,
    )


def test_ef_sgd_converges_like_exact():
    """EF-compressed SGD tracks exact SGD on a quadratic (the classical
    error-feedback guarantee)."""
    w_exact = jnp.asarray([4.0, -2.0, 1.0])
    w_ef = w_exact
    residual = jnp.zeros_like(w_exact)
    lr = 0.05
    for _ in range(300):
        w_exact = w_exact - lr * 2 * w_exact
        g = 2 * w_ef
        (q, scale), residual = ef_step(g, residual)
        w_ef = w_ef - lr * decompress(q, scale)
    assert float(jnp.abs(w_ef).max()) < 5e-2
    assert float(jnp.abs(w_exact).max()) < 5e-2


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_compress_is_symmetric_property(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    q_pos, s_pos = compress(g)
    q_neg, s_neg = compress(-g)
    np.testing.assert_allclose(np.asarray(q_pos), -np.asarray(q_neg))
    assert float(s_pos) == float(s_neg)


def test_init_residuals_structure():
    params = {"a": jnp.ones((3,), jnp.bfloat16), "b": {"c": jnp.ones((2, 2))}}
    res = init_residuals(params)
    assert res["a"].dtype == jnp.float32
    assert res["b"]["c"].shape == (2, 2)
