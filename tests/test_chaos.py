"""Chaos suite: overload-aware serving under injected faults.

Every test asserts the robustness contract (DESIGN.md §Robustness): each
request reaches exactly one terminal lifecycle status
(done | rejected | expired | cancelled | failed), pool blocks leak nothing
(free count returns to initial), and faults quarantine only the offending
request — concurrent unaffected requests produce bit-identical outputs
(greedy sampling + per-row decode independence make this deterministic).

Fast tests drive the scheduler through a fake engine (policy only, no
model); slow tests drive the real engines and the 8-device ring.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serve import lifecycle, paged
from repro.serve.degrade import DegradationController, DegradeConfig
from repro.serve.faults import (
    NULL_INJECTOR, FaultInjector, FaultSpec, InjectedFault,
)
from repro.serve.lifecycle import IncompleteRun
from repro.serve.scheduler import Scheduler, SchedulerConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Fault-injection plumbing (serve.faults)
# ---------------------------------------------------------------------------


def test_fault_spec_counted_window():
    """A spec fires exactly on hits [after, after + times); times=-1 fires
    forever — deterministic across runs by construction."""
    inj = FaultInjector([FaultSpec("stuck_step", after=2, times=3)])
    fired = [inj.fires("stuck_step") is not None for _ in range(8)]
    assert fired == [False, False, True, True, True, False, False, False]
    persistent = FaultInjector([FaultSpec("nan_logits", times=-1)])
    assert all(persistent.fires("nan_logits") is not None for _ in range(20))


def test_fault_spec_rejects_unknown_point():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("disk_on_fire")


def test_injector_uid_filter_and_dead_shards():
    inj = FaultInjector([
        FaultSpec("nan_logits", uid=7, times=-1),
        FaultSpec("dead_ring_shard", shards=(1, 3)),
        FaultSpec("dead_ring_shard", shards=(3, 5)),
    ])
    assert inj.fires("nan_logits", uid=3) is None
    assert inj.fires("nan_logits", uid=7) is not None
    assert inj.dead_shards() == frozenset({1, 3, 5})
    assert inj.raise_if("pool_exhausted", uid=7) is None  # no spec → no-op
    with pytest.raises(InjectedFault) as ei:
        FaultInjector([FaultSpec("stuck_step")]).raise_if("stuck_step", 4)
    assert ei.value.point == "stuck_step" and ei.value.uid == 4


def test_fault_spec_counts_hits_per_matching_uid():
    """A uid-filtered spec counts hits only on consultations that match:
    interleaved other-uid traffic must not advance its window."""
    inj = FaultInjector([FaultSpec("nan_logits", uid=1, after=2, times=1)])
    fired = []
    for _ in range(4):  # interleave uid 0 and uid 1 consultations
        inj.fires("nan_logits", uid=0)  # never matches, never counts
        fired.append(inj.fires("nan_logits", uid=1) is not None)
    # uid 1's own hits are 0,1,2,3 → fires exactly on its third hit.
    assert fired == [False, False, True, False]
    # An unrestricted spec, by contrast, counts every consultation.
    inj = FaultInjector([FaultSpec("nan_logits", after=2, times=1)])
    seen = [inj.fires("nan_logits", uid=u) is not None
            for u in (0, 1, 0, 1)]
    assert seen == [False, False, True, False]


def test_multiple_specs_on_one_point():
    """Several specs may watch one point: every matching spec counts the
    hit, the FIRST whose window covers it is returned — so staggered
    windows hand off deterministically and overlaps don't double-fire."""
    a = FaultSpec("stuck_step", after=0, times=2)
    b = FaultSpec("stuck_step", after=1, times=3)
    inj = FaultInjector([a, b])
    winners = []
    for _ in range(5):
        s = inj.fires("stuck_step")
        winners.append(None if s is None else ("a" if s is a else "b"))
    # hit 0: only a's window; hit 1: both → a (listed first); hits 2-3:
    # a exhausted → b; hit 4: both exhausted.
    assert winners == ["a", "a", "b", "b", None]
    # Exhaustion is permanent: further consultations stay quiet.
    assert inj.fires("stuck_step") is None
    # uid-filtered + unfiltered specs on one point: the filtered spec
    # only wins consultations it matches.
    u = FaultSpec("pool_exhausted", uid=5, times=-1)
    g = FaultSpec("pool_exhausted", after=1, times=-1)
    inj = FaultInjector([u, g])
    assert inj.fires("pool_exhausted", uid=3) is None  # g's hit 0 (after=1)
    assert inj.fires("pool_exhausted", uid=5) is u
    assert inj.fires("pool_exhausted", uid=3) is g


def test_replica_crash_point_in_catalog():
    """The cluster tier's fault point rides the same counted-trigger
    plumbing: uid carries the REPLICA id (serve.cluster consults it once
    per tick per replica)."""
    inj = FaultInjector([FaultSpec("replica_crash", uid=1, after=2)])
    assert inj.fires("replica_crash", uid=0) is None
    fired = [inj.fires("replica_crash", uid=1) is not None
             for _ in range(4)]
    assert fired == [False, False, True, False]


# ---------------------------------------------------------------------------
# Degradation controller (serve.degrade)
# ---------------------------------------------------------------------------


def test_degrade_config_validation():
    with pytest.raises(ValueError):
        DegradeConfig(group_sizes=())
    with pytest.raises(ValueError):
        DegradeConfig(group_sizes=(1, 4))
    with pytest.raises(ValueError):
        DegradeConfig(high_watermark=1, low_watermark=2)
    assert DegradeConfig(group_sizes=(2, 4, 8)).group_for(0) == 1
    assert DegradeConfig(group_sizes=(2, 4, 8)).group_for(3) == 8


def test_degrade_controller_hysteresis():
    """One level step per up_after (resp. down_after) CONSECUTIVE pressure
    (drain) ticks; a single calm tick resets the streak — no flapping on a
    bursty queue."""
    c = DegradationController(DegradeConfig(
        group_sizes=(2, 4), high_watermark=4, low_watermark=1,
        up_after=2, down_after=3,
    ))
    assert c.observe(10) == 0  # 1 hot tick — not yet
    assert c.observe(10) == 1  # 2 consecutive → up
    assert c.observe(10) == 1
    assert c.observe(2) == 1  # mid-band: neither hot nor cool
    assert c.observe(10) == 1  # streak was reset by the calm tick
    assert c.observe(10) == 2  # up again (max level)
    assert c.group_size == 4
    for _ in range(2):
        assert c.observe(0) == 2
    assert c.observe(0) == 1  # 3 consecutive cool → down
    assert c.observe(10) == 1  # pressure returns: drain streak resets


def test_degrade_return_bound_ticks():
    """Reversibility guarantee: from the deepest level, sustained drain
    returns to exact within down_after × max_level ticks."""
    cfg = DegradeConfig(group_sizes=(2, 4, 8), up_after=1, down_after=2)
    c = DegradationController(cfg)
    for _ in range(10):
        c.observe(100)
    assert c.level == cfg.max_level
    for t in range(cfg.return_bound_ticks()):
        if c.observe(0) == 0:
            break
    assert c.level == 0, (
        f"controller stuck at level {c.level} after "
        f"{cfg.return_bound_ticks()} drain ticks"
    )


# ---------------------------------------------------------------------------
# Scheduler chaos through a fake engine (policy only, no model)
# ---------------------------------------------------------------------------


class FakeReq:
    def __init__(self, uid, n_prompt=8, max_new=4, deadline_ttft=None,
                 deadline_e2e=None):
        self.uid = uid
        self.prompt = list(range(1, n_prompt + 1))
        self.max_new_tokens = max_new
        self.eos_id = None
        self.generated = []
        self.done = False
        self.status = lifecycle.QUEUED
        self.deadline_ttft = deadline_ttft
        self.deadline_e2e = deadline_e2e
        self.degrade_group = 1


class FakeEngine:
    """The scheduler's primitive surface over a bare BlockPool, consulting
    a FaultInjector at the same points the real paged engine does."""

    def __init__(self, num_blocks=16, block_size=8, max_batch=4,
                 capacity=64, faults=NULL_INJECTOR):
        self.pool = paged.BlockPool(num_blocks, block_size)
        self.bs = block_size
        self.max_batch = max_batch
        self.capacity_tokens = capacity
        self.faults = faults
        self.ids: dict[int, list[int]] = {}
        self.evicted_uids: set[int] = set()
        self.scheduler = None

    def free_lane(self):
        return next(l for l in range(self.max_batch)
                    if l not in self.scheduler.running)

    def alloc(self, entry, n_tokens):
        if self.faults.fires("pool_exhausted", entry.uid) is not None:
            return False
        need = -(-n_tokens // self.bs) - len(self.ids.get(entry.uid, []))
        if need <= 0:
            return True
        try:
            got = self.pool.alloc(need)
        except paged.PoolExhausted:
            return False
        self.ids.setdefault(entry.uid, []).extend(got)
        return True

    def can_admit(self, entry):
        need = -(-min(len(entry.req.prompt) + 1, self.capacity_tokens)
                 // self.bs)
        return self.pool.num_free >= need

    def holds_blocks(self, entry):
        return bool(self.ids.get(entry.uid))

    def evict(self, entry):
        for b in self.ids.pop(entry.uid):
            self.pool.free(b)
        self.evicted_uids.add(entry.uid)

    def restore(self, entry):
        self.faults.raise_if("restore_failure", entry.uid)
        blocks = -(-max(entry.length, 1) // self.bs)
        try:
            self.ids[entry.uid] = self.pool.alloc(blocks)
        except paged.PoolExhausted:
            return False
        return True

    def release(self, entry):
        for b in self.ids.pop(entry.uid, []):
            self.pool.free(b)

    def sample_one(self, logits):
        return 1

    def prefill_chunk_run(self, entry, chunk):
        self.faults.raise_if("stuck_step", entry.uid)
        if self.faults.fires("nan_logits", entry.uid) is not None:
            return np.nan
        return entry.uid  # "logits" scalar

    def decode_tick(self, running):
        for e in running.values():
            self.faults.raise_if("stuck_step", e.uid)
        ok = np.ones((self.max_batch,), bool)
        for lane, e in running.items():
            if self.faults.fires("nan_logits", e.uid) is not None:
                ok[lane] = False
        return np.full((self.max_batch,), 1, np.int64), ok


class DegradedFakeEngine(FakeEngine):
    def __init__(self, **kw):
        super().__init__(**kw)
        self.degraded_prompts: list[tuple[int, int]] = []

    def prefill_full_run(self, entry, group):
        self.faults.raise_if("stuck_step", entry.uid)
        self.degraded_prompts.append((entry.uid, group))
        return entry.uid


class MeshFakeEngine(FakeEngine):
    """FakeEngine + the mesh-admission surface (ISSUE 9): whole-prompt
    one-tick prefill, consulting the mesh_prefill fault point BEFORE any
    pool mutation — exactly like PagedServeEngine.prefill_mesh_run."""

    def __init__(self, threshold=8, **kw):
        super().__init__(**kw)
        self.threshold = threshold
        self.mesh_prompts: list[int] = []

    def mesh_prefill_ready(self, n):
        return n > self.threshold

    def prefill_mesh_run(self, entry):
        self.faults.raise_if("stuck_step", entry.uid)
        self.faults.raise_if("mesh_prefill", entry.uid)
        self.mesh_prompts.append(entry.uid)
        if self.faults.fires("nan_logits", entry.uid) is not None:
            return np.nan
        return entry.uid


class TickClock:
    """Injectable tick-domain clock: deadlines and TTFT in ticks."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def drive(sched, eng, clock=None, max_ticks=500):
    for _ in range(max_ticks):
        sched.tick(eng)
        if clock is not None:
            clock.t += 1
        if not sched.has_work():
            return
    raise AssertionError("scheduler did not drain within max_ticks")


def assert_all_terminal_and_clean(sched, eng, reqs):
    assert not sched.has_work()
    for r in reqs:
        assert lifecycle.is_terminal(r.status), (r.uid, r.status)
    assert eng.pool.num_free == eng.pool.num_blocks - 1, "blocks leaked"
    assert not eng.ids, "fake engine still maps uid → blocks"


def _sched(eng, *, max_batch=4, chunk=8, clock=None, **cfg_kw):
    s = Scheduler(
        SchedulerConfig(max_batch=max_batch, prefill_chunk=chunk, **cfg_kw),
        clock=clock or (lambda: 0.0),
        faults=eng.faults,
    )
    eng.scheduler = s
    return s


def test_shed_rejects_newest_when_queue_full():
    """Bounded waiting queue: the newest submissions are rejected at the
    gate with an immediate terminal status; accepted ones complete."""
    eng = FakeEngine()
    sched = _sched(eng, max_waiting=2)
    reqs = [FakeReq(uid) for uid in range(5)]
    entries = [sched.submit(r) for r in reqs]
    assert entries[0] is not None and entries[1] is not None
    assert entries[2] is None and entries[3] is None and entries[4] is None
    for shed in reqs[2:]:
        assert shed.status == lifecycle.REJECTED
    assert sched.counters["shed"] == 3
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert [r.status for r in reqs[:2]] == [lifecycle.DONE] * 2
    rows = {m["uid"]: m for m in sched.metrics()}
    assert rows[4]["status"] == lifecycle.REJECTED
    assert rows[0]["status"] == lifecycle.DONE


def test_cancel_frees_blocks_immediately():
    """cancel(uid) terminates a request wherever it is — waiting,
    mid-prefill, or running — and its blocks free in the call itself."""
    eng = FakeEngine()
    sched = _sched(eng, chunk=4)
    reqs = [FakeReq(uid, n_prompt=12, max_new=8) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    assert not sched.cancel(99, eng)  # unknown uid
    assert sched.cancel(2, eng)  # still waiting
    assert reqs[2].status == lifecycle.CANCELLED
    sched.tick(eng)  # uid 0 mid-prefill (chunk 4 < prompt 12) or running
    held_before = len(eng.ids.get(0, []))
    assert held_before > 0
    assert sched.cancel(0, eng)
    assert reqs[0].status == lifecycle.CANCELLED
    assert 0 not in eng.ids, "cancel left blocks allocated"
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[1].status == lifecycle.DONE
    assert sched.counters["cancelled"] == 2


def test_cancel_running_entry_mid_decode():
    eng = FakeEngine()
    sched = _sched(eng)
    reqs = [FakeReq(uid, max_new=32) for uid in range(2)]
    for r in reqs:
        sched.submit(r)
    for _ in range(3):
        sched.tick(eng)
    assert any(e.uid == 1 for e in sched.running.values())
    assert sched.cancel(1, eng)
    assert reqs[1].status == lifecycle.CANCELLED
    assert all(e.uid != 1 for e in sched.running.values())
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)


def test_ttft_deadline_expires_waiting_requests():
    """Tick-domain deadlines: a request whose TTFT deadline lapses while
    queued is expired at the next tick — running requests are untouched."""
    eng = FakeEngine(max_batch=1)
    clock = TickClock()
    sched = _sched(eng, max_batch=1, clock=clock)
    fast = FakeReq(0, max_new=16)
    tight = FakeReq(1, deadline_ttft=2)  # behind fast on 1 lane: starves
    loose = FakeReq(2, deadline_ttft=1000)
    for r in (fast, tight, loose):
        sched.submit(r)
    drive(sched, eng, clock=clock)
    assert_all_terminal_and_clean(sched, eng, [fast, tight, loose])
    assert fast.status == lifecycle.DONE
    assert tight.status == lifecycle.EXPIRED
    assert loose.status == lifecycle.DONE
    assert sched.counters["expired"] == 1


def test_e2e_deadline_expires_running_request():
    eng = FakeEngine()
    clock = TickClock()
    sched = _sched(eng, clock=clock)
    marathon = FakeReq(0, max_new=100, deadline_e2e=5)
    sprint = FakeReq(1, max_new=2)
    for r in (marathon, sprint):
        sched.submit(r)
    drive(sched, eng, clock=clock)
    assert_all_terminal_and_clean(sched, eng, [marathon, sprint])
    assert marathon.status == lifecycle.EXPIRED
    assert 0 < len(marathon.generated) < 100, "expiry never interrupted it"
    assert sprint.status == lifecycle.DONE


def test_slow_step_fault_ages_deadlines_without_sleeping():
    """The slow_step fault advances the scheduler's clock offset: deadline
    expiry is exercised with zero wall-clock sleep."""
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("slow_step", after=1, delay=50.0)]
    ))
    clock = TickClock()
    sched = _sched(eng, clock=clock)
    doomed = FakeReq(0, max_new=100, deadline_e2e=20)
    safe = FakeReq(1, max_new=3, deadline_e2e=10_000)
    for r in (doomed, safe):
        sched.submit(r)
    drive(sched, eng, clock=clock)
    assert_all_terminal_and_clean(sched, eng, [doomed, safe])
    assert doomed.status == lifecycle.EXPIRED  # 50 » 20, after one tick
    assert safe.status == lifecycle.DONE


def test_stuck_prefill_transient_fault_recovers():
    """A fault shorter than the retry budget costs ticks, not the request."""
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("stuck_step", uid=1, times=2)]  # budget is 2 retries
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert all(r.status == lifecycle.DONE for r in reqs)
    assert sched.counters["step_retries"] == 2


def test_stuck_prefill_persistent_fault_fails_culprit_only():
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("stuck_step", uid=1, times=-1)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[1].status == lifecycle.FAILED
    assert reqs[0].status == lifecycle.DONE
    assert reqs[2].status == lifecycle.DONE
    assert sched.counters["failed_fault"] == 1


def test_stuck_decode_fails_culprit_only():
    """A decode-tick fault surfaces after the culprit reaches a lane; the
    other lanes lose the faulted ticks but finish untouched."""
    eng = FakeEngine(faults=FaultInjector(
        # after=1: first decode for uid 1 succeeds, then 3 raises exhaust
        # the 2-retry budget.
        [FaultSpec("stuck_step", uid=1, after=2, times=-1)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid, max_new=6) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[1].status == lifecycle.FAILED
    assert reqs[0].status == lifecycle.DONE
    assert len(reqs[0].generated) == 6
    assert reqs[2].status == lifecycle.DONE


def test_nan_prefill_quarantined_before_lane():
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("nan_logits", uid=0, times=-1)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid) for uid in range(2)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[0].status == lifecycle.FAILED
    assert reqs[0].generated == [], "a poisoned prompt must not sample"
    assert reqs[1].status == lifecycle.DONE
    assert sched.counters["failed_numeric"] == 1


def test_nan_decode_quarantines_lane_only():
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("nan_logits", uid=1, after=2, times=1)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid, max_new=6) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[1].status == lifecycle.FAILED
    assert reqs[0].status == lifecycle.DONE
    assert reqs[2].status == lifecycle.DONE
    assert len(reqs[0].generated) == 6 and len(reqs[2].generated) == 6


def test_restore_fault_backoff_then_fail():
    """A faulting restore retries with exponential backoff and bounded
    budget; a pool-capacity wait (False return) costs no retries."""
    eng = FakeEngine(num_blocks=9, block_size=8, faults=FaultInjector(
        [FaultSpec("restore_failure", uid=3, times=-1)]
    ))
    sched = _sched(eng, max_batch=4, restore_max_retries=3,
                   restore_backoff_ticks=1)
    # Tight pool (as the preempt-resume test in test_paged.py): uid 3 (the
    # newest) is the LIFO victim; its restore then faults forever.
    reqs = [FakeReq(uid, n_prompt=10, max_new=16) for uid in range(4)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert 3 in eng.evicted_uids, "pressure never preempted uid 3"
    assert reqs[3].status == lifecycle.FAILED
    assert sched.counters["restore_retries"] == 4  # 3 retries + final
    for r in reqs[:3]:
        assert r.status == lifecycle.DONE
        assert len(r.generated) == 16


def test_restore_transient_fault_recovers():
    eng = FakeEngine(num_blocks=9, block_size=8, faults=FaultInjector(
        [FaultSpec("restore_failure", uid=3, times=2)]
    ))
    sched = _sched(eng, max_batch=4)
    reqs = [FakeReq(uid, n_prompt=10, max_new=16) for uid in range(4)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert all(r.status == lifecycle.DONE for r in reqs)
    assert all(len(r.generated) == 16 for r in reqs)
    assert sched.counters["restore_retries"] == 2


def test_watchdog_fails_head_on_global_stall():
    """A persistently failing allocator wedges the FCFS head; the global
    watchdog fails it after watchdog_ticks of zero progress, unwedging the
    queue.  Per-entry timers would have shot the healthy waiters too."""
    eng = FakeEngine(faults=FaultInjector(
        [FaultSpec("pool_exhausted", uid=0, times=-1)]
    ))
    sched = _sched(eng, watchdog_ticks=6)
    reqs = [FakeReq(uid) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[0].status == lifecycle.FAILED
    assert sched.counters["watchdog_fails"] == 1
    assert reqs[1].status == lifecycle.DONE
    assert reqs[2].status == lifecycle.DONE


@pytest.mark.parametrize("point,kw", [
    ("pool_exhausted", dict(uid=1, times=-1)),
    ("nan_logits", dict(uid=1, times=-1)),
    ("stuck_step", dict(uid=1, times=-1)),
    ("restore_failure", dict(uid=1, times=-1)),
    ("slow_step", dict(delay=1.0, times=3)),
])
def test_every_fault_reaches_terminal_status(point, kw):
    """The blanket contract: under each injectable fault point, every
    request reaches a terminal status and the pool drains clean."""
    eng = FakeEngine(faults=FaultInjector([FaultSpec(point, **kw)]))
    sched = _sched(eng, watchdog_ticks=6)
    reqs = [FakeReq(uid) for uid in range(4)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)


def test_mesh_prefill_one_tick_admission():
    """A prompt longer than the mesh threshold admits whole in one tick
    (counted as a mesh_prefill); shorter prompts still take the chunked
    path — and both drain clean."""
    eng = MeshFakeEngine(threshold=8)
    sched = _sched(eng, chunk=4)
    long_reqs = [FakeReq(uid, n_prompt=16, max_new=3) for uid in (0, 1)]
    short = FakeReq(2, n_prompt=6, max_new=3)
    for r in long_reqs + [short]:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, long_reqs + [short])
    assert all(r.status == lifecycle.DONE for r in long_reqs + [short])
    assert eng.mesh_prompts == [0, 1]
    assert 2 not in eng.mesh_prompts, "short prompt took the mesh path"
    assert sched.counters["mesh_prefills"] == 2


def test_mesh_prefill_transient_fault_recovers():
    """A mesh_prefill fault within the retry budget costs ticks, not the
    request: it raises BEFORE pool mutation, so the retry re-runs against
    clean blocks."""
    eng = MeshFakeEngine(threshold=8, faults=FaultInjector(
        [FaultSpec("mesh_prefill", uid=1, times=2)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid, n_prompt=16, max_new=3) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert all(r.status == lifecycle.DONE for r in reqs)
    assert sched.counters["step_retries"] == 2
    assert sched.counters["mesh_prefills"] == 3


def test_mesh_prefill_persistent_fault_fails_culprit_only():
    eng = MeshFakeEngine(threshold=8, faults=FaultInjector(
        [FaultSpec("mesh_prefill", uid=1, times=-1)]
    ))
    sched = _sched(eng)
    reqs = [FakeReq(uid, n_prompt=16, max_new=3) for uid in range(3)]
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)
    assert reqs[1].status == lifecycle.FAILED
    assert reqs[0].status == lifecycle.DONE
    assert reqs[2].status == lifecycle.DONE
    assert sched.counters["failed_fault"] == 1
    assert 1 not in eng.mesh_prompts, "faulted prefill mutated the pool"


@pytest.mark.parametrize("point,kw", [
    ("mesh_prefill", dict(uid=1, times=-1)),
    ("nan_logits", dict(uid=1, times=-1)),
    ("stuck_step", dict(uid=1, times=-1)),
    ("pool_exhausted", dict(uid=1, times=-1)),
])
def test_every_fault_reaches_terminal_under_mesh_admission(point, kw):
    """The blanket terminal-status contract holds when admission goes
    through the mesh path too."""
    eng = MeshFakeEngine(threshold=4, faults=FaultInjector(
        [FaultSpec(point, **kw)]
    ))
    sched = _sched(eng, watchdog_ticks=6)
    reqs = [FakeReq(uid) for uid in range(4)]  # 8 > 4: all mesh-admitted
    for r in reqs:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, reqs)


def test_scheduler_degrades_under_pressure_and_recovers():
    """Tentpole integration at the policy layer: a flooded queue pushes the
    controller up, new prompts prefill degraded (whole-prompt, recorded on
    the request), and once pressure drains the dial returns to exact within
    the documented bound."""
    eng = DegradedFakeEngine(num_blocks=64, block_size=8, max_batch=2,
                             capacity=64)
    dcfg = DegradeConfig(group_sizes=(2, 4), high_watermark=3,
                         low_watermark=1, up_after=2, down_after=2)
    sched = Scheduler(
        SchedulerConfig(max_batch=2, prefill_chunk=8),
        clock=lambda: 0.0, degrade=dcfg,
    )
    eng.scheduler = sched
    flood = [FakeReq(uid, n_prompt=16, max_new=2) for uid in range(12)]
    for r in flood:
        sched.submit(r)
    drive(sched, eng)
    assert_all_terminal_and_clean(sched, eng, flood)
    assert all(r.status == lifecycle.DONE for r in flood)
    assert eng.degraded_prompts, "overload never triggered degraded prefill"
    degraded_uids = {uid for uid, _ in eng.degraded_prompts}
    for r in flood:
        if r.uid in degraded_uids:
            assert r.degrade_group > 1
        else:
            assert r.degrade_group == 1
    assert sched.counters["degraded_prefills"] == len(eng.degraded_prompts)
    # Reversibility: drained queue → exact within the bound.
    assert sched.degrade.level > 0 or sched.degrade.transitions, \
        "controller never moved"
    for _ in range(dcfg.return_bound_ticks() + dcfg.down_after):
        sched.tick(eng)
    assert sched.degrade.level == 0, "dial did not return to exact"
    late = FakeReq(100, n_prompt=16, max_new=2)
    sched.submit(late)
    drive(sched, eng)
    assert late.status == lifecycle.DONE
    assert late.degrade_group == 1, "post-drain prompt should be exact"


def test_metrics_rows_carry_status_and_degrade_group():
    eng = FakeEngine()
    sched = _sched(eng)
    r = FakeReq(0)
    sched.submit(r)
    drive(sched, eng)
    (row,) = sched.metrics()
    assert row["status"] == lifecycle.DONE
    assert row["degrade_group"] == 1
    assert row["n_generated"] == len(r.generated)


# ---------------------------------------------------------------------------
# Dead ring shard (distributed.ring_attention fault hook)
# ---------------------------------------------------------------------------


def test_hop_schedule_skips_dead_shards_keeps_diagonal():
    """The dead-shard predicate drops every h>0 hop sourced from a dead
    shard but never hop 0 (own resident KV): no Q row loses its softmax
    diagonal, so outputs stay finite."""
    from repro.distributed.ring_attention import (
        _RingMeta, _hop_schedule, dead_shard_fault,
    )
    from repro.tune.block_sizes import BlockSizes

    meta = _RingMeta(axis="context", size=4, causal=False, scale=1.0,
                     interpret=True, n_live=512, shard=128,
                     blocks=BlockSizes())

    def runs(idx):
        out = []
        for h in range(meta.size):
            src, run, _ = _hop_schedule(meta, idx, h)
            out.append((int(src), bool(run)))
        return out

    baseline = runs(idx=1)
    assert all(r for _, r in baseline)  # non-causal, all live: all hops run
    with dead_shard_fault({3}):
        faulted = runs(idx=1)
    assert faulted[0] == (1, True), "hop 0 (own shard) must always run"
    for src, run in faulted[1:]:
        assert run == (src != 3), (src, run)
    # context manager restores the healthy schedule
    assert runs(idx=1) == baseline
    # a dead device's own hop-0 still runs (it is resident, not rotated)
    with dead_shard_fault({3}):
        assert runs(idx=3)[0] == (3, True)


@pytest.mark.slow
def test_dead_ring_shard_degraded_but_finite_8dev():
    """8-device ring with a dead KV shard: the sweep skips the dead hops
    (hop probe), output stays finite everywhere, and rows whose causal
    window excludes the dead shard are bit-identical to the healthy run."""
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.launch.mesh import compat_make_mesh
        from repro.distributed.ring_attention import (
            dead_shard_fault, ring_flash_attention,
        )
        ring = compat_make_mesh((8,), ("context",))
        B, Hq, Hkv, N, D = 1, 2, 1, 1024, 32  # 8 shards of 128, all live
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
        healthy, hops0 = jax.jit(lambda q, k, v: ring_flash_attention(
            q, k, v, ring, causal=True, return_hops=True))(q, k, v)
        with dead_shard_fault({2}):
            degraded, hops1 = jax.jit(lambda q, k, v: ring_flash_attention(
                q, k, v, ring, causal=True, return_hops=True))(q, k, v)
        assert int(hops1) < int(hops0), (int(hops1), int(hops0))
        d = np.asarray(degraded)
        assert np.isfinite(d).all(), "dead shard produced non-finite output"
        h = np.asarray(healthy)
        # Rows at positions < 256 never attend shard 2 (causal): identical.
        np.testing.assert_array_equal(d[:, :, :256], h[:, :, :256])
        # Rows past the dead shard lost real context: they must differ.
        assert np.abs(d[:, :, 384:] - h[:, :, 384:]).max() > 0
        print("DEAD SHARD OK")
        """
        % SRC
    )
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"


# ---------------------------------------------------------------------------
# Real engines: regression satellites + chaos integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    import jax as _jax

    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("minicpm-2b", reduced=True)
    params = lm.init_params(_jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged_engine(small_lm, **kw):
    from repro.serve.engine import PagedServeEngine

    cfg, params = small_lm
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return PagedServeEngine(cfg, params, **kw)


def _slot_engine(small_lm, **kw):
    from repro.serve.engine import ServeEngine

    cfg, params = small_lm
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_len", 64)
    return ServeEngine(cfg, params, **kw)


def test_run_to_completion_raises_incomplete_run(small_lm):
    """Regression (satellite 1): max_steps exhaustion with requests still
    in flight must raise, not silently return partial results."""
    eng = _paged_engine(small_lm)
    uid = eng.add_request([1, 2, 3], max_new_tokens=30)
    with pytest.raises(IncompleteRun) as ei:
        eng.run_to_completion(max_steps=2)
    assert uid in ei.value.uids
    eng.run_to_completion()  # plenty of steps: drains fine now

    slot = _slot_engine(small_lm)
    uid2 = slot.add_request([1, 2, 3], max_new_tokens=30)
    with pytest.raises(IncompleteRun) as ei:
        slot.run_to_completion(max_steps=2)
    assert uid2 in ei.value.uids
    slot.run_to_completion()


def test_add_request_validation_parity(small_lm):
    """Satellite 2: both engines reject bad input identically through the
    shared helper — empty prompt, non-positive max_new_tokens, overlong
    prompt."""
    engines = [_paged_engine(small_lm), _slot_engine(small_lm)]
    for eng in engines:
        with pytest.raises(ValueError, match="at least one token"):
            eng.add_request([])
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.add_request([1, 2], max_new_tokens=0)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.add_request([1, 2], max_new_tokens=-3)
        with pytest.raises(ValueError, match="exceeds the engine"):
            eng.add_request(list(range(1, 200)))
    # paged engine additionally reserves one slot for the first decode
    # token: a prompt that fills capacity exactly must be rejected too.
    with pytest.raises(ValueError, match="capacity"):
        engines[0].add_request(list(range(1, 65)))


PROMPTS = [list(range(3, 11)), list(range(5, 17)), list(range(2, 8))]


def _run_paged(small_lm, faults=None, **kw):
    eng = _paged_engine(small_lm, faults=faults, **kw)
    free0 = eng.cache.pool.num_free
    uids = [eng.add_request(p, max_new_tokens=6) for p in PROMPTS]
    eng.run_to_completion(max_steps=300)
    by_uid = {r.uid: r for r in eng.finished}
    assert set(by_uid) == set(uids)
    for r in eng.finished:
        assert lifecycle.is_terminal(r.status), (r.uid, r.status)
    assert eng.cache.pool.num_free == free0, "pool blocks leaked"
    return eng, by_uid


@pytest.mark.slow
@pytest.mark.parametrize("point", ["nan_logits", "stuck_step"])
def test_real_paged_engine_fault_quarantine_bit_identical(small_lm, point):
    """Chaos acceptance on the real paged engine: fault uid 1, every
    request terminal, pool clean, and the unaffected requests' tokens are
    BIT-IDENTICAL to the fault-free run (greedy sampling + per-row decode
    independence)."""
    _, baseline = _run_paged(small_lm)
    assert all(r.status == lifecycle.DONE for r in baseline.values())
    faults = FaultInjector([FaultSpec(point, uid=1, after=1, times=-1)])
    eng, by_uid = _run_paged(small_lm, faults=faults)
    assert by_uid[1].status == lifecycle.FAILED
    for uid in (0, 2):
        assert by_uid[uid].status == lifecycle.DONE
        assert by_uid[uid].generated == baseline[uid].generated, (
            f"uid {uid} diverged under {point} fault on uid 1"
        )
    counters = eng.counters_snapshot()
    assert counters.get("failed_numeric", 0) + counters.get(
        "failed_fault", 0) == 1


@pytest.mark.slow
def test_real_paged_engine_watchdog_on_wedged_alloc(small_lm):
    """Persistent allocator failure for one uid: the watchdog fails it and
    the queue unwedges; everything terminal, pool clean."""
    faults = FaultInjector([FaultSpec("pool_exhausted", uid=1, times=-1)])
    eng, by_uid = _run_paged(small_lm, faults=faults)
    assert by_uid[1].status == lifecycle.FAILED
    assert eng.counters_snapshot()["watchdog_fails"] == 1
    assert by_uid[0].status == lifecycle.DONE
    assert by_uid[2].status == lifecycle.DONE


@pytest.mark.slow
def test_real_paged_engine_cancel_and_deadline(small_lm):
    eng = _paged_engine(small_lm, clock=TickClock())
    free0 = eng.cache.pool.num_free
    u0 = eng.add_request(PROMPTS[0], max_new_tokens=40)
    u1 = eng.add_request(PROMPTS[1], max_new_tokens=4, deadline_ttft=1000)
    eng.step()
    assert eng.cancel(u0)
    assert not eng.cancel(u0)  # already terminal
    eng.run_to_completion(max_steps=300)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[u0].status == lifecycle.CANCELLED
    assert by_uid[u1].status == lifecycle.DONE
    assert eng.cache.pool.num_free == free0


@pytest.mark.slow
def test_real_paged_engine_degradation_reversible(small_lm):
    """Degradation on the real model: overload trips the controller, some
    prompts prefill through the whole-prompt DistrAttention path (recorded
    per request), everything completes, and the dial returns to exact."""
    dcfg = DegradeConfig(group_sizes=(2,), high_watermark=2,
                         low_watermark=1, up_after=1, down_after=2)
    eng = _paged_engine(small_lm, degrade=dcfg, max_batch=2, max_len=64)
    free0 = eng.cache.pool.num_free
    uids = [eng.add_request(list(range(2, 12)), max_new_tokens=3)
            for _ in range(8)]
    eng.run_to_completion(max_steps=400)
    by_uid = {r.uid: r for r in eng.finished}
    assert set(by_uid) == set(uids)
    assert all(r.status == lifecycle.DONE for r in by_uid.values())
    assert eng.cache.pool.num_free == free0
    degraded = [r for r in by_uid.values() if r.degrade_group > 1]
    assert degraded, "overload never tripped the degraded prefill path"
    assert eng.counters_snapshot()["degraded_prefills"] == len(degraded)
    # drained: the controller must be back at exact within its bound
    for _ in range(dcfg.return_bound_ticks() + dcfg.down_after):
        eng.step()
    assert eng.scheduler.degrade.level == 0
    late = eng.add_request(list(range(2, 12)), max_new_tokens=3)
    eng.run_to_completion(max_steps=100)
    late_req = next(r for r in eng.finished if r.uid == late)
    assert late_req.status == lifecycle.DONE
    assert late_req.degrade_group == 1


@pytest.mark.slow
def test_real_slot_engine_chaos(small_lm):
    """Slot-engine robustness: nan quarantine fails only the poisoned
    request (others bit-identical to fault-free), shedding and cancel
    produce their terminal statuses."""
    base = _slot_engine(small_lm)
    for p in PROMPTS:
        base.add_request(p, max_new_tokens=5)
    base.run_to_completion(max_steps=200)
    want = {r.uid: r.generated for r in base.finished}

    faults = FaultInjector([FaultSpec("nan_logits", uid=1, times=-1)])
    eng = _slot_engine(small_lm, faults=faults, max_waiting=4)
    uids = [eng.add_request(p, max_new_tokens=5) for p in PROMPTS]
    eng.run_to_completion(max_steps=200)
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[uids[1]].status == lifecycle.FAILED
    for i in (0, 2):
        assert by_uid[uids[i]].status == lifecycle.DONE
        assert by_uid[uids[i]].generated == want[i]

    # shedding + cancel on a fresh engine with a 1-deep waiting queue
    eng2 = _slot_engine(small_lm, max_slots=1, max_waiting=1)
    a = eng2.add_request(PROMPTS[0], max_new_tokens=4)
    eng2.step()  # a takes the single slot; the waiting queue is empty
    b = eng2.add_request(PROMPTS[1], max_new_tokens=4)  # queued
    c = eng2.add_request(PROMPTS[2], max_new_tokens=4)  # shed
    by_uid2 = {r.uid: r for r in eng2.finished}
    assert by_uid2[c].status == lifecycle.REJECTED
    assert eng2.cancel(b)
    eng2.run_to_completion(max_steps=200)
    by_uid2 = {r.uid: r for r in eng2.finished}
    assert by_uid2[a].status == lifecycle.DONE
    assert by_uid2[b].status == lifecycle.CANCELLED
    snap = eng2.counters_snapshot()
    assert snap["shed"] == 1 and snap["cancelled"] == 1
