"""Distributed runtime tests.

Multi-device cases run in a subprocess with 8 forced host devices (the main
test process keeps 1 device so everything else stays fast)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.distributed import sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str):
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import compat_make_mesh
        from repro.utils.jax_compat import get_abstract_mesh, set_mesh, shard_map
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        """
        % SRC
    ) + textwrap.dedent(body)
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


# ---------------------------------------------------------------------------
# single-process logic
# ---------------------------------------------------------------------------


def test_param_specs_rules():
    import jax.numpy as jnp

    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    axes = {"w": (None, "mlp"), "e": ("experts", None, None), "s": (None,)}
    shapes = {
        "w": jax.ShapeDtypeStruct((4096, 8192), jnp.float32),
        "e": jax.ShapeDtypeStruct((16, 64, 64), jnp.float32),
        "s": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    specs = shd.param_pspecs(axes, shapes, mesh, fsdp=True)
    assert specs["w"][1] == "model"
    assert specs["w"][0] == "data"  # FSDP on the large unsharded dim
    assert specs["e"][0] == "experts" or specs["e"][0] == "model"
    assert specs["s"] == jax.sharding.PartitionSpec(None)


def test_param_specs_divisibility_guard():
    import jax.numpy as jnp

    # 16-way axes in the production mesh wouldn't divide 3352 — simulate via
    # rule check with a fake mesh of size 1 (always divides) plus direct call
    spec = shd._spec_for((None, "mlp"), (768, 3352), FakeMesh(), fsdp=False,
                         stacked=False)
    assert spec[1] is None  # dropped: 3352 % 16 != 0


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_dp_axes_for_divisibility():
    mesh = FakeMesh()
    assert shd.dp_axes_for(mesh, 256) == ("data",)
    assert shd.dp_axes_for(mesh, 1) is None


# ---------------------------------------------------------------------------
# 8-device subprocess integration
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    _run_subprocess(
        """
        from repro.configs import get_config
        from repro.models import lm
        from repro.train.optimizer import OptimizerConfig, adamw_init
        from repro.train.train_step import make_train_step
        from repro.distributed import sharding as shd

        cfg = get_config("qwen1.5-4b", reduced=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab),
        }
        opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
        step = make_train_step(cfg, opt_cfg)

        # single-device reference
        p1, _, m1 = jax.jit(step)(params, opt, batch, jnp.asarray(0))

        # sharded
        p_shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
        shardings = shd.param_shardings(lm.param_axes(cfg), p_shapes, mesh, fsdp=True)
        params_s = jax.tree_util.tree_map(jax.device_put, params, shardings)
        batch_s = {k: jax.device_put(v, NamedSharding(mesh, P("data"))) for k, v in batch.items()}
        with set_mesh(mesh):
            p2, _, m2 = jax.jit(step)(params_s, jax.tree_util.tree_map(jnp.asarray, opt), batch_s, jnp.asarray(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
        d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).max()), p1, p2)
        worst = max(jax.tree_util.tree_leaves(d))
        assert worst < 5e-3, worst
        print("SHARDED OK", worst)
        """
    )


@pytest.mark.slow
def test_moe_ep_paths_match_dense():
    _run_subprocess(
        """
        from repro.configs import get_config
        from repro.models import moe, lm

        cfg = get_config("llama4-scout-17b-a16e", reduced=True)
        cfg = cfg.replace(capacity_factor=8.0)  # no drops: paths comparable
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

        y_ref, aux_ref = moe._moe_dense_onehot(params, x, cfg)
        with set_mesh(mesh):
            am = get_abstract_mesh()
            y_a2a, aux_a2a = jax.jit(lambda p, xx: moe._moe_ep_a2a(p, xx, cfg, am))(params, x)
            y_psum, aux_psum = jax.jit(lambda p, xx: moe._moe_ep_psum(p, xx, cfg, am))(params, x)
        e1 = float(jnp.abs(y_ref - y_a2a).max())
        e2 = float(jnp.abs(y_ref - y_psum).max())
        assert e1 < 1e-3, e1
        assert e2 < 1e-3, e2
        print("MOE OK", e1, e2)
        """
    )


@pytest.mark.slow
def test_pipeline_matches_sequential():
    _run_subprocess(
        """
        from repro.distributed.pipeline import pipeline_apply, stage_split
        mesh2 = compat_make_mesh((4, 2), ("pod", "model"))
        L, D = 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2

        def stage_fn(stage_params, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, stage_params)
            return y

        x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, D))  # 6 microbatches
        stages = stage_split(ws, 4)  # (4, 2, D, D)
        with set_mesh(mesh2):
            out = pipeline_apply(stage_fn, stages, x, mesh2, axis="pod")
        want = jax.vmap(lambda mb: stage_fn(ws, mb))(x)
        err = float(jnp.abs(out - want).max())
        assert err < 1e-5, err
        print("PIPELINE OK", err)
        """
    )


@pytest.mark.slow
def test_ring_allgather_matmul_and_psum_scatter():
    _run_subprocess(
        """
        from repro.distributed.collectives import (
            psum_scatter_matmul, ring_allgather_matmul,
        )

        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        with set_mesh(mesh):
            y = ring_allgather_matmul(x, w, mesh, axis="model")
            y2 = psum_scatter_matmul(x, w, mesh, axis="model")
        err = float(jnp.abs(y - x @ w).max())
        assert err < 1e-4, err
        err2 = float(jnp.abs(jnp.asarray(y2) - x @ w).max())
        assert err2 < 1e-4, err2
        print("COLLECTIVES OK", err, err2)
        """
    )


@pytest.mark.slow
def test_ef_pmean_compressed_allreduce():
    _run_subprocess(
        """
        from repro.train.compression import ef_pmean

        g = jax.random.normal(jax.random.PRNGKey(2), (2, 16))

        def local(gl):
            mean, new_r = ef_pmean({"g": gl}, {"g": jnp.zeros_like(gl)}, "data")
            return mean["g"], new_r["g"]

        gs = jax.device_put(g, NamedSharding(mesh, P("data", None)))
        with set_mesh(mesh):
            mean_g, _ = jax.jit(shard_map(
                local, mesh=mesh,
                in_specs=P("data", None),
                out_specs=(P("data", None), P("data", None)),
            ))(gs)
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        # int8 quantization error bound: scale/2 per shard
        err = float(jnp.abs(jnp.asarray(mean_g) - exact).max())
        assert err < float(jnp.abs(g).max()) / 127 + 1e-5, err
        print("EF PMEAN OK", err)
        """
    )
