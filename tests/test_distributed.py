"""Distributed runtime tests.

Multi-device cases run in a subprocess with 8 forced host devices (the main
test process keeps 1 device so everything else stays fast)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.distributed import sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str):
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import compat_make_mesh
        from repro.utils.jax_compat import get_abstract_mesh, set_mesh, shard_map
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        """
        % SRC
    ) + textwrap.dedent(body)
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


# ---------------------------------------------------------------------------
# single-process logic
# ---------------------------------------------------------------------------


def test_param_specs_rules():
    import jax.numpy as jnp

    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    axes = {"w": (None, "mlp"), "e": ("experts", None, None), "s": (None,)}
    shapes = {
        "w": jax.ShapeDtypeStruct((4096, 8192), jnp.float32),
        "e": jax.ShapeDtypeStruct((16, 64, 64), jnp.float32),
        "s": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    specs = shd.param_pspecs(axes, shapes, mesh, fsdp=True)
    assert specs["w"][1] == "model"
    assert specs["w"][0] == "data"  # FSDP on the large unsharded dim
    assert specs["e"][0] == "experts" or specs["e"][0] == "model"
    assert specs["s"] == jax.sharding.PartitionSpec(None)


def test_param_specs_divisibility_guard():
    import jax.numpy as jnp

    # 16-way axes in the production mesh wouldn't divide 3352 — simulate via
    # rule check with a fake mesh of size 1 (always divides) plus direct call
    spec = shd._spec_for((None, "mlp"), (768, 3352), FakeMesh(), fsdp=False,
                         stacked=False)
    assert spec[1] is None  # dropped: 3352 % 16 != 0


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_dp_axes_for_divisibility():
    mesh = FakeMesh()
    assert shd.dp_axes_for(mesh, 256) == ("data",)
    assert shd.dp_axes_for(mesh, 1) is None


class FakeContextMesh:
    axis_names = ("data", "context", "model")
    shape = {"data": 2, "context": 4, "model": 2}


def test_dp_axes_exclude_context():
    """The batch dim must never shard over the ring axis: each context
    device holds a sequence shard of the *same* batch."""
    assert shd.dp_axes(FakeContextMesh()) == ("data",)


def test_context_shard_len():
    from repro.distributed.ring_attention import context_shard_len

    assert context_shard_len(1024, 8) == 128
    assert context_shard_len(300, 8) == 128  # ceil(300/8)=38 → lane tile
    assert context_shard_len(2048, 8) == 256
    assert context_shard_len(3000, 8) == 384  # 375 → next 128-multiple
    assert context_shard_len(3000, 8, multiple=256) == 512


def test_ring_merge_algebra():
    """The (O, LSE) merge is the online-softmax combine: merging per-shard
    partials must equal the softmax over the concatenated KV."""
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.ring_attention import _merge_partial

    rng = np.random.RandomState(0)
    s1 = jnp.asarray(rng.randn(4, 8) * 3)  # scores vs shard 1 / shard 2
    s2 = jnp.asarray(rng.randn(4, 8) * 3)
    v1 = jnp.asarray(rng.randn(8, 5))
    v2 = jnp.asarray(rng.randn(8, 5))

    def partial(s, v):
        m = s.max(axis=1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(axis=1, keepdims=True)
        return (p @ v) / l, (m + jnp.log(l))[:, 0]

    o1, lse1 = partial(s1, v1)
    o2, lse2 = partial(s2, v2)
    o, lse = _merge_partial(o1[None], lse1[None], o2[None], lse2[None])

    p_full = jax.nn.softmax(jnp.concatenate([s1, s2], axis=1), axis=-1)
    want = p_full @ jnp.concatenate([v1, v2], axis=0)
    np.testing.assert_allclose(np.asarray(o[0]), np.asarray(want), atol=1e-6)
    want_lse = jax.scipy.special.logsumexp(
        jnp.concatenate([s1, s2], axis=1), axis=1
    )
    np.testing.assert_allclose(np.asarray(lse[0]), np.asarray(want_lse),
                               atol=1e-6)
    # merging against an empty partial (init carry) is the identity
    import repro.distributed.ring_attention as ra

    o_id, lse_id = _merge_partial(
        jnp.zeros_like(o1)[None], jnp.full_like(lse1, ra.NEG_INF)[None],
        o1[None], lse1[None],
    )
    np.testing.assert_allclose(np.asarray(o_id[0]), np.asarray(o1), atol=1e-6)


def test_ring_single_device_fallback():
    """A trivial ring (P=1) must collapse to the plain kernel call."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.distr_attention import DistrConfig
    from repro.distributed.ring_attention import (
        ring_distr_attention, ring_flash_attention,
    )
    from repro.kernels import ops

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("context",))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 160, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1, 160, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 160, 32), jnp.float32)
    out, hops = ring_flash_attention(q, k, v, mesh, causal=True,
                                     return_hops=True)
    ref = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    assert int(hops) == 1
    dcfg = DistrConfig(group_size=2)
    outd = ring_distr_attention(q, k, v, dcfg, mesh, causal=True)
    refd = ops.distr_attention(q, k, v, dcfg, causal=True)
    np.testing.assert_allclose(np.asarray(outd), np.asarray(refd), atol=1e-6)


# ---------------------------------------------------------------------------
# 8-device subprocess integration
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    _run_subprocess(
        """
        from repro.configs import get_config
        from repro.models import lm
        from repro.train.optimizer import OptimizerConfig, adamw_init
        from repro.train.train_step import make_train_step
        from repro.distributed import sharding as shd

        cfg = get_config("qwen1.5-4b", reduced=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab),
        }
        opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
        step = make_train_step(cfg, opt_cfg)

        # single-device reference
        p1, _, m1 = jax.jit(step)(params, opt, batch, jnp.asarray(0))

        # sharded
        p_shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
        shardings = shd.param_shardings(lm.param_axes(cfg), p_shapes, mesh, fsdp=True)
        params_s = jax.tree_util.tree_map(jax.device_put, params, shardings)
        batch_s = {k: jax.device_put(v, NamedSharding(mesh, P("data"))) for k, v in batch.items()}
        with set_mesh(mesh):
            p2, _, m2 = jax.jit(step)(params_s, jax.tree_util.tree_map(jnp.asarray, opt), batch_s, jnp.asarray(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
        d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).max()), p1, p2)
        worst = max(jax.tree_util.tree_leaves(d))
        assert worst < 5e-3, worst
        print("SHARDED OK", worst)
        """
    )


@pytest.mark.slow
def test_moe_ep_paths_match_dense():
    _run_subprocess(
        """
        from repro.configs import get_config
        from repro.models import moe, lm

        cfg = get_config("llama4-scout-17b-a16e", reduced=True)
        cfg = cfg.replace(capacity_factor=8.0)  # no drops: paths comparable
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

        y_ref, aux_ref = moe._moe_dense_onehot(params, x, cfg)
        with set_mesh(mesh):
            am = get_abstract_mesh()
            y_a2a, aux_a2a = jax.jit(lambda p, xx: moe._moe_ep_a2a(p, xx, cfg, am))(params, x)
            y_psum, aux_psum = jax.jit(lambda p, xx: moe._moe_ep_psum(p, xx, cfg, am))(params, x)
        e1 = float(jnp.abs(y_ref - y_a2a).max())
        e2 = float(jnp.abs(y_ref - y_psum).max())
        assert e1 < 1e-3, e1
        assert e2 < 1e-3, e2
        print("MOE OK", e1, e2)
        """
    )


@pytest.mark.slow
def test_pipeline_matches_sequential():
    _run_subprocess(
        """
        from repro.distributed.pipeline import pipeline_apply, stage_split
        mesh2 = compat_make_mesh((4, 2), ("pod", "model"))
        L, D = 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2

        def stage_fn(stage_params, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, stage_params)
            return y

        x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, D))  # 6 microbatches
        stages = stage_split(ws, 4)  # (4, 2, D, D)
        with set_mesh(mesh2):
            out = pipeline_apply(stage_fn, stages, x, mesh2, axis="pod")
        want = jax.vmap(lambda mb: stage_fn(ws, mb))(x)
        err = float(jnp.abs(out - want).max())
        assert err < 1e-5, err
        print("PIPELINE OK", err)
        """
    )


@pytest.mark.slow
def test_ring_allgather_matmul_and_psum_scatter():
    _run_subprocess(
        """
        from repro.distributed.collectives import (
            psum_scatter_matmul, ring_allgather_matmul,
        )

        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        with set_mesh(mesh):
            y = ring_allgather_matmul(x, w, mesh, axis="model")
            y2 = psum_scatter_matmul(x, w, mesh, axis="model")
        err = float(jnp.abs(y - x @ w).max())
        assert err < 1e-4, err
        err2 = float(jnp.abs(jnp.asarray(y2) - x @ w).max())
        assert err2 < 1e-4, err2
        print("COLLECTIVES OK", err, err2)
        """
    )


@pytest.mark.slow
def test_ring_flash_parity_8dev():
    """Ring flash == single-device kernel (fwd + grads) on 8 virtual
    devices, f32 + bf16, causal + non-causal, ragged length; the hop probe
    confirms causal rings and dead shards skip kernel launches."""
    _run_subprocess(
        """
        from repro.distributed.ring_attention import ring_flash_attention
        from repro.kernels import ops
        ring = compat_make_mesh((8,), ("context",))
        B, Hq, Hkv, N, D = 2, 4, 2, 300, 64  # ragged: 3 live shards of 128
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        qf = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
        kf = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
        vf = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
        w = jax.random.normal(ks[3], (B, Hq, N, D), jnp.float32)
        for dtype, ftol, gtol in ((jnp.float32, 2e-5, 5e-5),
                                  (jnp.bfloat16, 2e-2, 2e-1)):
            q, k, v = (x.astype(dtype) for x in (qf, kf, vf))
            for causal in (False, True):
                out, hops = jax.jit(lambda q, k, v: ring_flash_attention(
                    q, k, v, ring, causal=causal, return_hops=True))(q, k, v)
                ref = ops.flash_attention(q, k, v, causal=causal)
                err = float(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32)).max())
                assert err < ftol, (dtype, causal, err)
                # N=300 → live shards {0,1,2}: non-causal runs 3×3 hops,
                # causal 1+2+3; both far below the naive 8×8.
                assert int(hops) == (6 if causal else 9), (causal, int(hops))
                gr = jax.jit(jax.grad(
                    lambda q, k, v: (ring_flash_attention(
                        q, k, v, ring, causal=causal
                    ).astype(jnp.float32) * w).sum(), argnums=(0, 1, 2)
                ))(q, k, v)
                gs = jax.grad(
                    lambda q, k, v: (ops.flash_attention(
                        q, k, v, causal=causal
                    ).astype(jnp.float32) * w).sum(), argnums=(0, 1, 2)
                )(q, k, v)
                gerr = max(float(jnp.abs(a.astype(jnp.float32)
                                         - b.astype(jnp.float32)).max())
                           for a, b in zip(gr, gs))
                assert gerr < gtol, (dtype, causal, gerr)
        print("RING FLASH OK")
        """
    )


@pytest.mark.slow
def test_ring_distr_parity_8dev():
    """Ring DistrAttention == single-device distr kernel: shard-local LSH
    grouping derives identical permutations when shards are block-aligned,
    so outputs (and straight-through grads) match."""
    _run_subprocess(
        """
        from repro.core.distr_attention import DistrConfig
        from repro.distributed.ring_attention import ring_distr_attention
        from repro.kernels import ops
        ring = compat_make_mesh((8,), ("context",))
        B, Hq, Hkv, N, D = 2, 4, 2, 300, 64
        dcfg = DistrConfig(group_size=2)  # block_q=128: the grouping grain
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        qf = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
        kf = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
        vf = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
        w = jax.random.normal(ks[3], (B, Hq, N, D), jnp.float32)
        for dtype, ftol, gtol in ((jnp.float32, 2e-5, 5e-5),
                                  (jnp.bfloat16, 2e-2, 2e-1)):
            q, k, v = (x.astype(dtype) for x in (qf, kf, vf))
            for causal in (False, True):
                out, hops = jax.jit(lambda q, k, v: ring_distr_attention(
                    q, k, v, dcfg, ring, causal=causal, return_hops=True
                ))(q, k, v)
                ref = ops.distr_attention(q, k, v, dcfg, causal=causal)
                err = float(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32)).max())
                assert err < ftol, (dtype, causal, err)
                assert int(hops) == (6 if causal else 9), (causal, int(hops))
                gr = jax.jit(jax.grad(
                    lambda q, k, v: (ring_distr_attention(
                        q, k, v, dcfg, ring, causal=causal
                    ).astype(jnp.float32) * w).sum(), argnums=(0, 1, 2)
                ))(q, k, v)
                gs = jax.grad(
                    lambda q, k, v: (ops.distr_attention(
                        q, k, v, dcfg, causal=causal
                    ).astype(jnp.float32) * w).sum(), argnums=(0, 1, 2)
                )(q, k, v)
                gerr = max(float(jnp.abs(a.astype(jnp.float32)
                                         - b.astype(jnp.float32)).max())
                           for a, b in zip(gr, gs))
                assert gerr < gtol, (dtype, causal, gerr)
        print("RING DISTR OK")
        """
    )


@pytest.mark.slow
def test_ring_distr_shared_kv_perm_parity_8dev():
    """Ring DistrAttention with shared_kv_perm (one permutation per KV
    group, derived from the group's mean query block) == the single-device
    kernel, fwd + grads.  This used to raise NotImplementedError under the
    ring; stage 1 now runs the shared ops.distr_stage1 outside shard_map,
    so the variant composes for free."""
    _run_subprocess(
        """
        from repro.core.distr_attention import DistrConfig
        from repro.distributed.ring_attention import ring_distr_attention
        from repro.kernels import ops
        ring = compat_make_mesh((8,), ("context",))
        B, Hq, Hkv, N, D = 2, 4, 2, 300, 64
        dcfg = DistrConfig(group_size=2, shared_kv_perm=True)
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
        w = jax.random.normal(ks[3], (B, Hq, N, D), jnp.float32)
        for causal in (False, True):
            out, hops = jax.jit(lambda q, k, v: ring_distr_attention(
                q, k, v, dcfg, ring, causal=causal, return_hops=True
            ))(q, k, v)
            ref = ops.distr_attention(q, k, v, dcfg, causal=causal)
            err = float(jnp.abs(out - ref).max())
            assert err < 2e-5, (causal, err)
            assert int(hops) == (6 if causal else 9), (causal, int(hops))
            gr = jax.jit(jax.grad(
                lambda q, k, v: (ring_distr_attention(
                    q, k, v, dcfg, ring, causal=causal
                ) * w).sum(), argnums=(0, 1, 2)
            ))(q, k, v)
            gs = jax.grad(
                lambda q, k, v: (ops.distr_attention(
                    q, k, v, dcfg, causal=causal
                ) * w).sum(), argnums=(0, 1, 2)
            )(q, k, v)
            gerr = max(float(jnp.abs(a - b).max()) for a, b in zip(gr, gs))
            assert gerr < 5e-5, (causal, gerr)
        print("RING SHARED PERM OK")
        """
    )


@pytest.mark.slow
def test_attend_context_axis_dispatch_8dev():
    """core.api.attend routes to the ring under an active mesh with the
    configured context axis — including a mixed (data, context, model) mesh
    where batch and heads shard over their own axes — and falls back to the
    single-device kernel for short sequences."""
    _run_subprocess(
        """
        from repro.core import attend, AttentionConfig, DistrConfig
        ring = compat_make_mesh((2, 2, 2), ("data", "context", "model"))
        B, Hq, Hkv, N, D = 2, 4, 2, 512, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
        cfg = AttentionConfig(impl="pallas_flash", context_axis="context")
        ref = attend(q, k, v, cfg.with_impl("pallas_flash"), causal=True)
        with set_mesh(ring):
            out = jax.jit(lambda q, k, v: attend(q, k, v, cfg, causal=True))(
                q, k, v)
        err = float(jnp.abs(out - ref).max())
        assert err < 2e-5, err
        # short sequence: below ring_size × 128 the dispatch stays local
        qs, ks_, vs = q[:, :, :96], k[:, :, :96], v[:, :, :96]
        with set_mesh(ring):
            outs = jax.jit(lambda q, k, v: attend(q, k, v, cfg, causal=True))(
                qs, ks_, vs)
        refs = attend(qs, ks_, vs, cfg.with_impl("pallas_flash"), causal=True)
        errs = float(jnp.abs(outs - refs).max())
        assert errs < 2e-5, errs
        print("ATTEND DISPATCH OK", err, errs)
        """
    )


@pytest.mark.slow
def test_pipeline_drain_ticks_inject_zeros():
    """Regression (drain-tick re-injection): every stage must see each
    microbatch exactly once — stage 0 used to re-inject microbatch M-1 on
    every drain tick, so stages recomputed it S-1 extra times.  Microbatch
    identity is encoded in the data (constant value m+1) and an identity
    stage_fn records what each stage actually processes."""
    _run_subprocess(
        """
        from collections import Counter
        from repro.distributed.pipeline import pipeline_apply
        mesh2 = compat_make_mesh((4, 1), ("pod", "model"))
        S, M, MB, D = 4, 6, 2, 8
        seen = []
        def record(stage, val):
            seen.append((int(stage), round(float(val), 3)))
        def stage_fn(params, x):
            jax.debug.callback(record, jax.lax.axis_index("pod"), x[0, 0])
            return x
        x = jnp.broadcast_to(
            (jnp.arange(M, dtype=jnp.float32) + 1.0)[:, None, None], (M, MB, D)
        )
        ws = jnp.zeros((S, 1))
        with set_mesh(mesh2):
            out = pipeline_apply(stage_fn, ws, x, mesh2, axis="pod")
        jax.effects_barrier()
        err = float(jnp.abs(out - x).max())
        assert err < 1e-6, err
        counts = Counter((s, v) for s, v in seen if v != 0.0)
        assert len(counts) == S * M, sorted(counts)
        dupes = {k: c for k, c in counts.items() if c != 1}
        assert not dupes, f"stage saw a microbatch more than once: {dupes}"
        print("PIPELINE DRAIN OK")
        """
    )


@pytest.mark.slow
def test_serve_engine_ring_prefill_matches_single_device():
    """ServeEngine(mesh=...) long-prompt prefill rides the context ring;
    the generated (greedy) tokens must match a mesh-less engine, and the
    ring-produced KV cache must interoperate with the single-device decode
    step."""
    _run_subprocess(
        """
        from dataclasses import replace as dc_replace
        from repro.configs import get_config
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        cfg = get_config("qwen1.5-4b", reduced=True)
        cfg = cfg.replace(attention=dc_replace(
            cfg.attention, impl="pallas_flash", context_axis="context"))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        ring = compat_make_mesh((2,), ("context",))
        prompt = list(np.random.RandomState(0).randint(
            0, cfg.vocab, size=300))  # bucket 512 ≥ ring×128 → ring prefill

        eng = ServeEngine(cfg, params, max_slots=2, max_len=512, mesh=ring)
        eng.add_request(prompt, max_new_tokens=3)
        got = eng.run_to_completion()[0].generated

        cfg0 = cfg.replace(attention=dc_replace(
            cfg.attention, context_axis=None))
        eng0 = ServeEngine(cfg0, params, max_slots=2, max_len=512)
        eng0.add_request(prompt, max_new_tokens=3)
        want = eng0.run_to_completion()[0].generated
        assert got == want, (got, want)
        print("SERVE RING OK", got)
        """
    )


@pytest.mark.slow
def test_paged_engine_mesh_prefill_matches_single_device():
    """ISSUE 9 acceptance: a long prompt on PagedServeEngine(mesh=...)
    prefills whole across the context ring in one tick (mesh_prefills
    counter), lands its KV in the block pool spanning ≥ 3 blocks, and the
    paged greedy decode matches a mesh-less engine token for token.  The
    cluster router steers the long prompt to the mesh-capable replica and
    away from a short-cache one."""
    _run_subprocess(
        """
        from dataclasses import replace as dc_replace
        from repro.configs import get_config
        from repro.models import lm
        from repro.serve.cluster import ClusterRouter
        from repro.serve.engine import PagedServeEngine
        from repro.serve import lifecycle

        cfg = get_config("qwen1.5-4b", reduced=True)
        cfg = cfg.replace(attention=dc_replace(
            cfg.attention, impl="pallas_flash", context_axis="context"))
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        ring = compat_make_mesh((2,), ("context",))
        prompt = list(np.random.RandomState(0).randint(
            0, cfg.vocab, size=300))  # bucket 512 ≥ ring×128 → ring prefill

        eng = PagedServeEngine(
            cfg, params, max_batch=2, max_len=512, block_size=128,
            prefill_chunk=32, cache_dtype=jnp.float32, mesh=ring)
        assert eng.cache.blocks_for(len(prompt)) >= 3  # spans ≥ 3 blocks
        eng.add_request(prompt, max_new_tokens=3)
        got = eng.run_to_completion()[0].generated
        assert eng.counters_snapshot()["mesh_prefills"] == 1

        cfg0 = cfg.replace(attention=dc_replace(
            cfg.attention, context_axis=None))
        eng0 = PagedServeEngine(
            cfg0, params, max_batch=2, max_len=512, block_size=128,
            prefill_chunk=32, cache_dtype=jnp.float32)
        eng0.add_request(prompt, max_new_tokens=3)
        want = eng0.run_to_completion()[0].generated
        assert got == want, (got, want)

        # capability routing: a short-cache replica never sees the prompt
        short = PagedServeEngine(
            cfg0, params, max_batch=2, max_len=64, block_size=64,
            prefill_chunk=32, cache_dtype=jnp.float32)
        router = ClusterRouter([short, eng], policy="round_robin")
        uid = router.add_request(prompt, max_new_tokens=3)
        assert router.request(uid).rid == 1, "long prompt missed the mesh replica"
        router.run_to_completion(max_ticks=600)
        creq = router.request(uid)
        assert creq.status == lifecycle.DONE
        assert creq.emitted == want, (creq.emitted, want)
        print("MESH PAGED OK", got)
        """
    )


@pytest.mark.slow
def test_context_parallel_train_step_matches_single_device():
    """End-to-end train wiring: a Pallas-attention train step under a
    (data, context) mesh — ring attention inside the jitted loss/grads —
    matches the single-device step."""
    _run_subprocess(
        """
        from dataclasses import replace as dc_replace
        from repro.configs import get_config
        from repro.models import lm
        from repro.train.optimizer import OptimizerConfig, adamw_init
        from repro.train.train_step import make_train_step

        cfg = get_config("qwen1.5-4b", reduced=True)
        cfg = cfg.replace(attention=dc_replace(
            cfg.attention, impl="pallas_flash"))
        seq = 512  # ≥ ring size × 128 so the ring engages
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        batch = {
            "tokens": jax.random.randint(
                jax.random.PRNGKey(1), (2, seq), 0, cfg.vocab),
            "labels": jax.random.randint(
                jax.random.PRNGKey(2), (2, seq), 0, cfg.vocab),
        }
        opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10)
        step = make_train_step(cfg, opt_cfg)
        p1, _, m1 = jax.jit(step)(params, opt, batch, jnp.asarray(0))

        cfg_cp = cfg.replace(attention=dc_replace(
            cfg.attention, context_axis="context"))
        step_cp = make_train_step(cfg_cp, opt_cfg)
        mesh_cp = compat_make_mesh((2, 4), ("data", "context"))
        with set_mesh(mesh_cp):
            p2, _, m2 = jax.jit(step_cp)(
                params, jax.tree_util.tree_map(jnp.asarray, opt), batch,
                jnp.asarray(0))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (
            m1["loss"], m2["loss"])
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                       - jnp.asarray(b, jnp.float32)).max()),
            p1, p2)
        worst = max(jax.tree_util.tree_leaves(d))
        assert worst < 5e-3, worst
        print("CONTEXT TRAIN OK", float(m1["loss"]), worst)
        """
    )


@pytest.mark.slow
def test_ef_pmean_compressed_allreduce():
    _run_subprocess(
        """
        from repro.train.compression import ef_pmean

        g = jax.random.normal(jax.random.PRNGKey(2), (2, 16))

        def local(gl):
            mean, new_r = ef_pmean({"g": gl}, {"g": jnp.zeros_like(gl)}, "data")
            return mean["g"], new_r["g"]

        gs = jax.device_put(g, NamedSharding(mesh, P("data", None)))
        with set_mesh(mesh):
            mean_g, _ = jax.jit(shard_map(
                local, mesh=mesh,
                in_specs=P("data", None),
                out_specs=(P("data", None), P("data", None)),
            ))(gs)
        exact = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
        # int8 quantization error bound: scale/2 per shard
        err = float(jnp.abs(jnp.asarray(mean_g) - exact).max())
        assert err < float(jnp.abs(g).max()) / 127 + 1e-5, err
        print("EF PMEAN OK", err)
        """
    )
