"""DistrAttention core semantics (paper §3) — the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AttentionConfig,
    DistrConfig,
    attend,
    blockwise_flash_reference,
    distr_attention,
    distr_scores,
    reference_attention,
)


def _qkv(seed, b=2, hq=4, hkv=4, n=128, d=64, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, n, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, n, d), dtype)
    return q, k, v


def test_group_size_one_is_exact():
    """G*=1 ⇒ sampling+fusion is a pure permutation ⇒ Ŝ == S exactly."""
    q, k, v = _qkv(0)
    out = distr_attention(q, k, v, DistrConfig(group_size=1, block_q=32), causal=True)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_duplicated_columns_are_exact_at_g2():
    """If every Q/K column appears exactly twice, grouping the duplicates
    makes the distributive approximation EXACT (paper Eq. 1).

    Duplicates are interleaved (col 2i == col 2i+1): identical columns hash
    identically and the stable sort keeps them adjacent, so every group is a
    true duplicate pair even when two distinct columns collide in hash.
    """
    b, h, n, d = 1, 1, 64, 32
    qh = jax.random.normal(jax.random.PRNGKey(1), (b, h, n, d // 2))
    kh = jax.random.normal(jax.random.PRNGKey(2), (b, h, n, d // 2))
    q = jnp.repeat(qh, 2, axis=-1)
    k = jnp.repeat(kh, 2, axis=-1)
    s_hat = distr_scores(q, k, DistrConfig(group_size=2, block_q=16))
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    np.testing.assert_allclose(np.asarray(s_hat), np.asarray(s), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("g", [2, 4, 8])
def test_error_grows_with_sampling_rate(g):
    """Paper Table 4: error increases with G* (checked on gaussian data)."""
    q, k, _ = _qkv(3, b=1, hq=1, hkv=1, n=64, d=64)
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    err_g = float(
        jnp.abs(distr_scores(q, k, DistrConfig(group_size=g, block_q=16)) - s).mean()
    )
    err_1 = float(
        jnp.abs(distr_scores(q, k, DistrConfig(group_size=1, block_q=16)) - s).mean()
    )
    assert err_g > err_1


def test_output_rows_are_convex_combinations_of_v():
    """Softmax is untouched by the approximation ⇒ outputs stay within the
    per-feature [min, max] of V (full-context invariant)."""
    q, k, v = _qkv(4)
    out = distr_attention(q, k, v, DistrConfig(group_size=4, block_q=32))
    v_min = v.min(axis=2, keepdims=True) - 1e-4
    v_max = v.max(axis=2, keepdims=True) + 1e-4
    assert bool(((out >= v_min) & (out <= v_max)).all())


def test_gqa_and_shared_kv_perm():
    q, k, v = _qkv(5, hq=8, hkv=2)
    o1 = distr_attention(q, k, v, DistrConfig(group_size=2, block_q=32), causal=True)
    o2 = distr_attention(
        q, k, v, DistrConfig(group_size=2, block_q=32, shared_kv_perm=True),
        causal=True,
    )
    ref = reference_attention(q, k, v, causal=True)
    assert o1.shape == ref.shape == o2.shape
    # both approximations stay close to the exact output
    assert float(jnp.abs(o1 - ref).mean()) < 0.3
    assert float(jnp.abs(o2 - ref).mean()) < 0.3


def test_q_exact_slice_matches_concat_at_g1():
    """The MLA split-score path must equal attention over concatenated
    features when grouping is disabled."""
    b, h, n = 1, 2, 64
    q, k, v = _qkv(6, b=b, hq=h, hkv=h, n=n, d=64)
    qe = jax.random.normal(jax.random.PRNGKey(7), (b, h, n, 16))
    ke = jax.random.normal(jax.random.PRNGKey(8), (b, h, n, 16))
    scale = 1.0 / (80.0**0.5)
    out = distr_attention(
        q, k, v, DistrConfig(group_size=1, block_q=16),
        causal=True, scale=scale, q_exact=qe, k_exact=ke,
    )
    ref = reference_attention(
        jnp.concatenate([q, qe], -1), jnp.concatenate([k, ke], -1), v,
        causal=True, scale=scale,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_padding_path():
    q, k, v = _qkv(9, n=100)  # not a multiple of block_q
    out = distr_attention(q, k, v, DistrConfig(group_size=2, block_q=32), causal=True)
    assert out.shape == q.shape
    assert bool(jnp.isfinite(out).all())


def test_attend_dispatch_all_impls():
    q, k, v = _qkv(10, n=64)
    ref = attend(q, k, v, AttentionConfig(impl="reference"), causal=True)
    for impl in ("xla_flash", "distr", "pallas_flash", "pallas_distr"):
        cfg = AttentionConfig(
            impl=impl, block_q=32, block_k=32,
            distr=DistrConfig(group_size=2, block_q=32, block_k=32),
        )
        out = attend(q, k, v, cfg, causal=True)
        assert out.shape == ref.shape
        assert bool(jnp.isfinite(out).all())
        if impl == "xla_flash":
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_reference_exactness_rectangular():
    q, k, v = _qkv(11, n=96)
    ref = reference_attention(q, k, v, causal=False)
    out = blockwise_flash_reference(q, k, v, block_q=32, block_k=48, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([2, 4]), st.sampled_from([16, 32]))
def test_distr_softmax_rows_convex_property(seed, g, block):
    q, k, v = _qkv(seed, b=1, hq=2, hkv=2, n=64, d=32)
    out = distr_attention(q, k, v, DistrConfig(group_size=g, block_q=block))
    assert bool(jnp.isfinite(out).all())
    assert float(out.max()) <= float(v.max()) + 1e-3
    assert float(out.min()) >= float(v.min()) - 1e-3
