"""Data pipeline: determinism, resumability, binary shards, host sharding."""
import numpy as np

from repro.train.data import BinaryShardData, SyntheticLMData, write_binary_shard


def test_synthetic_deterministic_and_resumable():
    d1 = SyntheticLMData(512, batch=4, seq_len=16, seed=3)
    batches = [d1.next_batch() for _ in range(5)]
    d2 = SyntheticLMData(512, batch=4, seq_len=16, seed=3)
    d2.restore({"step": 3, "seed": 3})
    np.testing.assert_array_equal(d2.next_batch()["tokens"], batches[3]["tokens"])


def test_synthetic_labels_are_shifted_tokens():
    d = SyntheticLMData(512, batch=2, seq_len=8, seed=0)
    b = d.next_batch()
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    # labels[t] == tokens[t+1] within the underlying stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_synthetic_host_sharding_differs():
    a = SyntheticLMData(512, 2, 8, seed=0, host_id=0, num_hosts=2).next_batch()
    b = SyntheticLMData(512, 2, 8, seed=0, host_id=1, num_hosts=2).next_batch()
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_binary_shards_roundtrip_and_state(tmp_path):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, size=4096).astype(np.uint16)
    path = str(tmp_path / "shard0.bin")
    write_binary_shard(path, toks)

    ds = BinaryShardData([path], batch=2, seq_len=15)
    b1 = ds.next_batch()
    assert b1["tokens"].shape == (2, 15)
    np.testing.assert_array_equal(
        b1["tokens"][0], toks[:15].astype(np.int32)
    )
    np.testing.assert_array_equal(b1["labels"][0], toks[1:16].astype(np.int32))

    state = ds.state()
    b2 = ds.next_batch()
    ds2 = BinaryShardData([path], batch=2, seq_len=15)
    ds2.restore(state)
    np.testing.assert_array_equal(ds2.next_batch()["tokens"], b2["tokens"])


def test_binary_shards_multi_shard_state_roundtrip(tmp_path):
    """state()/restore() round-trip across shard boundaries AND an epoch
    wrap: restoring any mid-stream snapshot into a fresh reader reproduces
    the remaining stream exactly."""
    paths = []
    for i, n in enumerate((130, 200)):  # uneven shards
        p = str(tmp_path / f"shard{i}.bin")
        write_binary_shard(p, (np.arange(n) + 1000 * i).astype(np.uint16))
        paths.append(p)

    ref = BinaryShardData(paths, batch=1, seq_len=31)
    snapshots, batches = [], []
    for _ in range(12):  # crosses shard0→shard1 and wraps an epoch
        snapshots.append(ref.state())
        batches.append(ref.next_batch())
    assert ref.state()["epoch"] >= 1
    assert {s["shard_idx"] for s in snapshots} == {0, 1}

    for k, snap in enumerate(snapshots):
        ds = BinaryShardData(paths, batch=1, seq_len=31)
        ds.restore(snap)
        for want in batches[k:]:
            got = ds.next_batch()
            np.testing.assert_array_equal(got["tokens"], want["tokens"])
            np.testing.assert_array_equal(got["labels"], want["labels"])


def test_binary_shards_epoch_wrap(tmp_path):
    toks = np.arange(200, dtype=np.uint16)
    path = str(tmp_path / "s.bin")
    write_binary_shard(path, toks)
    ds = BinaryShardData([path], batch=1, seq_len=63)
    for _ in range(5):
        b = ds.next_batch()
        assert b["tokens"].shape == (1, 63)
    assert ds.state()["epoch"] >= 1
