"""Elasticity / fault-tolerance control-plane logic."""
import pytest

from repro.train.elastic import (
    FailureDetector,
    StragglerPolicy,
    reassign_shards,
    replan_mesh,
)


def test_reassign_shards_deterministic_and_complete():
    a = reassign_shards(10, [0, 2, 5])
    b = reassign_shards(10, [5, 0, 2])  # order-independent
    assert a == b
    assert sorted(s for shards in a.values() for s in shards) == list(range(10))
    # balanced within 1
    sizes = [len(v) for v in a.values()]
    assert max(sizes) - min(sizes) <= 1


def test_reassign_no_survivors_raises():
    with pytest.raises(ValueError):
        reassign_shards(4, [])


def test_replan_mesh_shrinks_dp_keeps_tp():
    shape, axes = replan_mesh(512, model_parallel=16, pods=2)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose a pod → single-pod plan
    shape, axes = replan_mesh(256, model_parallel=16, pods=1)
    assert shape == (16, 16) and axes == ("data", "model")
    # lose 16 chips → DP shrinks, TP unchanged
    shape, axes = replan_mesh(240, model_parallel=16)
    assert shape == (15, 16)
    with pytest.raises(ValueError):
        replan_mesh(250, model_parallel=16)


def test_straggler_policy():
    pol = StragglerPolicy(threshold=2.0)
    flags = pol.flag({0: 1.0, 1: 1.1, 2: 5.0, 3: 0.9})
    assert flags == [2]
    assert pol.flag({}) == []


def test_failure_detector():
    det = FailureDetector([0, 1, 2], max_missed=2)
    det.beat(0)
    det.beat(1)
    assert det.tick() == []  # everyone at 1 missed
    det.beat(0)
    dead = det.tick()  # 1 and 2 reach 2 missed
    assert set(dead) == {1, 2}
    assert det.alive == [0]


def test_failure_detector_dead_worker_stays_dead():
    """A beat from an already-declared-dead worker must not resurrect it:
    the controller has reassigned its shards; a zombie rejoin would
    double-assign them."""
    det = FailureDetector([0, 1], max_missed=2)
    det.beat(0)
    assert det.tick() == []
    det.beat(0)
    assert det.tick() == [1]
    det.beat(1)  # late heartbeat from the dead worker
    assert det.alive == [0]
    det.beat(0)
    assert det.tick() == []  # it is not reported dead twice either


def _assert_partition(assignment, num_shards, alive):
    """Every shard appears exactly once (none orphaned, none duplicated)
    and only surviving workers own shards."""
    flat = [s for shards in assignment.values() for s in shards]
    assert sorted(flat) == list(range(num_shards)), "orphaned/duplicated"
    assert set(assignment) == set(alive)


def test_worker_loss_sequence_keeps_shards_partitioned():
    """Drive the detector through a cascading-failure sequence and replan
    shard ownership after each death wave: at every point the data shards
    stay an exact partition of the surviving workers, and the final plan
    depends only on the surviving set (restart determinism)."""
    num_shards = 13
    det = FailureDetector([0, 1, 2, 3, 4], max_missed=2)
    plans = [reassign_shards(num_shards, det.alive)]
    _assert_partition(plans[0], num_shards, [0, 1, 2, 3, 4])

    # wave 1: workers 1 and 3 go silent; the rest keep beating
    dead = set()
    for _ in range(2):
        for w in (0, 2, 4):
            det.beat(w)
        dead.update(det.tick())
    assert dead == {1, 3}
    plans.append(reassign_shards(num_shards, det.alive))
    _assert_partition(plans[1], num_shards, [0, 2, 4])
    for w in (1, 3):
        assert w not in plans[1], "dead worker still owns shards"

    # wave 2: worker 4 dies too
    dead = set()
    for _ in range(2):
        det.beat(0)
        det.beat(2)
        dead.update(det.tick())
    assert dead == {4}
    plans.append(reassign_shards(num_shards, det.alive))
    _assert_partition(plans[2], num_shards, [0, 2])

    # restart determinism: a fresh controller that only knows the final
    # survivor set reproduces the same plan bit-for-bit
    assert reassign_shards(num_shards, [2, 0]) == plans[2]
    # balance survives the cascade (within one shard)
    sizes = [len(v) for v in plans[2].values()]
    assert max(sizes) - min(sizes) <= 1
