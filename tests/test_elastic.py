"""Elasticity / fault-tolerance control-plane logic."""
import pytest

from repro.train.elastic import (
    FailureDetector,
    StragglerPolicy,
    reassign_shards,
    replan_mesh,
)


def test_reassign_shards_deterministic_and_complete():
    a = reassign_shards(10, [0, 2, 5])
    b = reassign_shards(10, [5, 0, 2])  # order-independent
    assert a == b
    assert sorted(s for shards in a.values() for s in shards) == list(range(10))
    # balanced within 1
    sizes = [len(v) for v in a.values()]
    assert max(sizes) - min(sizes) <= 1


def test_reassign_no_survivors_raises():
    with pytest.raises(ValueError):
        reassign_shards(4, [])


def test_replan_mesh_shrinks_dp_keeps_tp():
    shape, axes = replan_mesh(512, model_parallel=16, pods=2)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose a pod → single-pod plan
    shape, axes = replan_mesh(256, model_parallel=16, pods=1)
    assert shape == (16, 16) and axes == ("data", "model")
    # lose 16 chips → DP shrinks, TP unchanged
    shape, axes = replan_mesh(240, model_parallel=16)
    assert shape == (15, 16)
    with pytest.raises(ValueError):
        replan_mesh(250, model_parallel=16)


def test_straggler_policy():
    pol = StragglerPolicy(threshold=2.0)
    flags = pol.flag({0: 1.0, 1: 1.1, 2: 5.0, 3: 0.9})
    assert flags == [2]
    assert pol.flag({}) == []


def test_failure_detector():
    det = FailureDetector([0, 1, 2], max_missed=2)
    det.beat(0)
    det.beat(1)
    assert det.tick() == []  # everyone at 1 missed
    det.beat(0)
    dead = det.tick()  # 1 and 2 reach 2 missed
    assert set(dead) == {1, 2}
    assert det.alive == [0]
