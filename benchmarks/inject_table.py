"""Inject the generated roofline table into EXPERIMENTS.md (idempotent)."""
import os
import re

from benchmarks.roofline_table import table

ROOT = os.path.join(os.path.dirname(__file__), "..")
MARK = "<!-- ROOFLINE_TABLE -->"
BEGIN = "<!-- ROOFLINE_TABLE_BEGIN -->"
END = "<!-- ROOFLINE_TABLE_END -->"


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    tbl = f"{BEGIN}\n{table('16x16')}\n{END}"
    if BEGIN in text:
        text = re.sub(
            re.escape(BEGIN) + r".*?" + re.escape(END), tbl, text, flags=re.S
        )
    else:
        text = text.replace(MARK, tbl)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md table updated")


if __name__ == "__main__":
    main()
