"""Paper Fig. 8 / §4.3-4.4 analogue: training-loss trajectories with exact
attention vs DistrAttention vs approximate baselines on the synthetic LM
task (reduced model, CPU)."""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.train.data import SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer
from benchmarks.common import save_result

STEPS = 30


def run(smoke: bool = False) -> list[tuple]:
    import tempfile

    rows, records = [], []
    steps = 3 if smoke else STEPS
    for name, impl in (
        ("exact_flash", "xla_flash"),
        ("distr_g2", "distr"),
    ):
        cfg = get_config("minicpm-2b", reduced=True)
        cfg = cfg.replace(attention=cfg.attention.with_impl(impl))
        opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=steps)
        data = SyntheticLMData(cfg.vocab, batch=2 if smoke else 8,
                               seq_len=32 if smoke else 64, seed=0)
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, opt, data, workdir=d, log_every=10_000,
                         ckpt_every=10_000)
            hist = tr.run(steps)
        losses = [h["loss"] for h in hist]
        records.append(dict(method=name, losses=losses))
        rows.append((
            f"train_loss/{name}", 0.0,
            f"first={losses[0]:.4f} last={losses[-1]:.4f}",
        ))
    if not smoke:
        save_result("accuracy_train", records)
    return rows
