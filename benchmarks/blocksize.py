"""Paper Table 2: (l, m) selection — analytic rule vs measured best vs the
static 128×128 default, on the same measured axis.

The candidate grid is sourced from ``core.block_size.enumerate_block_sizes``
via the autotuner's pruner (``repro.tune.pair_candidates``) — the benchmark
and the tuner search the *same* space, so a disagreement between the
analytic pick and the measured best here is exactly the gap the
``REPRO_TUNE=measure`` mode closes.  Timings are labeled by
backend/interpret (CPU interpreter wall time is not TPU time; the analytic
VMEM/IO story lives in DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_size import select_block_sizes
from repro.kernels import ops
from repro.tune import pair_candidates, seq_bucket
from repro.tune.measure import measure_candidates, wall_timer
from benchmarks.common import backend_info, save_result, timing_label

N = 512


def _measure(d: int, g: int, n: int, candidates, iters: int):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, n, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, n, d), jnp.float32)

    def make_run(cand):
        bq, bk = cand
        if g > 1:
            from repro.core.distr_attention import DistrConfig

            cfg = DistrConfig(group_size=g, block_q=bq, block_k=bk)
            return lambda: ops.distr_attention(q, k, v, cfg, causal=True)
        return lambda: ops.flash_attention(
            q, k, v, causal=True, block_q=bq, block_k=bk
        )

    return measure_candidates(
        make_run, candidates, wall_timer(warmup=1, iters=iters)
    )


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    n = 128 if smoke else N
    configs = [(64, 1)] if smoke else [(d, g) for d in (32, 64, 128, 256)
                                       for g in (1, 2)]
    for d, g in configs:
        # Shared search space: the tuner's analytic pruning over the full
        # enumerate_block_sizes grid, clamped to this sequence bucket.
        candidates = pair_candidates(d, n=n, group_size=g,
                                     top_k=3 if smoke else 8)
        nb = seq_bucket(n)
        analytic = select_block_sizes(d, group_size=g, max_l=nb, max_m=nb)
        analytic = (min(analytic[0], nb), min(analytic[1], nb))
        if analytic not in candidates:
            candidates.append(analytic)
        default = (min(128, nb), min(128, nb))

        table = _measure(d, g, n, candidates, iters=2 if smoke else 3)
        best = min(table, key=lambda c: table[c])

        def us(cand):
            # measure_candidates skips candidates that fail to run; a
            # missing analytic/default row reports NaN instead of crashing.
            s = table.get(cand)
            return s * 1e6 if s is not None else float("nan")

        rec = dict(
            d=d, g=g, n=n,
            candidates=[list(c) for c in candidates],
            analytic=list(analytic), analytic_us=us(analytic),
            measured_best=list(best), measured_best_us=us(best),
            default=list(default), default_us=us(default),
            **backend_info(),
        )
        records.append(rec)
        rows.append((
            f"blocksize/d={d}/G={g}", us(best),
            f"best={best} analytic={analytic}({us(analytic):.0f}us) "
            f"default={default}({us(default):.0f}us) "
            f"{timing_label()}",
        ))
    if not smoke:
        save_result("blocksize", records)
    return rows
