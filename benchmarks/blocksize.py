"""Paper Table 2: (l, m) selection — analytic rule vs exhaustive best,
re-parameterised for TPU VMEM (DESIGN.md §2)."""
from __future__ import annotations

from repro.core.block_size import enumerate_block_sizes, select_block_sizes
from benchmarks.common import save_result


def run() -> list[tuple]:
    rows, records = [], []
    for d in (32, 64, 128, 256):
        for g in (1, 2):
            # extend the search past 1024 so the VMEM constraint binds —
            # TPU VMEM (16 MiB) is ~100× GPU SMEM, so optimal TPU tiles are
            # far larger than the paper's (128, 128) (DESIGN.md §2).
            ours = select_block_sizes(d, group_size=g, max_l=4096, max_m=4096)
            legal = enumerate_block_sizes(d, group_size=g, max_l=4096,
                                          max_m=4096)
            # "best" = the config the selection rule ranks first among legal
            # (on hardware this would be a measured sweep; structurally the
            # rule's objective is max-l-then-m, so report the frontier too)
            max_l = max(x[0] for x in legal)
            best = (max_l, max(m for l, m, _ in legal if l == max_l))
            records.append(dict(d=d, g=g, ours=ours, best=best,
                                n_legal=len(legal)))
            rows.append((
                f"blocksize/d={d}/G={g}", 0.0,
                f"ours={ours} best={best} legal={len(legal)}",
            ))
    save_result("blocksize", records)
    return rows
