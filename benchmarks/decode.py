"""Flash-decoding benchmark: per-token decode cost vs *live* KV length.

The claim under test (ISSUE 2 acceptance): with the length-aware split-K
kernel + ring cache, per-token decode cost scales with the live length, not
the allocated ``max_len`` — the analytic model
(``roofline.analysis.decode_attention_cost``) must show ≥2× fewer KV bytes
at length=64 than length=512, and the measured timings (labeled by
backend/interpret — CPU interpret wall time is not TPU time) compare the
kernel op against the dense pure-JAX decode that attends over all
``max_len`` slots.

Emits ``BENCH_decode.json`` at the repo root (perf trajectory) and
``benchmarks/results/decode.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.obs.utilization import utilization_columns
from repro.roofline.analysis import decode_attention_cost
from benchmarks.common import backend_info, save_result, timeit, timing_label

B, HQ, HKV, D = 4, 8, 2, 64
MAX_LEN = 512
BLOCK_K = 64
LIVE_LENGTHS = (64, 128, 256, 512)
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_decode.json")


# The pre-kernel serve path: masked softmax over the whole padded cache —
# reads all max_len slots regardless of the live length (the kernel oracle).
_dense_decode = ref.decode_attention_ref


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    max_len = 128 if smoke else MAX_LEN
    live_lengths = (64, 128) if smoke else LIVE_LENGTHS
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, HQ, 1, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, HKV, max_len, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, HKV, max_len, D), jnp.float32)

    kernel_fn = jax.jit(
        lambda q, k, v, lens: ops.decode_attention(
            q, k, v, lengths=lens, block_k=BLOCK_K
        )
    )
    dense_fn = jax.jit(_dense_decode)

    for live in live_lengths:
        lens = jnp.full((B,), live, jnp.int32)
        t_kernel = timeit(kernel_fn, q, k, v, lens)
        t_dense = timeit(dense_fn, q, k, v, lens)

        cost = decode_attention_cost(
            B, HQ, HKV, live, max_len, D, block_k=BLOCK_K
        )
        # tokens/s for the whole batch at the measured per-step latency
        tokens_per_s = B / (t_kernel * 1e-6)
        rec = dict(
            live_length=live, max_len=max_len, block_k=BLOCK_K,
            b=B, hq=HQ, hkv=HKV, d=D,
            kernel_us=t_kernel, dense_us=t_dense,
            tokens_per_s=tokens_per_s,
            kv_bytes_per_token=cost["kv_bytes"],
            dense_kv_bytes_per_token=cost["dense_kv_bytes"],
            hbm_bytes_per_token=cost["hbm_bytes"],
            # Measured-vs-roofline: the achieved fraction of the analytic
            # lower bound (tiny on CPU interpret; ~O(1) on real TPUs —
            # regress.py bounds this per-backend).
            **utilization_columns(cost, t_kernel),
            **backend_info(),
        )
        records.append(rec)
        rows.append((
            f"decode/flash/len={live}", t_kernel,
            f"dense={t_dense:.0f}us tok/s={tokens_per_s:.0f} "
            f"kv_bytes={cost['kv_bytes']} (dense={cost['dense_kv_bytes']}) "
            f"{timing_label()}",
        ))

    # The acceptance ratio, recorded explicitly: live-length scaling in the
    # cost model (length=64 vs length=512 at the same max_len).
    c64 = decode_attention_cost(B, HQ, HKV, 64, max_len, D, block_k=BLOCK_K)
    c512 = decode_attention_cost(B, HQ, HKV, max_len, max_len, D,
                                 block_k=BLOCK_K)
    ratio = c512["kv_bytes"] / c64["kv_bytes"]
    records.append(dict(
        kind="kv_scaling", kv_bytes_ratio_512_vs_64=ratio, **backend_info(),
    ))
    rows.append((
        "decode/kv_scaling", 0.0,
        f"kv_bytes(len={max_len})/kv_bytes(len=64)={ratio:.1f}x",
    ))

    if not smoke:
        save_result("decode", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
