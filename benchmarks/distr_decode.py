"""Beyond-paper: fused-K̂ decode cache (serve.kv_cache) — KV-read bytes per
decode step and score fidelity vs the exact cache (EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import grouping
from repro.serve import kv_cache
from benchmarks.common import save_result


def run() -> list[tuple]:
    rows, records = [], []
    cfg = get_config("qwen2.5-32b")  # full dims; math only, tiny arrays below
    dh, hkv, hq = cfg.head_dim_, cfg.n_kv_heads, cfg.n_heads
    for g in (2, 4):
        # bytes read per cached token per decode step (per layer, kv head):
        # exact reads K+V; fused reads K̂+V (raw K stays cold for the score
        # stage and is only touched at eviction/rescoring).
        exact_bytes = 2 * dh * 2  # K + V bf16
        fused_bytes = (dh // g) * 2 + dh * 2  # K̂ bf16 + V bf16
        saving = 1 - fused_bytes / exact_bytes

        # fidelity on gaussian K/q with a static permutation
        perms = jax.random.permutation(jax.random.PRNGKey(0), dh)[None]
        perms = jnp.broadcast_to(perms, (hkv, dh)).astype(jnp.int32)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, hkv, 512, dh))
        q = jax.random.normal(jax.random.PRNGKey(2), (1, hq, 1, dh))
        k_f = grouping.fuse_columns(k.astype(jnp.float32), perms[None], g)
        q_s = kv_cache.sample_q(q, perms, g, hq // hkv)
        rep = hq // hkv
        s_apx = jnp.einsum("bhnd,bhmd->bhnm", q_s, jnp.repeat(k_f, rep, 1))
        s_ext = jnp.einsum("bhnd,bhmd->bhnm", q, jnp.repeat(k, rep, 1))
        corr = float(jnp.corrcoef(
            jnp.stack([s_apx.reshape(-1), s_ext.reshape(-1)])
        )[0, 1])
        records.append(dict(g=g, kv_byte_saving=saving, score_corr=corr))
        rows.append((
            f"distr_decode/G={g}", 0.0,
            f"kv_read_saving={saving*100:.1f}% score_corr={corr:.3f}",
        ))
    save_result("distr_decode", records)
    return rows
