"""Beyond-paper: fused-K̂ decode cache (serve.kv_cache) on the split-K
flash-decoding kernel — KV-read bytes per decode step, score fidelity vs the
exact cache, and kernel-vs-scan per-token latency at several live lengths
(EXPERIMENTS.md §Perf).

The fused variant stacks two savings: the ring cache's live-length grid
(bytes ∝ length, not max_len — benchmarks/decode.py) and the d/G*-wide
score-stage stream, (1−1/G*)·½ of KV traffic.  Timings are labeled by
backend/interpret — on this CPU container the kernel column is Pallas
interpreter wall time, not TPU time; the byte model carries the claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import grouping
from repro.core.flash_reference import reference_attention
from repro.kernels import ops
from repro.roofline.analysis import decode_attention_cost
from repro.serve import kv_cache
from benchmarks.common import backend_info, save_result, timeit, timing_label

MAX_LEN = 512
BLOCK_K = 64
LIVE_LENGTHS = (64, 256, 512)


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    max_len = 128 if smoke else MAX_LEN
    live_lengths = (64,) if smoke else LIVE_LENGTHS
    cfg = get_config("qwen2.5-32b")  # full head geometry; tiny batch below
    dh, hkv, hq = cfg.head_dim_, cfg.n_kv_heads, cfg.n_heads
    for g in ((2,) if smoke else (2, 4)):
        # bytes read per cached token per decode step (per layer, kv head):
        # exact reads K+V; fused reads K̂+V (raw K stays cold for the score
        # stage and is only touched at eviction/rescoring).
        exact_bytes = 2 * dh * 2  # K + V bf16
        fused_bytes = (dh // g) * 2 + dh * 2  # K̂ bf16 + V bf16
        saving = 1 - fused_bytes / exact_bytes

        # fidelity + latency on gaussian K/q with a static permutation
        perms = jax.random.permutation(jax.random.PRNGKey(0), dh)[None]
        perms = jnp.broadcast_to(perms, (hkv, dh)).astype(jnp.int32)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, hkv, max_len, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, hkv, max_len, dh))
        q = jax.random.normal(jax.random.PRNGKey(3), (1, hq, 1, dh))
        k_f = grouping.fuse_columns(k.astype(jnp.float32), perms[None], g)
        q_s = kv_cache.sample_q(q, perms, g, hq // hkv)
        rep = hq // hkv
        s_apx = jnp.einsum("bhnd,bhmd->bhnm", q_s, jnp.repeat(k_f, rep, 1))
        s_ext = jnp.einsum("bhnd,bhmd->bhnm", q, jnp.repeat(k, rep, 1))
        corr = float(jnp.corrcoef(
            jnp.stack([s_apx.reshape(-1), s_ext.reshape(-1)])
        )[0, 1])
        records.append(dict(
            g=g, kv_byte_saving=saving, score_corr=corr, **backend_info()
        ))
        rows.append((
            f"distr_decode/G={g}", 0.0,
            f"kv_read_saving={saving*100:.1f}% score_corr={corr:.3f}",
        ))

        # kernel op (fused-K̂ split-K decode) vs the pure-JAX scan path the
        # serve layer used before this op existed, at several live lengths.
        scale = 1.0 / dh ** 0.5
        kernel_fn = jax.jit(lambda q, kf, v, lens: ops.decode_attention(
            q, None, v, lengths=lens, k_fused=kf, perm=perms,
            group_size=g, scale=scale, block_k=BLOCK_K,
        ))

        def scan_fn(q, kf, v, lens):
            q_smp = kv_cache.sample_q(q, perms, g, hq // hkv)
            kv_mask = jnp.arange(max_len)[None, :] < lens[:, None]
            return reference_attention(
                q_smp, kf.astype(q_smp.dtype), v.astype(q_smp.dtype),
                causal=False, scale=scale, kv_mask=kv_mask,
            )

        scan_jit = jax.jit(scan_fn)
        for live in live_lengths:
            lens = jnp.full((1,), live, jnp.int32)
            t_kernel = timeit(kernel_fn, q, k_f.astype(q.dtype), v, lens)
            t_scan = timeit(scan_jit, q, k_f, v, lens)
            cost = decode_attention_cost(
                1, hq, hkv, live, max_len, dh, group_size=g, block_k=BLOCK_K
            )
            records.append(dict(
                g=g, live_length=live, max_len=max_len,
                kernel_us=t_kernel, scan_us=t_scan,
                kv_bytes_per_token=cost["kv_bytes"],
                dense_kv_bytes_per_token=cost["dense_kv_bytes"],
                **backend_info(),
            ))
            rows.append((
                f"distr_decode/G={g}/len={live}", t_kernel,
                f"scan={t_scan:.0f}us kv_bytes={cost['kv_bytes']} "
                f"{timing_label()}",
            ))
    if not smoke:
        save_result("distr_decode", records)
    return rows
