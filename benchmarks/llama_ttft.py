"""Paper Table 6: LM prefill time-to-first-token at varying prompt lengths,
exact vs DistrAttention (reduced llama-like config on CPU) — plus the serve
side of the same trajectory: per-token decode latency at several live
lengths, split-K decode kernel path vs the pure-JAX masked-scan path
(``impl="reference"``) that attends over the whole padded cache.

Timing rows carry backend/interpret labels (the kernel path runs in Pallas
interpreter mode off-TPU; the roofline story lives in
``roofline.analysis.decode_attention_cost`` / BENCH_decode.json).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.serve_step import make_decode_step, make_prefill
from benchmarks.common import backend_info, save_result, timeit, timing_label

MAX_LEN = 512
DECODE_LIVE = (64, 128, 256)


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    base = get_config("qwen1.5-4b", reduced=True).replace(
        n_layers=2 if smoke else 4, compute_dtype="float32"
    )
    max_len = 64 if smoke else MAX_LEN
    decode_live = (32,) if smoke else DECODE_LIVE
    params = lm.init_params(jax.random.PRNGKey(0), base)
    for impl in ("xla_flash", "distr"):
        cfg = base.replace(attention=base.attention.with_impl(impl))
        for n in ((64,) if smoke else (256, 512, 1024, 2048)):
            toks = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0, cfg.vocab)
            prefill = jax.jit(make_prefill(cfg, n))
            us = timeit(prefill, params, toks, warmup=1, iters=3)
            # xla_flash/distr prefill is pure XLA — always compiled, no
            # Pallas kernel involved.
            records.append(dict(impl=impl, n=n, us=us, kind="prefill",
                                **backend_info(False)))
            rows.append((f"ttft/{impl}/n={n}", us, f"prefill_tokens={n}"))

    # --- decode: per-token latency vs live length.  The kernel path (any
    # non-reference impl) walks ceil(length/block_k) KV blocks; the
    # reference path masks over all MAX_LEN slots every token.
    for impl in ("xla_flash", "reference"):
        cfg = base.replace(attention=base.attention.with_impl(impl))
        decode = jax.jit(make_decode_step(cfg))
        prefill = jax.jit(make_prefill(cfg, max_len))
        path = "kernel" if impl != "reference" else "scan"
        for live in decode_live:
            toks = jax.random.randint(
                jax.random.PRNGKey(2), (1, live), 0, cfg.vocab
            )
            _, cache = prefill(params, toks)
            pos = jnp.full((1,), live, jnp.int32)
            nxt = toks[:, -1:]
            us = timeit(decode, params, nxt, cache, pos, warmup=1, iters=3)
            records.append(dict(
                impl=impl, kind="decode", live_length=live, max_len=max_len,
                us_per_token=us,
                **backend_info(None if impl != "reference" else False),
            ))
            rows.append((
                f"decode_tok/{path}/len={live}", us,
                f"max_len={max_len} "
                + timing_label(None if path == "kernel" else False),
            ))
    if not smoke:
        save_result("llama_ttft", records)
    return rows
