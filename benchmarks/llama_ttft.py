"""Paper Table 6: LM prefill time-to-first-token at varying prompt lengths,
exact vs DistrAttention (reduced llama-like config on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.serve_step import make_prefill
from benchmarks.common import save_result, timeit


def run() -> list[tuple]:
    rows, records = [], []
    base = get_config("qwen1.5-4b", reduced=True).replace(
        n_layers=4, compute_dtype="float32"
    )
    params = lm.init_params(jax.random.PRNGKey(0), base)
    for impl in ("xla_flash", "distr"):
        cfg = base.replace(attention=base.attention.with_impl(impl))
        for n in (256, 512, 1024, 2048):
            toks = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0, cfg.vocab)
            prefill = jax.jit(make_prefill(cfg, n))
            us = timeit(prefill, params, toks, warmup=1, iters=3)
            records.append(dict(impl=impl, n=n, us=us))
            rows.append((f"ttft/{impl}/n={n}", us, f"prefill_tokens={n}"))
    save_result("llama_ttft", records)
    return rows
