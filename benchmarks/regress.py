"""Reference-bound regression gate over the persisted BENCH_*.json files.

The benchmark JSONs committed at the repo root are the recorded reference
for structural claims (goodput retention under replica loss, paged-over-
slot throughput).  This module re-reads them and fails — nonzero exit —
when a recorded number has dropped below its floor, so a regression in a
robustness or serving property cannot land silently behind a passing unit
suite: CI runs ``python -m benchmarks.regress`` right after the benchmark
smoke pass.

Bounds are declarative: a :class:`Bound` names the file, a record
selector (``kind`` plus optional extra field matches; ``kind=None``
selects rows in files whose records carry no ``kind`` field, and
``backend`` keys a bound to one backend's records), the metric, and a
floor plus optional ceiling — a *tolerance band*.  Floors are set from
the recorded reference run with headroom for benign drift — they gate
*collapses* (a failover path that stops retaining goodput), not noise;
ceilings gate impossibilities (a measured-vs-roofline utilization above
1.0 means the cost model or the timer is wrong).  Regenerating a BENCH
file with a legitimately different trade-off means revisiting the band
here, on purpose, in the same commit.
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass, field

ROOT = os.path.join(os.path.dirname(__file__), "..")


@dataclass(frozen=True)
class Bound:
    """``metric`` of every record matching ``kind`` (+ ``match`` fields,
    + ``backend``) in ``path`` must be ≥ ``floor`` and, when a ``ceiling``
    is set, ≤ ``ceiling``.  ``kind=None`` skips the kind filter — the
    selector for BENCH files whose per-row records carry no ``kind``."""

    path: str  # BENCH file, relative to the repo root
    kind: str | None  # record selector: record["kind"] == kind (None: all)
    metric: str
    floor: float
    match: tuple = field(default_factory=tuple)  # extra (key, value) pairs
    note: str = ""
    ceiling: float | None = None  # band upper bound (None: floor only)
    backend: str | None = None  # key the bound to one backend's records


#: The recorded floors.  BENCH_cluster.json reference (3 paged replicas,
#: replica 1 killed at tick 6, 60-tick e2e deadlines): healthy goodput
#: 1.00, kill retention 1.00, drain retention 1.00 — the deadline budget
#: absorbs one failover re-prefill.  The floors leave room for workload
#: tweaks but fail a collapse (lost redelivery → retention ≤ ~0.7).
BOUNDS = (
    Bound(
        path="BENCH_cluster.json", kind="summary",
        metric="kill_goodput_retention", floor=0.85,
        note="mid-run replica kill must retain most goodput via failover",
    ),
    Bound(
        path="BENCH_cluster.json", kind="summary",
        metric="drain_goodput_retention", floor=0.95,
        note="a planned drain migrates in place; near-zero goodput cost",
    ),
    Bound(
        path="BENCH_serving.json", kind="summary",
        metric="paged_over_slot_tokens_per_s", floor=1.0,
        note="continuous batching must not lose to slot serving at equal HBM",
    ),
    # BENCH_train_chaos.json reference (S=24 steps, checkpoint every 6,
    # kill at 14): kill resumes at 12 → goodput 0.923; a torn latest
    # checkpoint falls back to 6 → goodput 0.750; both resumes match the
    # uninterrupted loss trajectory bit-exactly (1.0).
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="kill_steps_retained_goodput", floor=0.85,
        note="a mid-run kill must only replay back to the latest checkpoint",
    ),
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="torn_steps_retained_goodput", floor=0.65,
        note="a torn latest checkpoint falls back one cadence, not to step 0",
    ),
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="resume_loss_match", floor=0.999,
        note="resume must reproduce the uninterrupted loss trajectory exactly",
    ),
    # BENCH_mesh.json reference (300-token prompt, prefill_chunk=32, 2-way
    # context ring): chunked prefill reaches the first token after 4
    # scheduler ticks, mesh whole-prompt admission emits it in the
    # admission tick itself (TTFT 0, clamped to 1 in the ratio) — recorded
    # ratio 4×.  A collapse back to chunked admission reads ~1×; the floor
    # fails that, not tick-count noise.
    Bound(
        path="BENCH_mesh.json", kind="summary",
        metric="chunked_over_mesh_ttft_ticks", floor=2.0,
        note="whole-prompt ring admission must collapse TTFT vs chunked",
    ),
    # ------------------------------------------------------------------
    # Per-row / per-backend bounds (BENCH schema v2).  The recorded
    # reference is the CPU-interpret run committed at the repo root, so
    # every row bound below is keyed backend="cpu"; a real-TPU
    # regeneration adds its own rows and its own bounds without touching
    # these.
    #
    # BENCH_cluster.json rows: all three policies complete 15/15 in the
    # healthy run; the kill scenario redelivers the 3 orphans of the dead
    # replica with zero failover failures; the drain migrates all 3.
    Bound(
        path="BENCH_cluster.json", kind="policy", metric="goodput",
        floor=0.9, match=(("policy", "p2c"),), backend="cpu",
        note="p2c routing must complete effectively all healthy requests",
    ),
    Bound(
        path="BENCH_cluster.json", kind="disruption", metric="redelivered",
        floor=1.0, match=(("scenario", "kill"),), backend="cpu",
        note="a replica kill must orphan and redeliver in-flight requests",
    ),
    Bound(
        path="BENCH_cluster.json", kind="disruption", metric="failover_failed",
        floor=0.0, ceiling=0.0, match=(("scenario", "kill"),), backend="cpu",
        note="failover after a kill must never exhaust redelivery attempts",
    ),
    Bound(
        path="BENCH_cluster.json", kind="disruption", metric="migrated",
        floor=1.0, match=(("scenario", "drain"),), backend="cpu",
        note="a planned drain must migrate the drained replica's requests",
    ),
    # BENCH_serving.json overload rows (24 requests, 16-tick TTFT
    # deadlines): the degrade controller trades precision for admission —
    # recorded goodput 0.625 vs exact 0.542, with 13 degraded prefills.
    Bound(
        path="BENCH_serving.json", kind="overload", metric="goodput",
        floor=0.5, match=(("controller", "degrade"),), backend="cpu",
        note="the degradation dial must buy goodput under overload",
    ),
    Bound(
        path="BENCH_serving.json", kind="overload", metric="degraded_prefills",
        floor=1.0, match=(("controller", "degrade"),), backend="cpu",
        note="the degrade controller must actually engage under overload",
    ),
    Bound(
        path="BENCH_serving.json", kind="overload", metric="deadline_miss_rate",
        floor=0.0, ceiling=0.25, match=(("controller", "exact"),),
        backend="cpu",
        note="shedding must keep admitted requests inside their deadlines",
    ),
    # BENCH_decode.json rows (no "kind" on per-length rows): the
    # measured-vs-roofline utilization band.  On CPU interpret the
    # achieved fraction of the analytic TPU lower bound is tiny (~1e-5)
    # but must be positive and can never exceed 1.0 — a value above the
    # ceiling means the cost model or the timer is wrong, a zero/negative
    # value means the columns stopped being emitted from measurements.
    Bound(
        path="BENCH_decode.json", kind=None, metric="roofline_util",
        floor=1e-9, ceiling=1.0, match=(("live_length", 64),), backend="cpu",
        note="achieved fraction of the roofline bound must be in (0, 1]",
    ),
    Bound(
        path="BENCH_decode.json", kind=None, metric="roofline_util",
        floor=1e-9, ceiling=1.0, match=(("live_length", 512),), backend="cpu",
        note="achieved fraction of the roofline bound must be in (0, 1]",
    ),
    Bound(
        path="BENCH_decode.json", kind="kv_scaling",
        metric="kv_bytes_ratio_512_vs_64", floor=4.0, backend="cpu",
        note="live-length KV scaling: ≥2× fewer bytes at 64 than 512",
    ),
    # BENCH_ring.json rows (no "kind"): the causal ring skips fully-masked
    # hops, so the hop count is exactly d(d+1)/2 — 36 for 8 devices, 1 for
    # a single device.  More hops = masking broke; fewer = steps skipped.
    Bound(
        path="BENCH_ring.json", kind=None, metric="hops",
        floor=8.0, ceiling=36.0, match=(("devices", 8),), backend="cpu",
        note="causal ring hop count is d(d+1)/2 = 36 on 8 devices",
    ),
    Bound(
        path="BENCH_ring.json", kind=None, metric="hops",
        floor=1.0, ceiling=1.0, match=(("devices", 1),), backend="cpu",
        note="a 1-device ring degenerates to the single local hop",
    ),
    # BENCH_attention_bwd.json distr rows: sampled fwd+bwd must do
    # strictly less MXU work than flash (ratio < 1) without collapsing
    # the computation — recorded 0.722 (g=2) and 0.583 (g=4).
    Bound(
        path="BENCH_attention_bwd.json", kind="distr",
        metric="fwd_bwd_mxu_ratio_vs_flash", floor=0.5, ceiling=0.95,
        match=(("n", 128), ("g", 2)), backend="cpu",
        note="g=2 sampling must cut MXU flops vs flash, not collapse them",
    ),
    Bound(
        path="BENCH_attention_bwd.json", kind="distr",
        metric="fwd_bwd_mxu_ratio_vs_flash", floor=0.4, ceiling=0.8,
        match=(("n", 256), ("g", 4)), backend="cpu",
        note="g=4 sampling must cut MXU flops deeper than g=2",
    ),
    # BENCH_autotune.json rows (no "kind"): the tuned pick must never lose
    # to the default configuration beyond noise (recorded speedups 1.0 —
    # 1.75; the cache makes the default a candidate, so < 1 is a bug).
    Bound(
        path="BENCH_autotune.json", kind=None, metric="speedup_vs_default",
        floor=0.95, match=(("kernel", "distr_fwd"),), backend="cpu",
        note="autotuned distr_fwd must not lose to the default config",
    ),
    Bound(
        path="BENCH_autotune.json", kind=None, metric="speedup_vs_default",
        floor=0.95, match=(("kernel", "decode"), ("d", 64)), backend="cpu",
        note="autotuned decode must not lose to the default block_k",
    ),
    # BENCH_mesh.json rows: whole-prompt ring admission must emit the
    # first token in the admission tick (TTFT ≤ 1) via mesh prefills.
    Bound(
        path="BENCH_mesh.json", kind=None, metric="mesh_prefills",
        floor=1.0, match=(("mode", "ring_into_paged"),), backend="cpu",
        note="ring_into_paged must route prompts through the mesh path",
    ),
    Bound(
        path="BENCH_mesh.json", kind=None, metric="ttft_ticks",
        floor=0.0, ceiling=1.0, match=(("mode", "ring_into_paged"),),
        backend="cpu",
        note="whole-prompt admission emits the first token immediately",
    ),
    # BENCH_train_chaos.json scenario rows: a kill replays at most one
    # checkpoint cadence (recorded 2 recovery steps, ckpt_every=6); the
    # torn-checkpoint scenario must exercise the fallback path.
    Bound(
        path="BENCH_train_chaos.json", kind="scenario",
        metric="recovery_steps", floor=0.0, ceiling=6.0,
        match=(("scenario", "kill_resume"),), backend="cpu",
        note="kill replay is bounded by the checkpoint cadence",
    ),
    Bound(
        path="BENCH_train_chaos.json", kind="scenario",
        metric="torn_ckpt_fallbacks", floor=1.0,
        match=(("scenario", "torn_resume"),), backend="cpu",
        note="the torn scenario must hit the verified-fallback path",
    ),
    # Schema stamp: every record in every bounded family must carry the
    # v2 stamp (kind=None + empty match selects all rows in the file; a
    # record without the field fails with "lacks metric").
    *[
        Bound(
            path=p, kind=None, metric="schema", floor=2.0, ceiling=2.0,
            note="all BENCH records must carry the v2 schema stamp",
        )
        for p in (
            "BENCH_attention_bwd.json", "BENCH_autotune.json",
            "BENCH_cluster.json", "BENCH_decode.json", "BENCH_mesh.json",
            "BENCH_ring.json", "BENCH_serving.json",
            "BENCH_train_chaos.json",
        )
    ],
)


def _select(records: list[dict], bound: Bound) -> list[dict]:
    out = []
    for rec in records:
        if bound.kind is not None and rec.get("kind") != bound.kind:
            continue
        if bound.backend is not None and rec.get("backend") != bound.backend:
            continue
        if all(rec.get(k) == v for k, v in bound.match):
            out.append(rec)
    return out


def _selector(bound: Bound) -> str:
    """Human-readable record selector for failure messages."""
    sel = dict(bound.match)
    if bound.backend is not None:
        sel["backend"] = bound.backend
    return f"kind={bound.kind!r} record matching {sel}"


def check_bound(records: list[dict], bound: Bound) -> list[str]:
    """Failure messages for one bound against loaded records ([] = pass)."""
    matches = _select(records, bound)
    if not matches:
        return [f"{bound.path}: no {_selector(bound)} "
                f"(metric {bound.metric})"]
    failures = []
    for rec in matches:
        val = rec.get(bound.metric)
        if val is None:
            failures.append(
                f"{bound.path}: kind={bound.kind!r} record lacks "
                f"metric {bound.metric!r}"
            )
        elif float(val) < bound.floor:
            failures.append(
                f"{bound.path}: {bound.metric} = {float(val):.3f} "
                f"< floor {bound.floor:.3f}"
                + (f" ({bound.note})" if bound.note else "")
            )
        elif bound.ceiling is not None and float(val) > bound.ceiling:
            failures.append(
                f"{bound.path}: {bound.metric} = {float(val):.3f} "
                f"> ceiling {bound.ceiling:.3f}"
                + (f" ({bound.note})" if bound.note else "")
            )
    return failures


def check_all(bounds=BOUNDS, root: str = ROOT) -> list[str]:
    """All failure messages across ``bounds`` (missing file = failure:
    every bounded BENCH file is committed at the repo root)."""
    failures: list[str] = []
    by_path: dict[str, list[dict] | None] = {}
    for bound in bounds:
        if bound.path not in by_path:
            full = os.path.join(root, bound.path)
            try:
                with open(full) as f:
                    by_path[bound.path] = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                by_path[bound.path] = None
                failures.append(f"{bound.path}: unreadable ({e})")
        records = by_path[bound.path]
        if records is not None:
            failures.extend(check_bound(records, bound))
    return failures


def main() -> int:
    failures = check_all()
    for msg in failures:
        print(f"REGRESS FAIL {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"regress: {len(BOUNDS)} bound(s) hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
