"""Reference-bound regression gate over the persisted BENCH_*.json files.

The benchmark JSONs committed at the repo root are the recorded reference
for structural claims (goodput retention under replica loss, paged-over-
slot throughput).  This module re-reads them and fails — nonzero exit —
when a recorded number has dropped below its floor, so a regression in a
robustness or serving property cannot land silently behind a passing unit
suite: CI runs ``python -m benchmarks.regress`` right after the benchmark
smoke pass.

Bounds are declarative: a :class:`Bound` names the file, a record
selector (``kind`` plus optional extra field matches), the metric, and
the floor.  Floors are set from the recorded reference run with headroom
for benign drift — they gate *collapses* (a failover path that stops
retaining goodput), not noise.  Regenerating a BENCH file with a
legitimately different trade-off means revisiting the floor here, on
purpose, in the same commit.
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass, field

ROOT = os.path.join(os.path.dirname(__file__), "..")


@dataclass(frozen=True)
class Bound:
    """``metric`` of the record matching ``kind`` (+ ``match`` fields) in
    ``path`` must be ≥ ``floor``."""

    path: str  # BENCH file, relative to the repo root
    kind: str  # record selector: record["kind"] == kind
    metric: str
    floor: float
    match: tuple = field(default_factory=tuple)  # extra (key, value) pairs
    note: str = ""


#: The recorded floors.  BENCH_cluster.json reference (3 paged replicas,
#: replica 1 killed at tick 6, 60-tick e2e deadlines): healthy goodput
#: 1.00, kill retention 1.00, drain retention 1.00 — the deadline budget
#: absorbs one failover re-prefill.  The floors leave room for workload
#: tweaks but fail a collapse (lost redelivery → retention ≤ ~0.7).
BOUNDS = (
    Bound(
        path="BENCH_cluster.json", kind="summary",
        metric="kill_goodput_retention", floor=0.85,
        note="mid-run replica kill must retain most goodput via failover",
    ),
    Bound(
        path="BENCH_cluster.json", kind="summary",
        metric="drain_goodput_retention", floor=0.95,
        note="a planned drain migrates in place; near-zero goodput cost",
    ),
    Bound(
        path="BENCH_serving.json", kind="summary",
        metric="paged_over_slot_tokens_per_s", floor=1.0,
        note="continuous batching must not lose to slot serving at equal HBM",
    ),
    # BENCH_train_chaos.json reference (S=24 steps, checkpoint every 6,
    # kill at 14): kill resumes at 12 → goodput 0.923; a torn latest
    # checkpoint falls back to 6 → goodput 0.750; both resumes match the
    # uninterrupted loss trajectory bit-exactly (1.0).
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="kill_steps_retained_goodput", floor=0.85,
        note="a mid-run kill must only replay back to the latest checkpoint",
    ),
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="torn_steps_retained_goodput", floor=0.65,
        note="a torn latest checkpoint falls back one cadence, not to step 0",
    ),
    Bound(
        path="BENCH_train_chaos.json", kind="summary",
        metric="resume_loss_match", floor=0.999,
        note="resume must reproduce the uninterrupted loss trajectory exactly",
    ),
    # BENCH_mesh.json reference (300-token prompt, prefill_chunk=32, 2-way
    # context ring): chunked prefill reaches the first token after 4
    # scheduler ticks, mesh whole-prompt admission emits it in the
    # admission tick itself (TTFT 0, clamped to 1 in the ratio) — recorded
    # ratio 4×.  A collapse back to chunked admission reads ~1×; the floor
    # fails that, not tick-count noise.
    Bound(
        path="BENCH_mesh.json", kind="summary",
        metric="chunked_over_mesh_ttft_ticks", floor=2.0,
        note="whole-prompt ring admission must collapse TTFT vs chunked",
    ),
)


def _select(records: list[dict], bound: Bound) -> list[dict]:
    out = []
    for rec in records:
        if rec.get("kind") != bound.kind:
            continue
        if all(rec.get(k) == v for k, v in bound.match):
            out.append(rec)
    return out


def check_bound(records: list[dict], bound: Bound) -> list[str]:
    """Failure messages for one bound against loaded records ([] = pass)."""
    matches = _select(records, bound)
    if not matches:
        return [f"{bound.path}: no kind={bound.kind!r} record "
                f"matching {dict(bound.match)} (metric {bound.metric})"]
    failures = []
    for rec in matches:
        val = rec.get(bound.metric)
        if val is None:
            failures.append(
                f"{bound.path}: kind={bound.kind!r} record lacks "
                f"metric {bound.metric!r}"
            )
        elif float(val) < bound.floor:
            failures.append(
                f"{bound.path}: {bound.metric} = {float(val):.3f} "
                f"< floor {bound.floor:.3f}"
                + (f" ({bound.note})" if bound.note else "")
            )
    return failures


def check_all(bounds=BOUNDS, root: str = ROOT) -> list[str]:
    """All failure messages across ``bounds`` (missing file = failure:
    every bounded BENCH file is committed at the repo root)."""
    failures: list[str] = []
    by_path: dict[str, list[dict] | None] = {}
    for bound in bounds:
        if bound.path not in by_path:
            full = os.path.join(root, bound.path)
            try:
                with open(full) as f:
                    by_path[bound.path] = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                by_path[bound.path] = None
                failures.append(f"{bound.path}: unreadable ({e})")
        records = by_path[bound.path]
        if records is not None:
            failures.extend(check_bound(records, bound))
    return failures


def main() -> int:
    failures = check_all()
    for msg in failures:
        print(f"REGRESS FAIL {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"regress: {len(BOUNDS)} bound(s) hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
