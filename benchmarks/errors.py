"""Paper Tables 3 & 4: error of Ŝ vs S under varying block size l and
sampling rate G* (uniform(0,1) Q/K, N=64, d=64, repeated trials).

Reported per config and hash method:
  S-err   — mean |Ŝ−S|/|S| on raw scores,
  O-err   — mean relative error of softmax(Ŝ/√d)V vs exact (the metric whose
            magnitude and l-insensitivity match the paper's numbers; see
            EXPERIMENTS.md §Repro-notes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistrConfig, distr_scores
from benchmarks.common import save_result

N, D, TRIALS = 64, 64, 30


def _one_trial(seed, cfg):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.uniform(kq, (1, 1, N, D))
    k = jax.random.uniform(kk, (1, 1, N, D))
    v = jax.random.uniform(kv, (1, 1, N, D))
    s = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    s_hat = distr_scores(q, k, cfg)
    scale = 1.0 / (D**0.5)
    p = jax.nn.softmax(s * scale, -1)
    p_hat = jax.nn.softmax(s_hat * scale, -1)
    o = p @ v
    o_hat = p_hat @ v
    s_rel = jnp.abs(s_hat - s) / jnp.abs(s)
    o_rel = jnp.abs(o_hat - o) / jnp.abs(o)
    return (
        float(s_rel.mean()), float(s_rel.max()),
        float(o_rel.mean()), float(o_rel.max()),
    )


def run(smoke: bool = False) -> list[tuple]:
    rows_out, records = [], []
    trials = 2 if smoke else TRIALS
    for method in (("sign_gray",) if smoke else ("sign_gray", "proj_morton")):
        # Table 3: vary block size l at G*=2
        for l in ((2,) if smoke else (1, 2, 4, 8)):
            cfg = DistrConfig(group_size=2, block_q=l, hash_method=method)
            r = np.mean([_one_trial(s, cfg) for s in range(trials)], axis=0)
            rec = dict(table="T3", method=method, l=l, g=2,
                       s_mean=r[0], s_max=r[1], o_mean=r[2], o_max=r[3])
            records.append(rec)
            rows_out.append((
                f"errors/T3/{method}/l={l}", 0.0,
                f"S-mean={r[0]*100:.2f}% O-mean={r[2]*100:.2f}% O-max={r[3]*100:.2f}%",
            ))
        # Table 4: vary G* at l=2
        for g in ((2,) if smoke else (2, 4, 8, 16)):
            cfg = DistrConfig(group_size=g, block_q=2, hash_method=method)
            r = np.mean([_one_trial(s, cfg) for s in range(trials)], axis=0)
            rec = dict(table="T4", method=method, l=2, g=g,
                       s_mean=r[0], s_max=r[1], o_mean=r[2], o_max=r[3])
            records.append(rec)
            rows_out.append((
                f"errors/T4/{method}/G={g}", 0.0,
                f"S-mean={r[0]*100:.2f}% O-mean={r[2]*100:.2f}% O-max={r[3]*100:.2f}%",
            ))
    if not smoke:
        save_result("errors", records)
    return rows_out
