"""Paper Fig. 9 / Table 1: attention compute time, Flash2 vs DistrAttention.

CPU wall time is not TPU time, so this reports BOTH:
  us        — measured XLA-CPU wall time (relative trend),
  derived   — MXU-FLOP ratio from the kernel cost model and the projected
              v5e score-stage time (the roofline-honest comparison).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import AttentionConfig, DistrConfig, attend
from repro.kernels.ops import attention_cost
from repro.roofline.analysis import PEAK_FLOPS
from benchmarks.common import save_result, timeit

B, H = 1, 10  # paper §4.5: batch 1, 10 heads


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    for d in ((32,) if smoke else (32, 64, 128)):
        for n in ((256,) if smoke else (1024, 2048, 4096)):
            q = jax.random.normal(jax.random.PRNGKey(0), (B, H, n, d), jnp.float32)
            k = jax.random.normal(jax.random.PRNGKey(1), (B, H, n, d), jnp.float32)
            v = jax.random.normal(jax.random.PRNGKey(2), (B, H, n, d), jnp.float32)

            flash_cfg = AttentionConfig(impl="xla_flash", block_q=128, block_k=128)
            flash = jax.jit(functools.partial(attend, cfg=flash_cfg, causal=True))
            t_flash = timeit(flash, q, k, v)

            for g in (2, 4):
                if d // g < 16:
                    continue  # paper §4.5 skips d=32, G*=4 (tensor-core floor)
                cfg = AttentionConfig(
                    impl="distr",
                    distr=DistrConfig(group_size=g, block_q=128, block_k=128),
                )
                distr = jax.jit(functools.partial(attend, cfg=cfg, causal=True))
                t_distr = timeit(distr, q, k, v)

                c_f = attention_cost(B, H, n, n, d, causal=True)
                c_d = attention_cost(B, H, n, n, d, causal=True, group_size=g)
                mxu_ratio = c_d["mxu_flops"] / c_f["mxu_flops"]
                v5e_flash_us = c_f["mxu_flops"] / PEAK_FLOPS * 1e6
                v5e_distr_us = c_d["mxu_flops"] / PEAK_FLOPS * 1e6
                rec = dict(
                    d=d, n=n, g=g, cpu_flash_us=t_flash, cpu_distr_us=t_distr,
                    mxu_flops_ratio=mxu_ratio,
                    v5e_flash_us=v5e_flash_us, v5e_distr_us=v5e_distr_us,
                )
                records.append(rec)
                rows.append((
                    f"attn_time/d={d}/n={n}/G={g}", t_distr,
                    f"flash_cpu={t_flash:.0f}us mxu_ratio={mxu_ratio:.3f} "
                    f"v5e_proj={v5e_distr_us:.1f}us_vs_{v5e_flash_us:.1f}us",
                ))
    if not smoke:
        save_result("attention_time", records)
    return rows
