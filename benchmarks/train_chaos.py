"""Train-chaos benchmark: checkpoint-resume goodput under mid-run kills
(ISSUE 8 acceptance).

One real reduced-config Trainer on the deterministic synthetic stream,
driven through two disruption scenarios against an uninterrupted baseline:

  baseline     — train S steps straight (periodic checkpoints every E).
  kill_resume  — kill the process at step k (no final save), restart from
      the workdir: resume lands on the newest *verified* checkpoint r and
      re-trains k−r steps it had already done.  ``steps_retained_goodput``
      = S / (S + (k − r)) — the fraction of total step work that was not
      thrown away.
  torn_resume  — same kill, but the latest checkpoint published torn
      (``ckpt_torn_write`` at its step): resume must *fall back* to the
      newest checkpoint that verifies, paying a bigger replay window but
      never resuming garbage.

Because model init and the data stream are deterministic, a correct resume
is bit-identical to the baseline — ``resume_loss_match`` records the
fraction of per-step losses that match exactly, and the summary's
``steps_retained_goodput``/``resume_loss_match`` floors are gated by
``benchmarks/regress.py`` so a resume regression (checkpoint cadence
silently broken, fallback resuming garbage) cannot land behind passing
unit tests.

Emits ``BENCH_train_chaos.json`` at the repo root and
``benchmarks/results/train_chaos.json``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

from benchmarks.common import backend_info, save_result
from repro.configs import get_config
from repro.faults import FaultInjector, FaultSpec
from repro.train.anomaly import AnomalyConfig
from repro.train.data import SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer

BENCH_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_train_chaos.json"
)

TOTAL_STEPS = 24   # S: target step count of every scenario
CKPT_EVERY = 6     # E: periodic checkpoint cadence
KILL_AT = 14       # k: the mid-run kill lands between checkpoints 12 and 18


def _trainer(workdir: str, ckpt_every: int, faults=None) -> Trainer:
    cfg = get_config("minicpm-2b", reduced=True)
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                          total_steps=TOTAL_STEPS)
    data = SyntheticLMData(cfg.vocab, 2, 16, seed=0)
    return Trainer(cfg, opt, data, workdir=workdir, log_every=10_000,
                   ckpt_every=ckpt_every, faults=faults,
                   anomaly=AnomalyConfig(enabled=False))


def _train_to(tr: Trainer, target: int) -> None:
    while tr.step < target:
        tr.step_once()


def _loss_match(hist: list[dict], baseline: dict[int, float]) -> float:
    """Fraction of history records whose loss EXACTLY matches the baseline
    at the same step (determinism makes ≈ the wrong tool)."""
    if not hist:
        return 0.0
    hits = sum(1 for r in hist if baseline.get(r["step"]) == r["loss"])
    return hits / len(hist)


def _scenario(total: int, ckpt_every: int, kill_at: int, baseline_losses,
              *, torn: bool) -> dict:
    workdir = tempfile.mkdtemp(prefix="bench_train_chaos_")
    try:
        faults = None
        if torn:
            # tear the newest pre-kill checkpoint as it publishes
            torn_step = (kill_at // ckpt_every) * ckpt_every
            faults = FaultInjector(
                [FaultSpec("ckpt_torn_write", uid=torn_step)]
            )
        t0 = time.perf_counter()
        first = _trainer(workdir, ckpt_every, faults=faults)
        _train_to(first, kill_at)
        del first  # the "kill": no final/emergency save happens

        resumed = _trainer(workdir, ckpt_every)
        resume_step = resumed.step
        _train_to(resumed, total)
        wall = time.perf_counter() - t0

        replay = kill_at - resume_step
        return {
            "total_steps": total,
            "ckpt_every": ckpt_every,
            "kill_at": kill_at,
            "resume_step": resume_step,
            "recovery_steps": replay,
            "steps_retained_goodput": total / (total + replay),
            "resume_loss_match": _loss_match(resumed.history,
                                             baseline_losses),
            "torn_ckpt_fallbacks":
                resumed.counters_snapshot()["torn_ckpt_fallbacks"],
            "wall_s": wall,
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def run(smoke: bool = False) -> list[tuple]:
    total = 6 if smoke else TOTAL_STEPS
    every = 2 if smoke else CKPT_EVERY
    kill_at = 5 if smoke else KILL_AT

    # -- uninterrupted baseline (also the reference loss trajectory) ------
    workdir = tempfile.mkdtemp(prefix="bench_train_chaos_")
    try:
        t0 = time.perf_counter()
        base = _trainer(workdir, every)
        _train_to(base, total)
        base_wall = time.perf_counter() - t0
        baseline_losses = {r["step"]: r["loss"] for r in base.history}
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    kill = _scenario(total, every, kill_at, baseline_losses, torn=False)
    torn = _scenario(total, every, kill_at, baseline_losses, torn=True)
    assert torn["resume_step"] < kill["resume_step"] or smoke, \
        "torn latest checkpoint must force a deeper fallback"

    records = [
        dict(kind="baseline", total_steps=total, ckpt_every=every,
             wall_s=base_wall, **backend_info()),
        dict(kind="scenario", scenario="kill_resume", **kill,
             **backend_info()),
        dict(kind="scenario", scenario="torn_resume", **torn,
             **backend_info()),
        dict(
            kind="summary",
            kill_steps_retained_goodput=kill["steps_retained_goodput"],
            torn_steps_retained_goodput=torn["steps_retained_goodput"],
            resume_loss_match=min(kill["resume_loss_match"],
                                  torn["resume_loss_match"]),
            kill_recovery_steps=kill["recovery_steps"],
            torn_recovery_steps=torn["recovery_steps"],
            total_steps=total, ckpt_every=every, kill_at=kill_at,
            **backend_info(),
        ),
    ]

    rows = [
        (
            "train_chaos/kill_resume", kill["wall_s"] * 1e6,
            f"goodput={kill['steps_retained_goodput']:.3f} "
            f"resume@{kill['resume_step']} replay={kill['recovery_steps']} "
            f"loss_match={kill['resume_loss_match']:.3f}",
        ),
        (
            "train_chaos/torn_resume", torn["wall_s"] * 1e6,
            f"goodput={torn['steps_retained_goodput']:.3f} "
            f"resume@{torn['resume_step']} replay={torn['recovery_steps']} "
            f"fallbacks={torn['torn_ckpt_fallbacks']} "
            f"loss_match={torn['resume_loss_match']:.3f}",
        ),
    ]

    if not smoke:
        save_result("train_chaos", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
