"""Paper §4.8: cost of the LSH-based grouping stage relative to the full
attention computation, across sequence lengths."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import AttentionConfig, DistrConfig, attend
from repro.core.distr_attention import compute_block_permutations
from benchmarks.common import save_result, timeit

D, H = 128, 4


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    cfg = DistrConfig(group_size=2, block_q=128, block_k=128)
    attn_cfg = AttentionConfig(impl="distr", distr=cfg)
    for n in ((512,) if smoke else (2048, 4096, 8192)):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, H, n, D), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, H, n, D), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2), (1, H, n, D), jnp.float32)

        group_fn = jax.jit(functools.partial(compute_block_permutations, cfg=cfg))
        t_group = timeit(group_fn, q)
        full_fn = jax.jit(functools.partial(attend, cfg=attn_cfg, causal=True))
        t_full = timeit(full_fn, q, k, v)
        frac = t_group / t_full * 100
        records.append(dict(n=n, group_us=t_group, total_us=t_full, pct=frac))
        rows.append((
            f"lsh_grouping/n={n}", t_group,
            f"total={t_full:.0f}us share={frac:.1f}%",
        ))
    if not smoke:
        save_result("lsh_grouping", records)
    return rows
