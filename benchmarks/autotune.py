"""Tuned-vs-default block sizes, measured (the autotuner acceptance row).

For every (kernel, shape) row the tuner sweeps the analytic top-K candidate
set *plus the 128×128 default* in one measurement pass and picks the argmin
— so the tuned configuration's throughput is ≥ the default's on the same
axis by construction, and the interesting signal is the margin and where
the pick lands (the analytic model already predicts non-128 tiles at d=64
and G*=4).  Timings carry ``backend``/``interpret`` labels like every other
bench: on this container they are Pallas-interpreter (or XLA-CPU) wall
times, not TPU times — the *ranking* inside one row is the claim, not the
absolute numbers.

Emits ``BENCH_autotune.json`` at the repo root and
``benchmarks/results/autotune.json``.
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.tune import Autotuner, TuneCache, cache_key, wall_timer
from repro.tune.autotune import _backend_tag, _default_interpret
from benchmarks.common import backend_info, save_result, timing_label

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_autotune.json")

# (kernel, d, n, group_size, causal)
ROWS = [
    ("flash_fwd", 64, 512, 1, True),
    ("flash_fwd", 128, 256, 1, True),
    ("flash_dq", 64, 256, 1, True),
    ("flash_dkv", 64, 256, 1, True),
    ("xla_flash", 64, 512, 1, True),
    ("distr_fwd", 128, 256, 4, True),
    ("decode", 64, 512, 1, False),
    ("decode", 128, 512, 4, False),
]
SMOKE_ROWS = [
    ("flash_fwd", 64, 128, 1, True),
    ("decode", 64, 128, 1, False),
]


def _measure_row(tuner: Autotuner, kernel, d, n, g, causal, interpret):
    """Resolve one key in measure mode and pull the per-candidate table out
    of the cache entry (default and tuned timings come from the SAME pass)."""
    if kernel == "decode":
        tuned = tuner.resolve_decode(d=d, n=n, group_size=g, dtype="float32")
        key = cache_key(
            "decode", backend=_backend_tag(interpret), dtype="float32", d=d,
            group_size=g, n=tuner._measure_seq(n, interpret), causal=False,
        )
    else:
        tuned = tuner.resolve_pair(
            kernel, d=d, n=n, group_size=g, causal=causal, dtype="float32"
        )
        key = cache_key(
            kernel, backend=_backend_tag(interpret), dtype="float32", d=d,
            group_size=g, n=tuner._measure_seq(n, interpret), causal=causal,
        )
    entry = tuner.cache.get(key)
    table = {
        tuple(r["candidate"]) if isinstance(r["candidate"], list)
        else r["candidate"]: r["seconds"]
        for r in entry["table"]
    }
    default = (128, 128) if kernel != "decode" else min(128, n)
    default_s = table.get(default)
    tuned_key = tuple(tuned) if isinstance(tuned, tuple) else tuned
    tuned_s = table[tuned_key]
    return tuned, tuned_s, default, default_s, entry["table"]


def run(smoke: bool = False) -> list[tuple]:
    rows_out, records = [], []
    prev = os.environ.get("REPRO_TUNE")
    os.environ["REPRO_TUNE"] = "measure"
    interpret = _default_interpret()
    try:
        with tempfile.TemporaryDirectory() as tmp:
            # Fresh cache: every run re-measures on the current backend.
            tuner = Autotuner(
                cache=TuneCache(os.path.join(tmp, "tune.json")),
                timer=wall_timer(warmup=1, iters=2 if smoke else 3),
                top_k=3 if smoke else 8,
            )
            for kernel, d, n, g, causal in (SMOKE_ROWS if smoke else ROWS):
                tuned, tuned_s, default, default_s, table = _measure_row(
                    tuner, kernel, d, n, g, causal, interpret
                )
                # default_s is None only if the 128-default itself failed to
                # run (measure_candidates skips broken candidates).
                speedup = (default_s / tuned_s) if default_s else float("nan")
                default_us = default_s * 1e6 if default_s else float("nan")
                rec = dict(
                    kernel=kernel, d=d, n=n, group_size=g, causal=causal,
                    tuned_blocks=tuned, tuned_us=tuned_s * 1e6,
                    default_blocks=default,
                    default_us=default_s * 1e6 if default_s else None,
                    speedup_vs_default=speedup,
                    table=table,
                    **backend_info(interpret),
                )
                records.append(rec)
                rows_out.append((
                    f"autotune/{kernel}/d={d}/n={n}/g={g}",
                    tuned_s * 1e6,
                    f"tuned={tuned} default_us={default_us:.0f} "
                    f"speedup={speedup:.2f}x {timing_label(interpret)}",
                ))
    finally:
        if prev is None:
            os.environ.pop("REPRO_TUNE", None)
        else:
            os.environ["REPRO_TUNE"] = prev
    if not smoke:
        save_result("autotune", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows_out
