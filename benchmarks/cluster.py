"""Cluster benchmark: routing policies and goodput retention under
replica loss (ISSUE 7 acceptance).

Three real ``PagedServeEngine`` replicas serve one mixed-length workload
through ``serve.cluster.ClusterRouter`` on a shared tick-domain clock
(router and engines see the same injected clock; one router tick = one
step of every live replica), with per-request end-to-end deadlines — so
goodput, TTFT percentiles, and failover cost are deterministic and the
numbers measure the POLICY, not CPU-interpret wall time.

Scenarios:

  healthy/{round_robin,least_queue,p2c}  — routing-policy comparison on an
      intact cluster: goodput, tokens/s (wall), TTFT p50/p99 in ticks.
  kill    — replica 1 crashes mid-run (``replica_crash`` fault): the
      router detects the death via missed heartbeats and redelivers the
      replica's in-flight requests to survivors as extended prefills.
      Requests whose remaining deadline cannot absorb the re-prefill
      expire — the goodput gap vs healthy is the price of the crash.
  drain   — replica 1 is drained (migrate=True) at the same tick instead:
      a *planned* removal fences admission and migrates in-flight work
      immediately, so retention should beat the crash scenario (no
      heartbeat-detection window).

The summary records ``kill_goodput_retention`` and
``drain_goodput_retention`` (scenario goodput / healthy round-robin
goodput) — ``benchmarks/regress.py`` gates the kill number against a
recorded floor so a failover regression cannot land silently.

Emits ``BENCH_cluster.json`` at the repo root and
``benchmarks/results/cluster.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import backend_info, save_result, timing_label
from repro.configs import get_config
from repro.models import lm
from repro.serve import lifecycle
from repro.serve.cluster import ClusterRouter
from repro.serve.engine import PagedServeEngine
from repro.serve.faults import FaultInjector, FaultSpec

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_cluster.json")

N_REPLICAS = 3
MAX_LEN = 64
MAX_BATCH = 2  # lanes per replica
BLOCK_SIZE = 16
PREFILL_CHUNK = 8
MAX_NEW = 5
DEADLINE_E2E = 60  # ticks; generous for a healthy run, tight across a crash
DISRUPT_AFTER = 6  # tick of the crash / drain
POLICIES = ("round_robin", "least_queue", "p2c")


class _TickClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _workload(smoke: bool):
    n = 6 if smoke else 15
    rng = np.random.RandomState(0)
    lengths = rng.choice([6, 9, 14, 20, 28], size=n)
    return [list(rng.randint(1, 500, size=int(ln))) for ln in lengths]


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p))


def _scenario(cfg, params, prompts, *, policy="round_robin", faults=None,
              drain_rid=None, disrupt_tick=DISRUPT_AFTER,
              n_replicas=N_REPLICAS, max_new=MAX_NEW):
    clock = _TickClock()
    engines = [
        PagedServeEngine(
            cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, prefill_chunk=PREFILL_CHUNK, clock=clock,
        )
        for _ in range(n_replicas)
    ]
    router = ClusterRouter(engines, policy=policy, policy_seed=0,
                           clock=clock, faults=faults)
    for p in prompts:
        router.add_request(p, max_new_tokens=max_new,
                           deadline_e2e=DEADLINE_E2E)
    t0 = time.perf_counter()
    for _tick in range(2000):
        router.tick()
        clock.t += 1
        if drain_rid is not None and clock.t == disrupt_tick:
            router.drain(drain_rid, migrate=True)
        if not router.has_work():
            break
    wall = time.perf_counter() - t0
    assert not router.has_work(), "cluster scenario did not drain"

    rows = router.metrics()
    done = sum(r["status"] == lifecycle.DONE for r in rows)
    tokens = sum(r["n_generated"] for r in rows)
    ttfts = [r["ttft_s"] for r in rows if r["ttft_s"] is not None]
    snap = router.counters_snapshot()
    return {
        "n_replicas": n_replicas,
        "n_requests": len(prompts),
        "deadline_e2e_ticks": DEADLINE_E2E,
        "completed": done,
        "goodput": done / len(prompts),
        "tokens": tokens,
        "tokens_per_s": tokens / wall,
        "ttft_p50_ticks": _percentile(ttfts, 50) if ttfts else None,
        "ttft_p99_ticks": _percentile(ttfts, 99) if ttfts else None,
        "replica_deaths": snap["replica_deaths"],
        "redelivered": snap["redelivered"],
        "migrated": snap["migrated"],
        "failover_failed": snap["failover_failed"],
        "expired": sum(r["status"] == lifecycle.EXPIRED for r in rows),
        "ticks": clock.t,
        "wall_s": wall,
    }


def run(smoke: bool = False) -> list[tuple]:
    prompts = _workload(smoke)
    cfg = get_config("qwen2.5-32b", reduced=True)  # GQA, paged-servable
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    policies = ("round_robin",) if smoke else POLICIES
    # The smoke workload drains fast: disrupt early so the crash/drain
    # paths (death detection, redelivery, migration) still execute.
    disrupt = 2 if smoke else DISRUPT_AFTER

    rows, records = [], []

    # -- healthy cluster: policy comparison -------------------------------
    healthy = {}
    for policy in policies:
        r = _scenario(cfg, params, prompts, policy=policy)
        healthy[policy] = r
        records.append(dict(kind="policy", scenario="healthy",
                            policy=policy, **r, **backend_info()))
        rows.append((
            f"cluster/healthy_{policy}", r["wall_s"] * 1e6,
            f"goodput={r['goodput']:.2f} tok/s={r['tokens_per_s']:.1f} "
            f"ttft_p50={r['ttft_p50_ticks']:.0f}t "
            f"ttft_p99={r['ttft_p99_ticks']:.0f}t {timing_label()}",
        ))
    base = healthy[policies[0]]

    # -- kill: replica 1 crashes mid-run ----------------------------------
    kill = _scenario(
        cfg, params, prompts, policy=policies[0],
        faults=FaultInjector(
            [FaultSpec("replica_crash", uid=1, after=disrupt)]
        ),
    )
    records.append(dict(kind="disruption", scenario="kill",
                        policy=policies[0], disrupt_tick=disrupt,
                        **kill, **backend_info()))
    rows.append((
        "cluster/kill_replica", kill["wall_s"] * 1e6,
        f"goodput={kill['goodput']:.2f} deaths={kill['replica_deaths']} "
        f"redelivered={kill['redelivered']} expired={kill['expired']} "
        f"{timing_label()}",
    ))

    # -- drain: planned removal of the same replica ------------------------
    drain = _scenario(cfg, params, prompts, policy=policies[0], drain_rid=1,
                      disrupt_tick=disrupt)
    records.append(dict(kind="disruption", scenario="drain",
                        policy=policies[0], disrupt_tick=disrupt,
                        **drain, **backend_info()))
    rows.append((
        "cluster/drain_replica", drain["wall_s"] * 1e6,
        f"goodput={drain['goodput']:.2f} migrated={drain['migrated']} "
        f"expired={drain['expired']} {timing_label()}",
    ))

    kill_retention = kill["goodput"] / base["goodput"]
    drain_retention = drain["goodput"] / base["goodput"]
    records.append(dict(
        kind="summary",
        kill_goodput_retention=kill_retention,
        drain_goodput_retention=drain_retention,
        healthy_goodput=base["goodput"],
        kill_goodput=kill["goodput"],
        drain_goodput=drain["goodput"],
        n_replicas=N_REPLICAS, disrupt_tick=DISRUPT_AFTER,
        deadline_e2e_ticks=DEADLINE_E2E, **backend_info(),
    ))
    rows.append((
        "cluster/goodput_retention", 0.0,
        f"kill={kill_retention:.2f} drain={drain_retention:.2f} "
        f"(healthy goodput {base['goodput']:.2f})",
    ))

    if not smoke:
        save_result("cluster", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
