"""Forward+backward attention timing: pure-JAX scan path vs the fused
custom_vjp Pallas kernel path (flash and distr).

CPU wall time is not TPU time — the kernel path runs in interpret mode on
this container — so every record carries ``backend``/``interpret`` labels
(kernel timings are interpret-mode unless backend is TPU; the XLA-path
timings are always compiled) plus the analytic fwd+bwd MXU-FLOP ratio from
``ops.attention_cost``, the roofline-honest comparison (the quantity the
37%-over-FA-2 claim rides on).  Emits ``BENCH_attention_bwd.json`` at the
repo root so the perf trajectory is recorded per PR.

  PYTHONPATH=src python -m benchmarks.run --only attention_bwd
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp

from repro.core import DistrConfig
from repro.core.distr_attention import distr_attention as core_distr
from repro.core.flash_reference import blockwise_flash_reference
from repro.kernels import ops
from repro.kernels.ops import attention_cost
from benchmarks.common import backend_info, save_result, timeit, timing_label

B, H = 1, 4
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_attention_bwd.json")


def _fwd_bwd(attn_fn):
    """value_and_grad of a scalar loss through the attention op."""

    def loss(q, k, v):
        return attn_fn(q, k, v).astype(jnp.float32).sum()

    return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))


def run(smoke: bool = False) -> list[tuple]:
    rows, records = [], []
    block = 64
    for d in (64,):
        for n in ((128,) if smoke else (128, 256)):
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            q = jax.random.normal(ks[0], (B, H, n, d), jnp.float32)
            k = jax.random.normal(ks[1], (B, H, n, d), jnp.float32)
            v = jax.random.normal(ks[2], (B, H, n, d), jnp.float32)

            # --- exact: XLA blockwise reference vs Pallas kernel custom_vjp.
            t_xla_flash = timeit(
                _fwd_bwd(functools.partial(
                    blockwise_flash_reference, block_q=block, block_k=block,
                    causal=True,
                )), q, k, v,
            )
            t_krn_flash = timeit(
                _fwd_bwd(functools.partial(
                    ops.flash_attention, causal=True, block_q=block,
                    block_k=block,
                )), q, k, v,
            )
            c_f = attention_cost(B, H, n, n, d, causal=True, block_q=block)
            rec = dict(
                kind="flash", d=d, n=n,
                xla_fwd_bwd_us=t_xla_flash, kernel_fwd_bwd_us=t_krn_flash,
                fwd_bwd_mxu_flops=c_f["fwd_bwd_mxu_flops"],
                # The XLA reference always runs compiled; the kernel column
                # follows the backend auto-detect (interpret off-TPU).
                **backend_info(),
            )
            records.append(rec)
            rows.append((
                f"attn_bwd/flash/d={d}/n={n}", t_krn_flash,
                f"xla_scan={t_xla_flash:.0f}us {timing_label()}",
            ))

            # --- distr: checkpoint-scan core path vs kernel custom_vjp.
            for g in ((2,) if smoke else (2, 4)):
                cfg = DistrConfig(group_size=g, block_q=block, block_k=block)
                t_core = timeit(
                    _fwd_bwd(functools.partial(core_distr, cfg=cfg, causal=True)),
                    q, k, v,
                )
                t_krn = timeit(
                    _fwd_bwd(functools.partial(
                        ops.distr_attention, cfg=cfg, causal=True,
                    )), q, k, v,
                )
                c_d = attention_cost(
                    B, H, n, n, d, causal=True, group_size=g, block_q=block
                )
                ratio = c_d["fwd_bwd_mxu_flops"] / c_f["fwd_bwd_mxu_flops"]
                rec = dict(
                    kind="distr", d=d, n=n, g=g,
                    scan_fwd_bwd_us=t_core, kernel_fwd_bwd_us=t_krn,
                    fwd_bwd_mxu_flops=c_d["fwd_bwd_mxu_flops"],
                    fwd_bwd_mxu_ratio_vs_flash=ratio,
                    **backend_info(),
                )
                records.append(rec)
                rows.append((
                    f"attn_bwd/distr/d={d}/n={n}/G={g}", t_krn,
                    f"scan={t_core:.0f}us mxu_ratio={ratio:.3f} {timing_label()}",
                ))

    if not smoke:
        save_result("attention_bwd", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
