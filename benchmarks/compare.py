"""Paper Tables 5/7/8 analogue: DistrAttention vs approximate-attention
baselines (Hydra / Flatten / Primal-lowrank / Hyper-sampled) on the SAME
mechanism-level task: output fidelity vs exact attention + wall time.

The paper measures fine-tuned model accuracy; without ImageNet/MMLU on this
container the mechanism-level fidelity (cosine similarity and relative error
vs exact attention on realistic activations) is the faithful proxy — the
ordering it produces matches the paper's (ours most accurate, Hydra least).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import AttentionConfig, DistrConfig, attend, reference_attention
from repro.core.baselines import BASELINES
from benchmarks.common import save_result, timeit

B, H, N, D = 2, 8, 1024, 64


def run(smoke: bool = False) -> list[tuple]:
    n = 256 if smoke else N
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    # mildly correlated activations (more realistic than iid)
    base = jax.random.normal(ks[0], (B, H, n, D))
    q = base + 0.5 * jax.random.normal(ks[1], (B, H, n, D))
    k = base + 0.5 * jax.random.normal(ks[2], (B, H, n, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, H, n, D))

    exact = reference_attention(q, k, v, causal=True)

    methods = {
        "ours_distr_g2": jax.jit(functools.partial(
            attend,
            cfg=AttentionConfig(impl="distr", distr=DistrConfig(group_size=2)),
            causal=True,
        )),
        "ours_distr_g4": jax.jit(functools.partial(
            attend,
            cfg=AttentionConfig(impl="distr", distr=DistrConfig(group_size=4)),
            causal=True,
        )),
    }
    for name, fn in BASELINES.items():
        methods[name] = jax.jit(functools.partial(fn, causal=True))

    rows, records = [], []
    for name, fn in methods.items():
        out = fn(q, k, v)
        diff = (out - exact).astype(jnp.float32)
        rel = float(jnp.abs(diff).mean() / jnp.abs(exact).mean())
        cos = float(
            jnp.sum(out.astype(jnp.float32) * exact)
            / (jnp.linalg.norm(out.astype(jnp.float32)) * jnp.linalg.norm(exact))
        )
        us = timeit(fn, q, k, v, warmup=1, iters=2 if smoke else 3)
        records.append(dict(method=name, rel_err=rel, cosine=cos, us=us))
        rows.append((f"compare/{name}", us, f"rel_err={rel:.4f} cos={cos:.4f}"))
    if not smoke:
        save_result("compare", records)
    return rows
