"""Mesh serving benchmark: ring-prefill-into-paged-decode TTFT vs chunked
single-device prefill (ISSUE 9 acceptance).

Two ``PagedServeEngine`` configurations serve the same long prompt:

  chunked_1dev    — no mesh: the prompt admits through chunked prefill,
                    one ``prefill_chunk`` slice per scheduler tick, so
                    TTFT is ~ceil(n / chunk) ticks;
  ring_into_paged — ``mesh=`` a context ring: the scheduler's mesh
                    admission prefills the WHOLE prompt across the ring in
                    one tick and lands the KV in the block pool, so TTFT
                    is ~1 tick.

TTFT is measured in the tick domain (injected clock, one tick per
scheduler step) so the structural claim — whole-prompt admission
collapses time-to-first-token — is deterministic and backend-independent.
Wall-clock rows ride along, labelled via ``backend_info`` (CPU-interpret
wall time is not TPU time; an 8-host-device ring adds collective overhead
the tick metric deliberately ignores).

Emits ``BENCH_mesh.json`` at the repo root (floor gated by
benchmarks/regress.py) and ``benchmarks/results/mesh_serving.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import save_result

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_mesh.json")

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time
sys.path.insert(0, {src!r})
from dataclasses import replace as dc_replace
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import compat_make_mesh
from repro.models import lm
from repro.serve.engine import PagedServeEngine
from benchmarks.common import backend_info

class TickClock:
    t = 0.0
    def __call__(self):
        return self.t

cfg = get_config("qwen1.5-4b", reduced=True)
cfg = cfg.replace(attention=dc_replace(
    cfg.attention, impl="pallas_flash", context_axis="context"))
params = lm.init_params(jax.random.PRNGKey(0), cfg)
n, max_len, ndev = {n}, {max_len}, {ndev}
prompt = list(np.random.RandomState(0).randint(0, cfg.vocab, size=n))
ring = compat_make_mesh((ndev,), ("context",))

out = []
for mode, mesh in (("chunked_1dev", None), ("ring_into_paged", ring)):
    c = cfg if mesh is not None else cfg.replace(
        attention=dc_replace(cfg.attention, context_axis=None))
    clock = TickClock()
    eng = PagedServeEngine(
        c, params, max_batch=2, max_len=max_len, block_size=128,
        prefill_chunk=32, cache_dtype=jnp.float32, clock=clock, mesh=mesh)
    eng.add_request(prompt, max_new_tokens=2)  # warm every jit path
    eng.run_to_completion()
    eng.finished = []
    eng.scheduler.done = []
    t0 = time.perf_counter()
    eng.add_request(prompt, max_new_tokens=2)
    while eng.scheduler.has_work():
        eng.step()
        clock.t += 1
    wall = time.perf_counter() - t0
    (row,) = eng.metrics()
    out.append(dict(
        mode=mode, prompt_len=n, prefill_chunk=32, max_len=max_len,
        devices=1 if mesh is None else ndev,
        ttft_ticks=float(row["ttft_s"]), wall_s=wall,
        mesh_prefills=eng.counters_snapshot()["mesh_prefills"],
        **backend_info(),
    ))
assert out[1]["mesh_prefills"] >= 1, "ring engine never took the mesh path"
print("MESHJSON:" + json.dumps(out))
"""


def _run_sub(script: str, rows: list):
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=1100)
    if res.returncode != 0:
        rows.append(("mesh_serving/FAILED", 0.0, res.stderr[-200:]))
        return None
    return json.loads(res.stdout.split("MESHJSON:")[1])


def run(smoke: bool = False) -> list[tuple]:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    rows: list[tuple] = []
    records = _run_sub(
        textwrap.dedent(_SCRIPT).format(
            src=src,
            # smoke: bucket 256 = 2 × 128 still engages the ring
            n=160 if smoke else 300,
            max_len=256 if smoke else 512,
            ndev=2,
        ),
        rows,
    )
    if records is None:
        return rows

    by_mode = {r["mode"]: r for r in records}
    ratio = (by_mode["chunked_1dev"]["ttft_ticks"]
             / max(by_mode["ring_into_paged"]["ttft_ticks"], 1.0))
    summary = dict(
        kind="summary", chunked_over_mesh_ttft_ticks=ratio,
        prompt_len=by_mode["ring_into_paged"]["prompt_len"],
        **{k: v for k, v in by_mode["ring_into_paged"].items()
           if k in ("backend", "interpret")},
    )
    records = records + [summary]
    for r in records[:-1]:
        mode = "interpret" if r["interpret"] else "compiled"
        rows.append((
            f"mesh_serving/{r['mode']}", r["wall_s"] * 1e6,
            f"ttft={r['ttft_ticks']:.0f}ticks devices={r['devices']} "
            f"mesh_prefills={r['mesh_prefills']} "
            f"backend={r['backend']}:{mode}",
        ))
    rows.append((
        "mesh_serving/ttft_collapse", 0.0,
        f"chunked/mesh TTFT = {ratio:.1f}x in ticks "
        f"(whole-prompt ring admission)",
    ))
    if not smoke:
        save_result("mesh_serving", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
