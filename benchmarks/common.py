"""Shared benchmark utilities: timing, CSV rows, result persistence."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn, *args, warmup: int = 2, iters: int = 5, **kwargs) -> float:
    """Median wall time (µs) of a jitted callable (CPU wall clock)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def save_result(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def emit(rows: list[tuple]) -> None:
    """Print the run.py CSV contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
