"""Shared benchmark utilities: timing, CSV rows, result persistence.

Every BENCH record that carries a wall-clock number must also carry the
``backend_info()`` fields: CPU wall time of an interpret-mode Pallas kernel
is *not* comparable to a compiled-kernel or XLA timing, and unlabeled rows
read like a kernel-vs-XLA comparison when they are not (the acceptance
criterion for the perf trajectory).
"""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

#: BENCH record schema.  v1 = the historical unstamped records (no
#: ``schema`` field); v2 adds the stamp (schema + backend/interpret on
#: every record) so benchmarks/regress.py can key bounds per-row and
#: per-backend instead of guessing from filenames.
SCHEMA_VERSION = 2


def backend_info(interpret: bool | None = None) -> dict:
    """The shared per-record stamp: schema version, the JAX backend, and
    whether Pallas kernels ran in interpreter mode (None →
    ``kernels.ops.default_interpret``, the same rule the ops apply; pass
    False for pure-XLA timings)."""
    from repro.kernels.ops import default_interpret

    if interpret is None:
        interpret = default_interpret()
    return {"schema": SCHEMA_VERSION, "backend": jax.default_backend(),
            "interpret": bool(interpret)}


def timing_label(interpret: bool | None = None) -> str:
    """Short derived-column suffix, e.g. ``backend=cpu:interpret``."""
    info = backend_info(interpret)
    mode = "interpret" if info["interpret"] else "compiled"
    return f"backend={info['backend']}:{mode}"


def timeit(fn, *args, warmup: int = 2, iters: int = 5, **kwargs) -> float:
    """Median wall time (µs) of a jitted callable (CPU wall clock)."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def save_result(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)


def emit(rows: list[tuple]) -> None:
    """Print the run.py CSV contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
