"""Paper Table 9: multi-device attention, Flash2 vs DistrAttention.

Runs in a subprocess with 8 forced host devices; the attention workload is
sharded over a data mesh of 1/2/4/8 devices (paper: 1/2/4 GPUs) and timed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import save_result

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, functools, time
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import attend, AttentionConfig, DistrConfig
from repro.utils.jax_compat import set_mesh
from benchmarks.common import timeit

B, H, N, D = 8, 8, {n}, 128
q = jax.random.normal(jax.random.PRNGKey(0), (B, H, N, D), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (B, H, N, D), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(2), (B, H, N, D), jnp.float32)

flash = functools.partial(
    attend, cfg=AttentionConfig(impl="xla_flash"), causal=True)
distr = functools.partial(
    attend,
    cfg=AttentionConfig(impl="distr", distr=DistrConfig(group_size=2)),
    causal=True)

out = []
for ndev in {ndevs}:
    mesh = jax.sharding.Mesh(jax.devices()[:ndev], ("data",))
    sh = NamedSharding(mesh, P("data"))
    qs, ks, vs = (jax.device_put(x, sh) for x in (q, k, v))
    with set_mesh(mesh):
        t_f = timeit(jax.jit(flash), qs, ks, vs, warmup=1, iters=3)
        t_d = timeit(jax.jit(distr), qs, ks, vs, warmup=1, iters=3)
    out.append(dict(devices=ndev, flash_us=t_f, distr_us=t_d,
                    speedup=t_f / t_d))
print("JSON:" + json.dumps(out))
"""


def run(smoke: bool = False) -> list[tuple]:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = textwrap.dedent(_SCRIPT).format(
        src=os.path.abspath(src),
        n=256 if smoke else 2048,
        ndevs=(1, 2) if smoke else (1, 2, 4, 8),
    )
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=560)
    rows = []
    if res.returncode != 0:
        rows.append(("multidevice/FAILED", 0.0, res.stderr[-200:]))
        return rows
    records = json.loads(res.stdout.split("JSON:")[1])
    if not smoke:
        save_result("multidevice", records)
    for r in records:
        rows.append((
            f"multidevice/devices={r['devices']}", r["distr_us"],
            f"flash={r['flash_us']:.0f}us speedup={r['speedup']:.2f}x",
        ))
    return rows
