"""Paper Table 9: multi-device attention, Flash2 vs DistrAttention.

Runs in a subprocess with 8 forced host devices; the attention workload is
sharded over a data mesh of 1/2/4/8 devices (paper: 1/2/4 GPUs) and timed.

Beyond the paper, a second subprocess times **ring sequence-parallel
attention** (distributed.ring_attention) — flash and distr — on context
rings of 1/2/4/8 devices against the single-device kernels, emitting
``BENCH_ring.json`` at the repo root.  On this CPU container the rows are
interpret-mode (labelled via ``backend_info``): the point is exercising the
ring schedule end-to-end and tracking the hop/merge overhead trend, not
absolute kernel speed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import save_result

BENCH_RING_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_ring.json"
)

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, functools, time
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import attend, AttentionConfig, DistrConfig
from repro.utils.jax_compat import set_mesh
from benchmarks.common import timeit

B, H, N, D = 8, 8, {n}, 128
q = jax.random.normal(jax.random.PRNGKey(0), (B, H, N, D), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (B, H, N, D), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(2), (B, H, N, D), jnp.float32)

flash = functools.partial(
    attend, cfg=AttentionConfig(impl="xla_flash"), causal=True)
distr = functools.partial(
    attend,
    cfg=AttentionConfig(impl="distr", distr=DistrConfig(group_size=2)),
    causal=True)

out = []
for ndev in {ndevs}:
    mesh = jax.sharding.Mesh(jax.devices()[:ndev], ("data",))
    sh = NamedSharding(mesh, P("data"))
    qs, ks, vs = (jax.device_put(x, sh) for x in (q, k, v))
    with set_mesh(mesh):
        t_f = timeit(jax.jit(flash), qs, ks, vs, warmup=1, iters=3)
        t_d = timeit(jax.jit(distr), qs, ks, vs, warmup=1, iters=3)
    out.append(dict(devices=ndev, flash_us=t_f, distr_us=t_d,
                    speedup=t_f / t_d))
print("JSON:" + json.dumps(out))
"""


_RING_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, functools
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from repro.core.distr_attention import DistrConfig
from repro.distributed.ring_attention import (
    ring_distr_attention, ring_flash_attention,
)
from repro.kernels import ops
from benchmarks.common import backend_info, timeit

B, Hq, Hkv, N, D = 1, 4, 2, {n}, 64
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, Hq, N, D), jnp.float32)
k = jax.random.normal(ks[1], (B, Hkv, N, D), jnp.float32)
v = jax.random.normal(ks[2], (B, Hkv, N, D), jnp.float32)
dcfg = DistrConfig(group_size=2)

t_flash1 = timeit(jax.jit(functools.partial(ops.flash_attention, causal=True)),
                  q, k, v, warmup=1, iters=3)
t_distr1 = timeit(
    jax.jit(lambda q, k, v: ops.distr_attention(q, k, v, dcfg, causal=True)),
    q, k, v, warmup=1, iters=3)
out = []
for ndev in {ndevs}:
    mesh = jax.sharding.Mesh(jax.devices()[:ndev], ("context",))
    _, hops = ring_flash_attention(q, k, v, mesh, causal=True,
                                   return_hops=True)
    t_f = timeit(
        jax.jit(lambda q, k, v: ring_flash_attention(
            q, k, v, mesh, causal=True)), q, k, v, warmup=1, iters=3)
    t_d = timeit(
        jax.jit(lambda q, k, v: ring_distr_attention(
            q, k, v, dcfg, mesh, causal=True)), q, k, v, warmup=1, iters=3)
    out.append(dict(devices=ndev, seq=N, causal=True, hops=int(hops),
                    ring_flash_us=t_f, ring_distr_us=t_d,
                    single_flash_us=t_flash1, single_distr_us=t_distr1,
                    **backend_info()))
print("RINGJSON:" + json.dumps(out))
"""


def _run_sub(script: str, marker: str, rows: list, label: str):
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=1100)
    if res.returncode != 0:
        rows.append((f"{label}/FAILED", 0.0, res.stderr[-200:]))
        return None
    return json.loads(res.stdout.split(marker)[1])


def run(smoke: bool = False) -> list[tuple]:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    rows: list[tuple] = []
    records = _run_sub(
        textwrap.dedent(_SCRIPT).format(
            src=src,
            n=256 if smoke else 2048,
            ndevs=(1, 2) if smoke else (1, 2, 4, 8),
        ),
        "JSON:", rows, "multidevice",
    )
    if records is not None:
        if not smoke:
            save_result("multidevice", records)
        for r in records:
            rows.append((
                f"multidevice/devices={r['devices']}", r["distr_us"],
                f"flash={r['flash_us']:.0f}us speedup={r['speedup']:.2f}x",
            ))

    ring = _run_sub(
        textwrap.dedent(_RING_SCRIPT).format(
            src=src,
            n=256 if smoke else 1024,
            ndevs=(1, 2) if smoke else (1, 2, 4, 8),
        ),
        "RINGJSON:", rows, "multidevice/ring",
    )
    if ring is not None:
        if not smoke:
            save_result("ring", ring)
            with open(os.path.abspath(BENCH_RING_PATH), "w") as f:
                json.dump(ring, f, indent=1)
        for r in ring:
            mode = "interpret" if r["interpret"] else "compiled"
            rows.append((
                f"multidevice/ring/devices={r['devices']}",
                r["ring_flash_us"],
                f"distr={r['ring_distr_us']:.0f}us "
                f"single_flash={r['single_flash_us']:.0f}us "
                f"hops={r['hops']} backend={r['backend']}:{mode}",
            ))
    return rows
