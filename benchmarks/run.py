"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Results are also persisted as
JSON under benchmarks/results/ for EXPERIMENTS.md.

  T1/Fig9  attention_time   — Flash2 vs DistrAttention compute time
  §Bwd     attention_bwd    — fwd+bwd: scan path vs kernel custom_vjp path
  T2       blocksize        — (l, m): analytic vs measured best vs default
  §Tune    autotune         — tuned-vs-default blocks per kernel
                              (BENCH_autotune.json)
  T3/T4    errors           — Ŝ error vs block size / sampling rate
  T5/T7/T8 compare          — ours vs Hydra/Flatten/Primal/Hyper fidelity+time
  T6       llama_ttft       — LM prefill TTFT, exact vs distr
  T9       multidevice      — sharded attention on 1/2/4/8 devices
  Fig8     accuracy_train   — training-loss trajectories exact vs distr
  §4.8     lsh_grouping     — LSH grouping share of attention time
  extra    distr_decode     — beyond-paper fused-K̂ decode cache
  §Decode  decode           — split-K flash-decoding: tokens/s + per-token
                              KV bytes vs live length (BENCH_decode.json)
  §Paged   serving          — slot engine vs paged continuous batching at
                              equal HBM: tokens/s + P50/P99 TTFT
                              (BENCH_serving.json)
  §Cluster cluster          — multi-replica router: routing policies +
                              goodput retention under a mid-run replica
                              kill vs drain (BENCH_cluster.json; floors
                              gated by benchmarks/regress.py)
  §Train   train_chaos      — checkpoint-resume goodput under a mid-run
                              kill, with the latest checkpoint healthy vs
                              torn, plus bit-exact resume-loss match
                              (BENCH_train_chaos.json; floors gated by
                              benchmarks/regress.py)
  §Mesh    mesh_serving     — ring-prefill-into-paged-decode TTFT vs
                              chunked single-device prefill on the same
                              engine (BENCH_mesh.json; floor gated by
                              benchmarks/regress.py)

``--smoke`` runs every benchmark at one tiny shape (interpret mode on this
container) without touching the persisted JSON results — a CI-grade check
that no benchmark has silently rotted.

``--trace PATH`` installs a global :class:`repro.obs.trace.TraceRecorder`
for the run (autotune measurement spans ride it) and writes a Chrome
trace_event JSON; ``--metrics-out PATH`` writes a typed metrics snapshot
of the run itself (rows emitted, failures, per-row latency histogram).
Both artifacts conform to ``python -m repro.obs.validate``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback

BENCHES = [
    "errors",
    "blocksize",
    "autotune",
    "attention_time",
    "attention_bwd",
    "compare",
    "llama_ttft",
    "lsh_grouping",
    "accuracy_train",
    "multidevice",
    "distr_decode",
    "decode",
    "serving",
    "cluster",
    "train_chaos",
    "mesh_serving",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shape pass over every benchmark; no JSON "
                         "results are written")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a typed metrics snapshot of the run")
    args = ap.parse_args()
    names = args.only or BENCHES

    from repro.obs import (
        NULL_RECORDER, MetricsRegistry, TraceRecorder, set_recorder,
    )

    rec = NULL_RECORDER
    if args.trace:
        rec = TraceRecorder()
        set_recorder(rec)
    reg = MetricsRegistry()
    c_rows = reg.counter("bench_rows", "CSV rows emitted across benchmarks")
    c_fail = reg.counter("bench_failures", "benchmark modules that raised")
    h_row = reg.histogram(
        "bench_row_us", "per-row us_per_call",
        buckets=(10.0, 100.0, 1e3, 1e4, 1e5, 1e6),
    )

    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            with rec.span("bench", bench=name):
                if args.smoke:
                    if "smoke" not in inspect.signature(mod.run).parameters:
                        raise TypeError(f"{name}.run() lacks a smoke=... param")
                    rows = mod.run(smoke=True)
                else:
                    rows = mod.run()
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
                c_rows.inc()
                h_row.observe(float(us))
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            c_fail.inc()
            traceback.print_exc()
    if args.trace:
        rec.save(args.trace)
        print(f"[bench] trace: {args.trace} "
              f"({len(rec.events)} events, {rec.dropped} dropped)",
              file=sys.stderr)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(reg.snapshot(), f, indent=1)
        print(f"[bench] metrics: {args.metrics_out}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
