"""Serving benchmark: slot engine vs paged continuous batching at EQUAL
HBM budget (ISSUE 5 acceptance).

Both engines serve the same mixed-length synthetic workload with the same
total KV-token budget:

  slot engine   — ``max_slots`` contiguous ``max_len`` slabs: admission is
                  slot-bound (a short request strands a whole slab) and
                  concurrency is capped at ``max_slots``;
  paged engine  — the same token budget as a shared block pool: admission
                  is memory-bound, so the mixed-length mix packs ~3× more
                  concurrent decode lanes into the same HBM, and the
                  continuous-batching scheduler admits every tick.

Reported per engine: total generated tokens/s (wall), P50/P99 TTFT and
mean TPOT from the engines' own metrics.  jit compilation is excluded by a
warm-up workload covering every prefill bucket / step width before the
timed run — compile time is a one-off, not a serving-throughput property.
All rows carry backend/interpret labels (CPU-interpret wall time is not
TPU time; the *structural* claim — more lanes at equal HBM, admission
every tick — is backend-independent).

A second scenario measures OVERLOAD behaviour (ISSUE 6 acceptance): an
arrival rate above capacity with per-request deadlines, run with and
without the graceful-degradation controller.  It uses an injected
tick-domain clock (one tick per scheduler step), so shed rate,
deadline-miss rate, and TTFT percentiles are deterministic — wall time on
CPU-interpret would say nothing about the policy.  The structural claim:
under the same overload the controller sheds/expires fewer requests and
cuts p99 TTFT, because degraded whole-prompt prefill (coarser
DistrAttention grouping) admits a queued prompt in one tick instead of
ceil(n/chunk) chunked ticks.

Emits ``BENCH_serving.json`` at the repo root and
``benchmarks/results/serving.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import backend_info, save_result, timing_label
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import PagedServeEngine, ServeEngine

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

MAX_LEN = 64
SLOTS = 4  # slot engine: SLOTS × MAX_LEN KV tokens of HBM
BLOCK_SIZE = 16
MAX_BATCH = 8  # paged lanes — memory-bound, not slab-bound
PREFILL_CHUNK = 32
MAX_NEW = 12


def _workload(smoke: bool):
    """Mixed prompt lengths (short-heavy, a few long): the regime where
    contiguous slabs strand the most memory."""
    if smoke:
        return [4, 10, 6, 20], 4
    return [4, 6, 8, 12, 16, 24, 40, 48, 8, 10, 5, 14, 6, 20, 9, 12], MAX_NEW


def _prompts(lengths):
    rng = np.random.RandomState(0)
    return [list(rng.randint(1, 500, size=n)) for n in lengths]


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p))


def _drive(engine, prompts, max_new):
    for p in prompts:
        engine.add_request(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run_to_completion()
    wall = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in done)
    m = engine.metrics()
    ttfts = [x["ttft_s"] for x in m if x["ttft_s"] is not None]
    tpots = [x["tpot_s"] for x in m if x["tpot_s"] is not None]
    return {
        "wall_s": wall,
        "tokens": tokens,
        "tokens_per_s": tokens / wall,
        "ttft_p50_s": _percentile(ttfts, 50),
        "ttft_p99_s": _percentile(ttfts, 99),
        "tpot_mean_s": float(np.mean(tpots)) if tpots else None,
        "n_preemptions": sum(x["n_preemptions"] for x in m),
    }


class _TickClock:
    """Injectable clock advanced once per scheduler step: deadlines, TTFT
    and the controller's pressure signal all live in the tick domain."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _overload(cfg, params, *, smoke: bool, degrade):
    """Arrivals above capacity with deadlines; returns policy metrics."""
    from repro.serve import lifecycle

    n_requests = 8 if smoke else 24
    per_tick = 1  # still ≫ service rate: chunked prefill is the bottleneck
    deadline_ttft, deadline_e2e = 16, 80
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(1, 500, size=int(n)))
               for n in rng.choice([16, 24, 32, 40], size=n_requests)]

    clock = _TickClock()
    eng = PagedServeEngine(
        cfg, params, max_batch=2, max_len=MAX_LEN, block_size=16,
        num_blocks=1 + 3 * (MAX_LEN // 16), prefill_chunk=8,
        max_waiting=8, clock=clock, degrade=degrade,
    )
    arrivals = list(enumerate(prompts))
    t0 = time.perf_counter()
    for _step in range(4000):
        for _ in range(per_tick):
            if arrivals:
                _uid, p = arrivals.pop(0)
                eng.add_request(p, max_new_tokens=6,
                                deadline_ttft=deadline_ttft,
                                deadline_e2e=deadline_e2e)
        eng.step()
        clock.t += 1
        if not arrivals and not eng.scheduler.has_work():
            break
    wall = time.perf_counter() - t0
    assert not eng.scheduler.has_work(), "overload scenario did not drain"

    counters = eng.counters_snapshot()
    rows = eng.metrics()
    statuses = [r["status"] for r in rows]
    ttfts = [r["ttft_s"] for r in rows if r["ttft_s"] is not None]
    done = sum(s == lifecycle.DONE for s in statuses)
    return {
        "n_requests": n_requests,
        "arrivals_per_tick": per_tick,
        "deadline_ttft_ticks": deadline_ttft,
        "deadline_e2e_ticks": deadline_e2e,
        "completed": done,
        "shed_rate": counters.get("shed", 0) / n_requests,
        "deadline_miss_rate": counters.get("expired", 0) / n_requests,
        "goodput": done / n_requests,
        "ttft_p50_ticks": _percentile(ttfts, 50) if ttfts else None,
        "ttft_p99_ticks": _percentile(ttfts, 99) if ttfts else None,
        "degraded_prefills": counters.get("degraded_prefills", 0),
        "ticks": clock.t,
        "wall_s": wall,
    }


def run(smoke: bool = False) -> list[tuple]:
    lengths, max_new = _workload(smoke)
    prompts = _prompts(lengths)
    warm_prompts = _prompts(sorted(set(lengths)))  # hit every jit bucket
    cfg = get_config("qwen2.5-32b", reduced=True)  # GQA (Hkv < Hq)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    hbm_tokens = SLOTS * MAX_LEN  # the shared budget
    # The reserved garbage block counts INSIDE the budget: the paged pools
    # physically allocate num_blocks × BLOCK_SIZE tokens of KV per layer,
    # and "equal HBM" must mean equal allocation, not equal usable tokens.
    num_blocks = hbm_tokens // BLOCK_SIZE

    def slot_engine():
        return ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN)

    def paged_engine():
        return PagedServeEngine(
            cfg, params, max_batch=MAX_BATCH if not smoke else 4,
            max_len=MAX_LEN, block_size=BLOCK_SIZE, num_blocks=num_blocks,
            prefill_chunk=PREFILL_CHUNK,
        )

    engines = {}
    for name, make in (("slot", slot_engine), ("paged", paged_engine)):
        eng = make()
        _drive(eng, warm_prompts, 2)  # compile every bucket, untimed
        if isinstance(eng, PagedServeEngine):
            # Warm the preemption path too (evict/restore trace fixed
            # shapes — one op-cache fill, then host-copy cost only).
            eng.cache.allocate_to(10_000, 1)
            eng.cache.evict_to_host(10_000, 1, pad_to=eng.max_blocks)
            eng.cache.restore(10_000)
            eng.cache.free(10_000)
        engines[name] = eng

    # Interleaved repetitions, best-of per engine: serving a whole workload
    # takes long enough that background load drifts between runs — pairing
    # the engines inside each rep and taking each engine's best keeps the
    # comparison apples-to-apples on a shared machine.
    results: dict[str, dict] = {}
    reps = 1 if smoke else 3
    for _rep in range(reps):
        for name, eng in engines.items():
            # clear finished lists so each rep's metrics are clean
            eng.finished = []
            if hasattr(eng, "scheduler"):
                eng.scheduler.done = []
            r = _drive(eng, prompts, max_new)
            if (name not in results
                    or r["tokens_per_s"] > results[name]["tokens_per_s"]):
                results[name] = r

    rows, records = [], []
    for name, r in results.items():
        rec = dict(
            engine=name, max_len=MAX_LEN, hbm_kv_tokens=hbm_tokens,
            slots_or_lanes=SLOTS if name == "slot" else MAX_BATCH,
            block_size=None if name == "slot" else BLOCK_SIZE,
            n_requests=len(prompts), max_new_tokens=max_new,
            prompt_lengths=lengths, reps_best_of=reps, **r, **backend_info(),
        )
        records.append(rec)
        rows.append((
            f"serving/{name}", r["wall_s"] * 1e6,
            f"tok/s={r['tokens_per_s']:.1f} ttft_p50={r['ttft_p50_s']*1e3:.0f}ms "
            f"ttft_p99={r['ttft_p99_s']*1e3:.0f}ms preempts={r['n_preemptions']} "
            f"{timing_label()}",
        ))

    speedup = results["paged"]["tokens_per_s"] / results["slot"]["tokens_per_s"]
    records.append(dict(
        kind="summary", paged_over_slot_tokens_per_s=speedup,
        equal_hbm_kv_tokens=hbm_tokens, **backend_info(),
    ))
    rows.append((
        "serving/continuous_vs_slots", 0.0,
        f"paged/slot tokens/s = {speedup:.2f}x at equal HBM "
        f"({hbm_tokens} KV tokens)",
    ))

    # -- overload: deadlines + shedding, controller off vs on ------------
    from repro.serve.degrade import DegradeConfig

    dcfg = DegradeConfig(group_sizes=(2, 4), high_watermark=3,
                         low_watermark=1, up_after=1, down_after=2)
    overload = {}
    for mode, degrade in (("exact", None), ("degrade", dcfg)):
        r = _overload(cfg, params, smoke=smoke, degrade=degrade)
        overload[mode] = r
        records.append(dict(
            kind="overload", controller=mode, max_waiting=8,
            **r, **backend_info(),
        ))
        p99 = r["ttft_p99_ticks"]
        p99_s = f"{p99:.0f}ticks" if p99 is not None else "n/a"
        rows.append((
            f"serving/overload_{mode}", r["wall_s"] * 1e6,
            f"goodput={r['goodput']:.2f} shed={r['shed_rate']:.2f} "
            f"miss={r['deadline_miss_rate']:.2f} ttft_p99={p99_s} "
            f"degraded={r['degraded_prefills']} {timing_label()}",
        ))
    rows.append((
        "serving/overload_controller_effect", 0.0,
        "goodput {:.2f}->{:.2f}, miss {:.2f}->{:.2f} with degradation dial".format(
            overload["exact"]["goodput"], overload["degrade"]["goodput"],
            overload["exact"]["deadline_miss_rate"],
            overload["degrade"]["deadline_miss_rate"],
        ),
    ))

    if not smoke:
        save_result("serving", records)
        with open(os.path.abspath(BENCH_PATH), "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
