"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh 16x16]

``--attn`` instead renders the analytic attention fwd+bwd roofline (v5e)
from ``kernels.ops.attention_cost`` — exact FA-2 vs DistrAttention per
(d, N, G*), now that the cost model covers the backward kernels too.

  PYTHONPATH=src python -m benchmarks.roofline_table --attn
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

ARCH_ORDER = [
    "minicpm-2b", "starcoder2-7b", "qwen2.5-32b", "qwen1.5-4b",
    "whisper-small", "internvl2-2b", "llama4-scout-17b-a16e",
    "deepseek-v2-236b", "zamba2-7b", "mamba2-130m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "") -> dict:
    recs = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            r = json.load(f)
        name = os.path.basename(path)[:-5]
        if r.get("mesh") != mesh:
            continue
        # normalise: the attention-free arch records carry an impl suffix
        base = f"{r['arch']}_{r['shape']}_{r['mesh']}"
        norm = name.replace("_reference", "")
        want = f"{base}_{tag}" if tag else base
        if norm != want:
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def table(mesh: str, tag: str = "") -> str:
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| useful-FLOPs | mem/dev GiB (TPU est) | status |",
        "|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                             "skipped (DESIGN.md §4) |")
                continue
            if r.get("status") == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | — | — | skip |"
                )
                continue
            t = r["roofline"]
            ratio = r.get("useful_flops_ratio")
            mem = r["memory"]["per_device_total"] / 2**30
            est = r["tpu_memory_estimate"]["total"] / 2**30
            lines.append(
                f"| {arch} | {shape} | {fmt_ms(t['compute_s'])} | "
                f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} | "
                f"{t['dominant']} | "
                f"{ratio:.3f} | {mem:.1f} ({est:.1f}) | ok |"
            )
    return "\n".join(lines)


def attn_fwd_bwd_table() -> str:
    """Analytic fwd+bwd attention roofline per (d, N, G*) on v5e numbers."""
    from repro.kernels.ops import attention_cost
    from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

    lines = [
        "| d | N | G* | fwd MXU GF | bwd MXU GF | fwd+bwd vs exact "
        "| compute µs | memory µs | dominant |",
        "|---:|---:|---:|---:|---:|---:|---:|---:|---|",
    ]
    for d in (64, 128):
        for n in (4096, 16384):
            base = attention_cost(1, 8, n, n, d, causal=True)
            for g in (1, 2, 4):
                c = attention_cost(1, 8, n, n, d, causal=True, group_size=g)
                fb_flops = c["fwd_bwd_mxu_flops"]
                fb_bytes = c["fwd_bwd_hbm_bytes"]
                comp_us = fb_flops / PEAK_FLOPS * 1e6
                mem_us = fb_bytes / HBM_BW * 1e6
                lines.append(
                    f"| {d} | {n} | {g} | {c['mxu_flops']/1e9:.1f} | "
                    f"{c['bwd_mxu_flops']/1e9:.1f} | "
                    f"{fb_flops/base['fwd_bwd_mxu_flops']:.3f} | "
                    f"{comp_us:.1f} | {mem_us:.1f} | "
                    f"{'compute' if comp_us > mem_us else 'memory'} |"
                )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn", action="store_true",
                    help="analytic attention fwd+bwd roofline instead")
    args = ap.parse_args()
    if args.attn:
        print(attn_fwd_bwd_table())
        return
    print(table(args.mesh, args.tag))


if __name__ == "__main__":
    main()
