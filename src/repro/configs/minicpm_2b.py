"""minicpm-2b [dense] — 40L d=2304 36H (kv=36) ff=5760 vocab=122753.
WSD schedule, llama-like trunk.  [arXiv:2404.06395; hf]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab=122753,
        head_dim=64,
        tie_embeddings=True,
        schedule="wsd",
        # 36 heads % 16-way TP != 0 → sequence-sharded attention (DESIGN §5).
        attn_shard="seq",
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
