"""internvl2-2b [vlm] — 24L d=2048 16H (GQA kv=8) ff=8192 vocab=92553.
InternViT frontend is a STUB (input_specs provides precomputed patch
embeddings); InternLM2 LM backbone.  [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92553,
        head_dim=128,
        frontend="patch_stub",
        num_patch_tokens=256,
        # §Perf iteration: "heads" (16/16 q-heads) measured 12× worse on the
        # collective term — kv=8 < TP=16 forces kv padding/replication.
        # Sequence-parallel attention wins for every kv<TP arch.
        attn_shard="seq",
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, num_patch_tokens=16, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
