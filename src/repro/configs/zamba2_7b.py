"""zamba2-7b [hybrid] — 81L d=3584 32H ff=14336 vocab=32000, ssm_state=64.
Mamba2 backbone with 2 alternating SHARED attention blocks applied after
every 6th mamba layer (concat-skip from the embedding trunk).
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab=32000,
        head_dim=112,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        ssm_chunk=128,
        attn_every=6,
        n_shared_attn_blocks=2,
        attn_shard="heads",  # 32 heads / 16-way TP
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
        attn_every=2, n_shared_attn_blocks=2, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
