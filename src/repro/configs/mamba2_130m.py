"""mamba2-130m [ssm] — 24L d=768 (attention-free) vocab=50280, ssm_state=128.
SSD (state-space duality).  DistrAttention is inapplicable (no QKᵀ stage) —
implemented without the technique per DESIGN.md §4.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=12,  # unused (attention-free)
        n_kv_heads=12,
        d_ff=0,
        vocab=50280,
        tie_embeddings=True,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        ssm_chunk=128,
        attention=AttentionConfig(impl="reference"),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, vocab=512, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=32, max_seq_len=256,
    )
