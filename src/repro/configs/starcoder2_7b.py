"""starcoder2-7b [dense] — 32L d=4608 36H (GQA kv=4) ff=18432 vocab=49152.
GQA + RoPE; layernorm/gelu trunk with QKV bias.  [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab=49152,
        head_dim=128,
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
        rope_theta=1e6,
        attn_shard="seq",  # 36 heads % 16 != 0
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
