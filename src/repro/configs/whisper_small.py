"""whisper-small [audio] — 12L(+12L enc) d=768 12H ff=3072 vocab=51865.
Encoder-decoder; conv frontend is a STUB (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        n_encoder_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51865,
        head_dim=64,
        act="gelu",
        norm="layernorm",
        pos="learned",
        learned_pos_len=32768,
        frontend="audio_stub",
        cross_len=1500,
        attn_shard="seq",  # 12 heads % 16 != 0
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, n_encoder_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512, learned_pos_len=512, cross_len=64,
        max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
