"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, input_specs

_ARCH_MODULES = {
    "minicpm-2b": "repro.configs.minicpm_2b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "whisper-small": "repro.configs.whisper_small",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.reduced() if reduced else mod.config()


def list_configs() -> list[ModelConfig]:
    return [get_config(n) for n in ARCH_NAMES]


__all__ = [
    "ARCH_NAMES",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "input_specs",
    "list_configs",
]
