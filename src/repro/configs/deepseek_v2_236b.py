"""deepseek-v2-236b [moe] — 60L d=5120 128H MLA (kv_lora=512) vocab=102400,
MoE: 2 shared + 160 routed top-6 (d_ff_expert=1536), first layer dense
(d_ff=12288).  [arXiv:2405.04434; hf]

DistrAttention applies to the materialised per-head QKᵀ over the nope
sub-dimension; RoPE dims stay exact (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,  # the single dense layer
        vocab=102400,
        n_experts=160,
        moe_top_k=6,
        n_shared_experts=2,
        d_ff_expert=1536,
        first_dense_layers=1,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        attn_shard="heads",  # 128 heads / 16-way TP
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        n_experts=8, moe_top_k=2, n_shared_experts=1, d_ff_expert=64,
        first_dense_layers=1, q_lora_rank=64, kv_lora_rank=32,
        qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
