"""Model / shape configuration system.

One ``ModelConfig`` covers every assigned architecture family (dense GQA,
MoE, MLA, SSM, hybrid, enc-dec) via optional field groups; each
``configs/<arch>.py`` instantiates the exact published dims plus a
``reduced()`` variant for CPU smoke tests.

Shapes (assignment): train_4k, prefill_32k, decode_32k, long_500k.  The
decode shapes lower ``serve_step`` (1 token vs a seq_len KV cache);
``long_500k`` only applies to sub-quadratic archs (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core.api import AttentionConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    # transformer trunk
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"
    norm: str = "rmsnorm"
    pos: str = "rope"  # rope | learned | none
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # attention implementation (the paper's technique lives here)
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    # distribution strategy hints (resolved by repro.distributed.sharding)
    attn_shard: str = "heads"  # heads | seq — seq when heads % tp != 0
    fsdp: bool = True  # shard params/optimizer over the data axis too
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "auto"  # auto | dense_onehot | ep_a2a | ep_psum
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0  # hybrid: shared attn block after every k ssm layers
    n_shared_attn_blocks: int = 2
    # encoder-decoder / multimodal stubs
    n_encoder_layers: int = 0
    frontend: str | None = None  # audio_stub | patch_stub
    num_patch_tokens: int = 256  # vlm: image tokens per sample
    cross_len: int = 1500  # enc output length seen by decode shapes
    learned_pos_len: int = 32768  # table size when pos == "learned"
    # numerics & training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    schedule: str = "cosine"  # cosine | wsd
    max_seq_len: int = 532480

    # ---- derived -----------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the embedding/LM-head shard over the
        16-way model axis (and stay MXU-tile aligned).  Padded logits are
        masked to -inf in logits_fn; padded rows receive no gradient signal
        beyond weight decay."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim_

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- shape applicability (DESIGN.md §4) ---------------------------
    def supports_shape(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            # needs sub-quadratic sequence handling
            return self.family in ("ssm", "hybrid")
        return True

    def skip_reason(self, shape: ShapeSpec) -> str | None:
        if self.supports_shape(shape):
            return None
        return (
            "long_500k requires sub-quadratic attention; "
            f"{self.name} is a pure softmax-attention arch (see DESIGN.md §4)"
        )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    train  → token/label batches (+ stub frontend embeddings).
    prefill→ token batch (serve prefill lowering).
    decode → one-token batch; KV-cache specs come from repro.serve.kv_cache.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.bfloat16

    def tok(n):
        return jax.ShapeDtypeStruct((b, n), i32)

    if cfg.family == "encdec":
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": tok(s),
                "labels": tok(s),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": tok(s),
            }
        return {"tokens": tok(1)}  # decode: cache specs added by serve layer

    if cfg.frontend == "patch_stub":
        npatch = min(cfg.num_patch_tokens, s // 2)
        ntext = s - npatch
        if shape.kind == "train":
            return {
                "patches": jax.ShapeDtypeStruct((b, npatch, cfg.d_model), f32),
                "tokens": tok(ntext),
                "labels": tok(ntext),
            }
        if shape.kind == "prefill":
            return {
                "patches": jax.ShapeDtypeStruct((b, npatch, cfg.d_model), f32),
                "tokens": tok(ntext),
            }
        return {"tokens": tok(1)}

    if shape.kind == "train":
        return {"tokens": tok(s), "labels": tok(s)}
    if shape.kind == "prefill":
        return {"tokens": tok(s)}
    return {"tokens": tok(1)}
