"""qwen2.5-32b [dense] — 64L d=5120 40H (GQA kv=8) ff=27648 vocab=152064.
GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B family scaling; hf]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab=152064,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1e6,
        attn_shard="seq",  # 40 heads % 16 != 0
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
