"""qwen1.5-4b [dense] — 40L d=2560 20H (kv=20) ff=6912 vocab=151936.
MHA with QKV bias.  [hf:Qwen/Qwen1.5-0.5B family scaling; hf]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_ff=6912,
        vocab=151936,
        head_dim=128,
        qkv_bias=True,
        attn_shard="seq",  # 20 heads % 16 != 0
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
