"""llama4-scout-17b-a16e [moe] — 48L d=5120 40H (GQA kv=8) vocab=202048,
MoE 16 experts top-1 + 1 shared expert (d_ff_expert=8192).
Early fusion is multimodal-specific; the assigned shapes are text-only so the
backbone here is the text LM.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig
from repro.core.api import AttentionConfig
from repro.core.distr_attention import DistrConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        head_dim=128,
        n_experts=16,
        moe_top_k=1,
        n_shared_experts=1,
        d_ff_expert=8192,
        attn_shard="seq",  # 40 heads % 16 != 0
        attention=AttentionConfig(
            impl="distr",
            distr=DistrConfig(group_size=2, block_q=128, block_k=128),
        ),
    )


def reduced() -> ModelConfig:
    return config().replace(
        compute_dtype="float32", capacity_factor=4.0,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, n_experts=4, moe_top_k=1, n_shared_experts=1,
        d_ff_expert=128, max_seq_len=256,
        attention=AttentionConfig(
            impl="distr", distr=DistrConfig(group_size=2, block_q=32, block_k=32)
        ),
    )
