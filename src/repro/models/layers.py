"""Primitive layers: linear, norm, embedding, rotary, MLP.

Pure-function style (no flax): ``*_init(key, ...) -> params`` and
``*_apply(params, x, ...) -> y``.  Every init has a matching ``*_axes(...)``
returning the same pytree structure with logical-axis tuples for the
distributed sharding rules (repro.distributed.sharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sharding constraint helper — no-op when no mesh is active so the same model
# code runs in smoke tests (1 device) and under the production mesh.
# ---------------------------------------------------------------------------


def constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint(x, P(*spec)) if a mesh is active, else x.

    ``"data"`` entries denote *batch* dims and expand to every non-"model"
    mesh axis, so the same model code data-parallelises over the extra "pod"
    axis of the multi-pod mesh.  The "context" axis (ring sequence-parallel
    attention) is excluded: the sequence dim shards over it, never the
    batch.
    """
    try:
        from repro.utils.jax_compat import get_abstract_mesh

        mesh = get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        from repro.distributed.sharding import CONTEXT_AXIS

        dp = tuple(
            a for a in mesh.axis_names if a not in ("model", CONTEXT_AXIS)
        )
        # "seq" entries denote the sequence dim: sharded over the reserved
        # context axis when the mesh rings it, replicated otherwise.
        ctx = CONTEXT_AXIS if CONTEXT_AXIS in mesh.axis_names else None
        expanded = tuple(
            (dp if s == "data" else ctx if s == "seq" else s) for s in spec
        )
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*expanded)
        )
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Backward-stream dtype guard (§Perf iteration 5)
#
# The loss head computes in f32, so without intervention every cotangent down
# the residual stream stays f32 — doubling backward HBM traffic and the
# activation-gradient collectives vs the bf16 forward.  ``grad_cast`` is an
# identity whose VJP casts the cotangent back to bf16; applied at block
# boundaries it keeps the whole backward stream in the compute dtype.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _grad_cast_bf16(x):
    return x


def _gc_fwd(x):
    return x, None


def _gc_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_grad_cast_bf16.defvjp(_gc_fwd, _gc_bwd)


def grad_cast(x: jnp.ndarray) -> jnp.ndarray:
    """Clamp the backward stream to the forward compute dtype (bf16)."""
    if x.dtype == jnp.bfloat16:
        return _grad_cast_bf16(x)
    return x


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None):
    w_scale = scale if scale is not None else d_in**-0.5
    params = {"w": (jax.random.normal(key, (d_in, d_out)) * w_scale).astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
    return params


def linear_axes(in_axis: str | None, out_axis: str | None, *, bias: bool = False):
    axes = {"w": (in_axis, out_axis)}
    if bias:
        axes["b"] = (out_axis,)
    return axes


def linear_apply(params, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    # Mixed precision: params may be stored fp32; compute follows the
    # activation dtype unless an explicit compute_dtype is given.
    dtype = compute_dtype if compute_dtype is not None else x.dtype
    y = x.astype(dtype) @ params["w"].astype(dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_axes():
    return {"scale": (None,)}


def rmsnorm_apply(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_axes():
    return {"scale": (None,), "bias": (None,)}


def layernorm_apply(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embedding_axes():
    return {"table": ("vocab", None)}


def embedding_apply(params, tokens: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    return jnp.take(table, tokens, axis=0)


def embedding_logits(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-embedding readout."""
    return x @ params["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0,
               rope_dim: int | None = None) -> jnp.ndarray:
    """Rotate the leading ``rope_dim`` features of x.

    x: (B, H, N, d); positions: (B, N) int32.
    """
    d = x.shape[-1]
    rd = rope_dim if rope_dim is not None else d
    freqs = rope_frequencies(rd, theta)  # (rd/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,N,rd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    if rd == d:
        return rotated
    return jnp.concatenate([rotated, x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, *, act: str = "silu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "up": linear_init(k1, d_model, d_ff, dtype=dtype),
        "down": linear_init(k2, d_ff, d_model, dtype=dtype),
    }
    if act == "silu":  # SwiGLU needs the gate
        params["gate"] = linear_init(k3, d_model, d_ff, dtype=dtype)
    return params


def mlp_axes(act: str = "silu"):
    axes = {
        "up": linear_axes(None, "mlp"),
        "down": linear_axes("mlp", None),
    }
    if act == "silu":
        axes["gate"] = linear_axes(None, "mlp")
    return axes


def mlp_apply(params, x: jnp.ndarray, *, act: str = "silu") -> jnp.ndarray:
    up = linear_apply(params["up"], x)
    if act == "silu":
        gate = linear_apply(params["gate"], x)
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(f"unknown act {act!r}")
    h = constrain(h, "data", None, "model")
    return linear_apply(params["down"], h)
