"""Mamba-2 (SSD) block — the attention-free assigned archs (mamba2-130m) and
the hybrid backbone (zamba2-7b).

``ssd_xla`` is the chunked state-space-duality forward in pure JAX (scan over
chunks) used by dry-runs so cost_analysis sees real FLOPs; the Pallas kernel
(repro.kernels.ssd) implements the same math for TPU and validates against
the same oracle.  ``ssd_step`` is the O(1)-per-token decode recurrence.

DistrAttention is inapplicable here (no QKᵀ softmax stage) — DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import constrain


# ---------------------------------------------------------------------------
# SSD forward (chunked, XLA) and decode step
# ---------------------------------------------------------------------------


def ssd_xla(
    x: jnp.ndarray,  # (B, N, H, P)
    a: jnp.ndarray,  # (B, N, H) log-decays (<= 0)
    b: jnp.ndarray,  # (B, N, G, S)
    c: jnp.ndarray,  # (B, N, G, S)
    *,
    chunk: int = 128,
    return_state: bool = False,
):
    bsz, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    r = h // g
    pad = (-n) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk

    # chunk-major xs for the scan: (nc, B, chunk, ...)
    def chunked(t, feat_dims):
        return jnp.moveaxis(
            t.reshape((bsz, nc, chunk) + feat_dims), 1, 0
        )

    xs = chunked(x.astype(jnp.float32), (g, r, p))
    as_ = chunked(a.astype(jnp.float32), (h,))
    bs = chunked(b.astype(jnp.float32), (g, s))
    cs = chunked(c.astype(jnp.float32), (g, s))

    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    tril = col <= row

    def body(state, inputs):
        # state: (B, G, r, S, P)
        x_c, a_c, b_c, c_c = inputs
        a_cum = jnp.cumsum(a_c, axis=1)  # (B, Q, H) inclusive
        a_grp = a_cum.reshape(bsz, chunk, g, r)

        # Intra-chunk
        cb = jnp.einsum("bigs,bjgs->bgij", c_c, b_c)  # (B, G, Q, Q)
        decay = jnp.exp(
            a_grp[:, :, None, :, :] - a_grp[:, None, :, :, :]
        )  # (B, Q, Q, G, r)
        decay = jnp.where(tril[None, :, :, None, None], decay, 0.0)
        y = jnp.einsum("bgij,bijgr,bjgrp->bigrp", cb, decay, x_c)

        # Inter-chunk: carry-in state decayed to each step.
        y = y + jnp.exp(a_grp)[..., None] * jnp.einsum(
            "bigs,bgrsp->bigrp", c_c, state
        )

        # State update.
        a_tot = a_grp[:, -1]  # (B, G, r)
        w = jnp.exp(a_tot[:, None] - a_grp)  # (B, Q, G, r)
        new_state = (
            jnp.exp(a_tot)[..., None, None] * state
            + jnp.einsum("bjgs,bjgr,bjgrp->bgrsp", b_c, w, x_c)
        )
        # ys in compute dtype (f32 ys double the stacked-scan memory).
        return new_state, y.astype(x.dtype)

    state0 = jnp.zeros((bsz, g, r, s, p), jnp.float32)
    final_state, ys = jax.lax.scan(body, state0, (xs, as_, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * chunk, h, p)
    y = y[:, :n].astype(x.dtype)
    if return_state:
        # (B, G, r, S, P) → (B, H, S, P), matching ssd_step's layout.
        return y, final_state.reshape(bsz, h, s, p)
    return y


def ssd_step(
    x_t: jnp.ndarray,  # (B, H, P)
    a_t: jnp.ndarray,  # (B, H)
    b_t: jnp.ndarray,  # (B, G, S)
    c_t: jnp.ndarray,  # (B, G, S)
    state: jnp.ndarray,  # (B, H, S, P)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step of the SSD recurrence → (y_t (B,H,P), new_state)."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    r = h // g
    bt = jnp.repeat(b_t, r, axis=1)  # (B, H, S)
    ct = jnp.repeat(c_t, r, axis=1)
    decay = jnp.exp(a_t.astype(jnp.float32))[..., None, None]
    state = state * decay + bt[..., None].astype(jnp.float32) * x_t[
        :, :, None, :
    ].astype(jnp.float32)
    y = jnp.einsum("bhs,bhsp->bhp", ct.astype(jnp.float32), state)
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def _conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def mamba_init(key, cfg, dtype=jnp.float32):
    d_in = cfg.d_inner
    h = cfg.ssm_heads
    gs = cfg.ssm_groups * cfg.ssm_state
    proj_out = 2 * d_in + 2 * gs + h  # z, x, B, C, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": layers.linear_init(k1, cfg.d_model, proj_out, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, _conv_dim(cfg)))
                   * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((_conv_dim(cfg),), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.full((h,), 0.5, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": layers.rmsnorm_init(d_in, dtype),
        "out_proj": layers.linear_init(k3, d_in, cfg.d_model, dtype=dtype),
    }


def mamba_axes(cfg):
    return {
        "in_proj": layers.linear_axes(None, "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "out_norm": layers.rmsnorm_axes(),
        "out_proj": layers.linear_axes("mlp", None),
    }


def _split_proj(proj: jnp.ndarray, cfg):
    d_in = cfg.d_inner
    gs = cfg.ssm_groups * cfg.ssm_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * gs]
    dt = proj[..., d_in + d_in + 2 * gs :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, conv_w: jnp.ndarray, conv_b: jnp.ndarray):
    """Depthwise causal conv over the sequence (kernel taps via shifts)."""
    k = conv_w.shape[0]
    y = xbc * conv_w[k - 1].astype(xbc.dtype)
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        y = y + shifted * conv_w[k - 1 - i].astype(xbc.dtype)
    return jax.nn.silu(y + conv_b.astype(xbc.dtype))


def mamba_apply(params, x: jnp.ndarray, cfg, *, return_state: bool = False):
    """Full-sequence Mamba-2 block.  x: (B, N, D) → (B, N, D).

    With return_state=True also returns (conv_state, ssm_state) at position N
    so serving can switch from prefill to step decoding.
    """
    bsz, n, _ = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, s = cfg.ssm_groups, cfg.ssm_state

    proj = layers.linear_apply(params["in_proj"], x)
    z, xbc_raw, dt = _split_proj(proj, cfg)
    xbc_raw = constrain(xbc_raw, "data", None, "model")
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs = xbc[..., : cfg.d_inner]
    b = xbc[..., cfg.d_inner : cfg.d_inner + g * s].reshape(bsz, n, g, s)
    c = xbc[..., cfg.d_inner + g * s :].reshape(bsz, n, g, s)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,N,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    a_t = dt * a  # log-decay per step
    x_heads = xs.reshape(bsz, n, h, p)
    x_in = x_heads * dt[..., None].astype(x_heads.dtype)

    ssd_out = ssd_xla(x_in, a_t, b, c, chunk=cfg.ssm_chunk,
                      return_state=return_state)
    y, ssm_state = ssd_out if return_state else (ssd_out, None)
    y = y + x_heads * params["d_skip"][None, None, :, None].astype(x_heads.dtype)
    y = y.reshape(bsz, n, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm_apply(params["out_norm"], y, cfg.norm_eps)
    out = layers.linear_apply(params["out_proj"], y)
    if return_state:
        k = cfg.ssm_conv
        conv_state = xbc_raw[:, n - (k - 1):, :]  # last k-1 pre-conv inputs
        return out, (conv_state.astype(x.dtype), ssm_state)
    return out


def mamba_decode_apply(params, x: jnp.ndarray, cfg, *, conv_state, ssm_state):
    """One-token step.  x: (B, 1, D); conv_state: (B, k-1, conv_dim);
    ssm_state: (B, H, S, P).  Returns (y, (conv_state, ssm_state))."""
    bsz = x.shape[0]
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, s = cfg.ssm_groups, cfg.ssm_state
    k = cfg.ssm_conv

    proj = layers.linear_apply(params["in_proj"], x)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc_t = xbc[:, 0]  # (B, conv_dim)

    window = jnp.concatenate([conv_state, xbc_t[:, None]], axis=1)  # (B,k,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    new_conv_state = window[:, 1:]

    xs = conv_out[:, : cfg.d_inner]
    b = conv_out[:, cfg.d_inner : cfg.d_inner + g * s].reshape(bsz, g, s)
    c = conv_out[:, cfg.d_inner + g * s :].reshape(bsz, g, s)

    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a_t = dt_t * (-jnp.exp(params["a_log"]))  # (B, H)
    x_heads = xs.reshape(bsz, h, p)
    x_in = (x_heads * dt_t[..., None]).astype(x.dtype)

    y, new_ssm_state = ssd_step(x_in, a_t, b.astype(x.dtype), c.astype(x.dtype),
                                ssm_state)
    y = y + x_heads.astype(y.dtype) * params["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm_apply(params["out_norm"], y, cfg.norm_eps)
    return layers.linear_apply(params["out_proj"], y), (new_conv_state, new_ssm_state)
