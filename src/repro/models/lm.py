"""Full model assembly: init / train forward / prefill / decode for every
assigned architecture family.

Layers are parameter-stacked and driven with ``lax.scan`` so the lowered HLO
stays compact at 60-80 layers (essential for the 512-device dry-run compile)
and per-layer remat falls out naturally.  Heterogeneous stacks (DeepSeek's
first dense layer, zamba2's mamba/shared-attention interleave) are split into
multiple homogeneous scans.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.layers import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stack_axes(axes_tree):
    """Prepend the layer-stack dim (unsharded) to every leaf's axes."""
    return jax.tree_util.tree_map(
        lambda t: (None,) + tuple(t),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple),
    )


def _hybrid_layout(cfg) -> tuple[int, int]:
    """(n_groups, n_tail) for the mamba/shared-attn interleave."""
    n_groups = cfg.n_layers // cfg.attn_every
    n_tail = cfg.n_layers - n_groups * cfg.attn_every
    return n_groups, n_tail


def init_params(key, cfg, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 16))
    params: dict = {
        "embed": layers.embedding_init(next(ks), cfg.padded_vocab, cfg.d_model, dtype)
    }
    if cfg.pos == "learned":
        params["pos_embed"] = layers.embedding_init(
            next(ks), cfg.learned_pos_len, cfg.d_model, dtype
        )

    if cfg.family in ("dense",):
        params["blocks"] = _stacked_init(
            lambda k: transformer.block_init(k, cfg, "dense", dtype), next(ks), cfg.n_layers
        )
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            dense_cfg = cfg
            params["dense_blocks"] = _stacked_init(
                lambda k: transformer.block_init(k, dense_cfg, "dense", dtype),
                next(ks), cfg.first_dense_layers,
            )
        params["blocks"] = _stacked_init(
            lambda k: transformer.block_init(k, cfg, "moe", dtype),
            next(ks), cfg.n_layers - cfg.first_dense_layers,
        )
    elif cfg.family == "ssm":
        params["blocks"] = _stacked_init(
            lambda k: transformer.block_init(k, cfg, "mamba", dtype), next(ks), cfg.n_layers
        )
    elif cfg.family == "hybrid":
        n_groups, n_tail = _hybrid_layout(cfg)
        group_key = next(ks)

        def group_init(k):
            return _stacked_init(
                lambda kk: transformer.block_init(kk, cfg, "mamba", dtype),
                k, cfg.attn_every,
            )

        params["groups"] = _stacked_init(group_init, group_key, n_groups)
        if n_tail:
            params["tail"] = _stacked_init(
                lambda k: transformer.block_init(k, cfg, "mamba", dtype), next(ks), n_tail
            )
        params["shared"] = [
            transformer.shared_block_init(next(ks), cfg, dtype)
            for _ in range(cfg.n_shared_attn_blocks)
        ]
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stacked_init(
            lambda k: transformer.block_init(k, cfg, "dense", dtype),
            next(ks), cfg.n_encoder_layers or cfg.n_layers,
        )
        params["enc_norm"] = (
            layers.rmsnorm_init(cfg.d_model, dtype)
            if cfg.norm == "rmsnorm"
            else layers.layernorm_init(cfg.d_model, dtype)
        )
        params["blocks"] = _stacked_init(
            lambda k: transformer.block_init(k, cfg, "dense", dtype, cross=True),
            next(ks), cfg.n_layers,
        )
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    params["final_norm"] = (
        layers.rmsnorm_init(cfg.d_model, dtype)
        if cfg.norm == "rmsnorm"
        else layers.layernorm_init(cfg.d_model, dtype)
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.linear_init(
            next(ks), cfg.d_model, cfg.padded_vocab, dtype=dtype
        )
    return params


def param_axes(cfg):
    axes: dict = {"embed": layers.embedding_axes()}
    if cfg.pos == "learned":
        axes["pos_embed"] = layers.embedding_axes()
    if cfg.family == "dense":
        axes["blocks"] = _stack_axes(transformer.block_axes(cfg, "dense"))
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            axes["dense_blocks"] = _stack_axes(transformer.block_axes(cfg, "dense"))
        axes["blocks"] = _stack_axes(transformer.block_axes(cfg, "moe"))
    elif cfg.family == "ssm":
        axes["blocks"] = _stack_axes(transformer.block_axes(cfg, "mamba"))
    elif cfg.family == "hybrid":
        axes["groups"] = _stack_axes(_stack_axes(transformer.block_axes(cfg, "mamba")))
        n_groups, n_tail = _hybrid_layout(cfg)
        if n_tail:
            axes["tail"] = _stack_axes(transformer.block_axes(cfg, "mamba"))
        axes["shared"] = [
            transformer.shared_block_axes(cfg) for _ in range(cfg.n_shared_attn_blocks)
        ]
    elif cfg.family == "encdec":
        axes["enc_blocks"] = _stack_axes(transformer.block_axes(cfg, "dense"))
        axes["enc_norm"] = (
            layers.rmsnorm_axes() if cfg.norm == "rmsnorm" else layers.layernorm_axes()
        )
        axes["blocks"] = _stack_axes(transformer.block_axes(cfg, "dense", cross=True))
    axes["final_norm"] = (
        layers.rmsnorm_axes() if cfg.norm == "rmsnorm" else layers.layernorm_axes()
    )
    if not cfg.tie_embeddings:
        axes["lm_head"] = layers.linear_axes(None, "vocab")
    return axes


# ---------------------------------------------------------------------------
# scan machinery
# ---------------------------------------------------------------------------


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = None  # full remat
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def _scan_blocks(blocks, x, cfg, layer_type, *, positions=None, causal=True,
                 enc_out=None, collect_cache=False):
    def body(carry, layer_params):
        h, aux_sum = carry
        h, aux, kv = transformer.block_apply(
            layer_params, h, cfg, layer_type,
            positions=positions, causal=causal, enc_out=enc_out,
            collect_cache=collect_cache,
        )
        return (h, aux_sum + aux), kv

    body = _remat(body, cfg)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux, kvs


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, patches=None, frames=None):
    """→ (x, positions, n_prefix) where n_prefix = non-text prefix length."""
    compute = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    if cfg.family == "encdec":
        x = layers.embedding_apply(params["embed"], tokens, compute)
    elif patches is not None:
        tok_emb = layers.embedding_apply(params["embed"], tokens, compute)
        x = jnp.concatenate([patches.astype(compute), tok_emb], axis=1)
    else:
        x = layers.embedding_apply(params["embed"], tokens, compute)
    b, n = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(n), (b, n))
    if cfg.pos == "learned":
        x = x + layers.embedding_apply(params["pos_embed"], positions, compute)
    n_prefix = 0 if patches is None else patches.shape[1]
    x = constrain(x, "data", None, None)
    return x, positions, n_prefix


def _encode(params, cfg, frames):
    compute = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = frames.astype(compute)  # stub frontend: precomputed frame embeddings
    b, n = x.shape[0], x.shape[1]
    if cfg.pos == "learned":
        pos = jnp.broadcast_to(jnp.arange(n), (b, n))
        x = x + layers.embedding_apply(params["pos_embed"], pos, compute)
    x, _, _ = _scan_blocks(params["enc_blocks"], x, cfg, "dense", causal=False)
    return transformer.norm_apply(params["enc_norm"], x, cfg)


def backbone(params, cfg, tokens, *, patches=None, frames=None,
             collect_cache=False):
    """Shared trunk → (hidden, aux, cache_parts, n_prefix)."""
    cache_parts: dict = {}
    x, positions, n_prefix = _embed_inputs(params, cfg, tokens, patches, frames)

    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, frames)
        cache_parts["enc_out"] = enc_out
        x, aux, kvs = _scan_blocks(
            params["blocks"], x, cfg, "dense", positions=positions,
            causal=True, enc_out=enc_out, collect_cache=collect_cache,
        )
        cache_parts["kv"] = kvs if collect_cache else None
    elif cfg.family == "dense":
        x, aux, kvs = _scan_blocks(
            params["blocks"], x, cfg, "dense", positions=positions,
            collect_cache=collect_cache,
        )
        cache_parts["kv"] = kvs if collect_cache else None
    elif cfg.family == "moe":
        aux = jnp.zeros((), jnp.float32)
        kv_list = []
        if cfg.first_dense_layers:
            x, aux_d, kvs_d = _scan_blocks(
                params["dense_blocks"], x, cfg, "dense", positions=positions,
                collect_cache=collect_cache,
            )
            aux += aux_d
            kv_list.append(kvs_d)
        x, aux_m, kvs_m = _scan_blocks(
            params["blocks"], x, cfg, "moe", positions=positions,
            collect_cache=collect_cache,
        )
        aux += aux_m
        kv_list.append(kvs_m)
        if collect_cache:
            cache_parts["kv"] = (
                jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *kv_list
                )
                if len(kv_list) > 1
                else kv_list[0]
            )
    elif cfg.family == "ssm":
        x, aux, states = _scan_blocks(
            params["blocks"], x, cfg, "mamba", collect_cache=collect_cache
        )
        cache_parts["ssm"] = states if collect_cache else None
    elif cfg.family == "hybrid":
        x0 = x  # trunk input for the shared blocks' concat skip
        aux = jnp.zeros((), jnp.float32)
        n_groups, n_tail = _hybrid_layout(cfg)

        def mamba_step(carry, layer_params):
            h, aux_sum = carry
            h, aux_l, states = transformer.block_apply(
                layer_params, h, cfg, "mamba", collect_cache=collect_cache
            )
            return (h, aux_sum + aux_l), states

        mamba_step = _remat(mamba_step, cfg)
        branches = [
            functools.partial(
                transformer.shared_block_apply, sp, cfg=cfg, positions=positions
            )
            for sp in params["shared"]
        ]

        def group_body(carry, inputs):
            h, aux_sum = carry
            group_params, gi = inputs
            (h, aux_sum), states = jax.lax.scan(
                mamba_step, (h, aux_sum), group_params
            )
            h, kv = jax.lax.switch(
                gi % cfg.n_shared_attn_blocks,
                [lambda hh, bb=bb: bb(hh, x0) for bb in branches],
                h,
            )
            return (h, aux_sum), (states, kv)

        (x, aux), (g_states, g_kv) = jax.lax.scan(
            group_body,
            (x, aux),
            (params["groups"], jnp.arange(n_groups)),
        )
        if n_tail:
            x, aux_t, t_states = _scan_blocks(
                params["tail"], x, cfg, "mamba", collect_cache=collect_cache
            )
            aux += aux_t
        else:
            t_states = None
        if collect_cache:
            cache_parts["ssm_groups"] = g_states
            cache_parts["shared_kv"] = g_kv
            cache_parts["ssm_tail"] = t_states
    else:
        raise ValueError(cfg.family)

    x = transformer.norm_apply(params["final_norm"], x, cfg)
    return x, aux, cache_parts, n_prefix


def logits_fn(params, cfg, hidden):
    if cfg.tie_embeddings:
        logits = layers.embedding_logits(params["embed"], hidden)
    else:
        logits = layers.linear_apply(params["lm_head"], hidden)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return constrain(logits, "data", None, "model")


def forward(params, cfg, tokens, *, patches=None, frames=None):
    """Train/eval forward → (logits, aux)."""
    hidden, aux, _, n_prefix = backbone(params, cfg, tokens,
                                        patches=patches, frames=frames)
    if n_prefix:
        hidden = hidden[:, n_prefix:]
    return logits_fn(params, cfg, hidden), aux


def loss_fn(params, cfg, batch):
    """Cross-entropy next-token loss (+MoE aux, +z-loss) → (loss, metrics)."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        patches=batch.get("patches"), frames=batch.get("frames"),
    )
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    zloss = ((jax.nn.logsumexp(logits, axis=-1) ** 2) * mask).sum() / denom
    total = ce + cfg.router_aux_weight * aux + 1e-4 * zloss
    return total, {"ce": ce, "aux": aux, "zloss": zloss}
