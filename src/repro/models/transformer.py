"""Transformer blocks: dense (GQA/MLA) + MoE + Mamba + hybrid shared-attn.

Pre-norm residual blocks.  Every attention goes through ``repro.core.attend``
so DistrAttention is a config flip.  Blocks return ``(x, aux)`` where aux is
the MoE load-balance loss (0.0 for non-MoE blocks) — keeps scan carries
uniform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers, mamba, moe
from repro.models.layers import constrain


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return layers.rmsnorm_init(d)
    return layers.layernorm_init(d)


def _norm_axes(cfg):
    if cfg.norm == "rmsnorm":
        return layers.rmsnorm_axes()
    return layers.layernorm_axes()


def norm_apply(params, x, cfg):
    if cfg.norm == "rmsnorm":
        return layers.rmsnorm_apply(params, x, cfg.norm_eps)
    return layers.layernorm_apply(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Dense / MoE / MLA decoder block
# ---------------------------------------------------------------------------


def block_init(key, cfg, layer_type: str, dtype=jnp.float32, *, cross: bool = False):
    """layer_type: dense | moe | mamba."""
    ks = jax.random.split(key, 6)
    if layer_type == "mamba":
        return {
            "norm1": _norm_init(cfg),
            "mixer": mamba.mamba_init(ks[0], cfg, dtype),
        }
    params = {
        "norm1": _norm_init(cfg),
        "norm2": _norm_init(cfg),
    }
    if cfg.use_mla:
        params["attn"] = attn_mod.mla_init(ks[0], cfg, dtype)
    else:
        params["attn"] = attn_mod.attention_init(ks[0], cfg, dtype)
    if cross:
        params["norm_cross"] = _norm_init(cfg)
        params["cross_attn"] = attn_mod.attention_init(ks[1], cfg, dtype)
    if layer_type == "moe":
        params["ffn"] = moe.moe_init(ks[2], cfg, dtype)
    else:
        params["ffn"] = layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                        act=cfg.act, dtype=dtype)
    return params


def block_axes(cfg, layer_type: str, *, cross: bool = False):
    if layer_type == "mamba":
        return {"norm1": _norm_axes(cfg), "mixer": mamba.mamba_axes(cfg)}
    axes = {"norm1": _norm_axes(cfg), "norm2": _norm_axes(cfg)}
    if cfg.use_mla:
        axes["attn"] = attn_mod.mla_axes(cfg)
    else:
        axes["attn"] = attn_mod.attention_axes(cfg)
    if cross:
        axes["norm_cross"] = _norm_axes(cfg)
        axes["cross_attn"] = attn_mod.attention_axes(cfg)
    if layer_type == "moe":
        axes["ffn"] = moe.moe_axes(cfg)
    else:
        axes["ffn"] = layers.mlp_axes(act=cfg.act)
    return axes


def block_apply(
    params,
    x: jnp.ndarray,
    cfg,
    layer_type: str,
    *,
    positions=None,
    causal: bool = True,
    enc_out=None,
    decode: bool = False,
    collect_cache: bool = False,
):
    """Full-sequence block (train / prefill).

    Returns (x, aux, kv) — kv is (k, v) from self-attention (for mamba with
    collect_cache: (conv_state, ssm_state)); used by prefill to build caches.
    """
    aux = jnp.zeros((), jnp.float32)
    if layer_type == "mamba":
        h = norm_apply(params["norm1"], x, cfg)
        if collect_cache:
            y, states = mamba.mamba_apply(params["mixer"], h, cfg, return_state=True)
            return x + y, aux, states
        x = x + mamba.mamba_apply(params["mixer"], h, cfg)
        return x, aux, None

    h = norm_apply(params["norm1"], x, cfg)
    if cfg.use_mla:
        o, kv = attn_mod.mla_apply(params["attn"], h, cfg, positions=positions,
                                   causal=causal)
    else:
        o, kv = attn_mod.attention_apply(params["attn"], h, cfg,
                                         positions=positions, causal=causal)
    x = x + o

    if enc_out is not None:
        hc = norm_apply(params["norm_cross"], x, cfg)
        oc, _ = attn_mod.attention_apply(
            params["cross_attn"], hc, cfg, x_kv=enc_out, causal=False,
            use_rope=False,
        )
        x = x + oc

    h2 = norm_apply(params["norm2"], x, cfg)
    if layer_type == "moe":
        y, aux = moe.moe_apply(params["ffn"], h2, cfg, decode=decode)
    else:
        y = layers.mlp_apply(params["ffn"], h2, act=cfg.act)
    x = x + y
    # Megatron-style sequence-parallel residual stream: the per-layer scan
    # carry (saved for backward) is sharded over the model axis too, which
    # is what lets 32B+ models fit 16 GiB/chip at batch 256×4k.
    if x.shape[1] > 1:
        x = constrain(x, "data", "model", None)
    else:
        x = constrain(x, "data", None, None)
    # bf16 backward stream (§Perf iter 5): halves activation-grad collectives.
    x = layers.grad_cast(x)
    return x, aux, kv


def block_decode_apply(
    params,
    x: jnp.ndarray,
    cfg,
    layer_type: str,
    *,
    cache: dict,
    cache_index,
    cross_len=None,
    length=None,
):
    """One-token decode.  cache is a per-layer dict (see serve.kv_cache);
    ``length`` is the per-slot live token count incl. the new token (None →
    derived from cache_index) — it bounds the decode kernel's KV walk."""
    if layer_type == "mamba":
        y, (conv_s, ssm_s) = mamba.mamba_decode_apply(
            params["mixer"], norm_apply(params["norm1"], x, cfg), cfg,
            conv_state=cache["conv"], ssm_state=cache["ssm"],
        )
        return x + y, {**cache, "conv": conv_s, "ssm": ssm_s}

    h = norm_apply(params["norm1"], x, cfg)
    if cfg.use_mla:
        o, (ckv, krope) = attn_mod.mla_decode_apply(
            params["attn"], h, cfg,
            cache_ckv=cache["ckv"], cache_krope=cache["krope"],
            cache_index=cache_index,
        )
        new_cache = {**cache, "ckv": ckv, "krope": krope}
    else:
        o, (ck, cv) = attn_mod.attention_decode_apply(
            params["attn"], h, cfg,
            cache_k=cache["k"], cache_v=cache["v"], cache_index=cache_index,
            length=length,
        )
        new_cache = {**cache, "k": ck, "v": cv}
    x = x + o

    if "cross_k" in cache:
        hc = norm_apply(params["norm_cross"], x, cfg)
        oc, _ = attn_mod.attention_decode_apply(
            params["cross_attn"], hc, cfg,
            cache_k=cache["cross_k"], cache_v=cache["cross_v"],
            cache_index=cache_index, is_cross=True, cross_len=cross_len,
        )
        x = x + oc

    h2 = norm_apply(params["norm2"], x, cfg)
    if layer_type == "moe":
        y, _ = moe.moe_apply(params["ffn"], h2, cfg, decode=True)
    else:
        y = layers.mlp_apply(params["ffn"], h2, act=cfg.act)
    return x + y, new_cache


def block_paged_decode_apply(
    params,
    x: jnp.ndarray,
    cfg,
    layer_type: str,
    *,
    pool_k,
    pool_v,
    block_tables,
    pos,
    count=None,
    pool_k_fused=None,
    perm=None,
):
    """Windowed decode of one transformer block against the paged KV pool
    (serve.paged): w = 1 is token decode, w = chunk width is chunked
    prefill.  GQA dense/moe only — the paged layout replaces the ring slab
    cache, the other families keep the slot engine."""
    h = norm_apply(params["norm1"], x, cfg)
    o, (pk, pv, pkf) = attn_mod.attention_decode_paged(
        params["attn"], h, cfg,
        pool_k=pool_k, pool_v=pool_v, block_tables=block_tables,
        cache_index=pos, count=count, pool_k_fused=pool_k_fused, perm=perm,
    )
    x = x + o
    h2 = norm_apply(params["norm2"], x, cfg)
    if layer_type == "moe":
        y, _ = moe.moe_apply(params["ffn"], h2, cfg, decode=True)
    else:
        y = layers.mlp_apply(params["ffn"], h2, act=cfg.act)
    return x + y, (pk, pv, pkf)


# ---------------------------------------------------------------------------
# Hybrid (zamba2) shared attention block: fuse(concat(x, x0)) → dense block
# ---------------------------------------------------------------------------


def shared_block_init(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "fuse": layers.linear_init(k1, 2 * cfg.d_model, cfg.d_model, dtype=dtype),
        "block": block_init(k2, cfg, "dense", dtype),
    }


def shared_block_axes(cfg):
    return {
        "fuse": layers.linear_axes(None, None),
        "block": block_axes(cfg, "dense"),
    }


def shared_block_apply(params, x, x0, cfg, *, positions=None):
    h = layers.linear_apply(params["fuse"], jnp.concatenate([x, x0], axis=-1))
    y, _, kv = block_apply(params["block"], h, cfg, "dense",
                           positions=positions, causal=True)
    # Add the block's residual *delta* to the trunk (the block already
    # carries h through its own residuals).
    return x + (y - h), kv


def shared_block_decode_apply(params, x, x0, cfg, *, cache, cache_index):
    h = layers.linear_apply(params["fuse"], jnp.concatenate([x, x0], axis=-1))
    y, new_cache = block_decode_apply(params["block"], h, cfg, "dense",
                                      cache=cache, cache_index=cache_index)
    return x + (y - h), new_cache
