"""Attention modules: GQA/MHA (with KV cache) and DeepSeek-style MLA.

All modules dispatch the score stage through ``repro.core.attend`` so the
paper's DistrAttention drops in via config.  MLA routes its RoPE
sub-dimensions through the exact path (``q_exact``/``k_exact``) because
fusing rotated rows would break the rotation structure (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import AttentionConfig, attend, attend_decode
from repro.core.distr_attention import distr_attention
from repro.core.flash_reference import reference_attention
from repro.models import layers
from repro.models.layers import constrain


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, n, _ = x.shape
    return x.reshape(b, n, n_heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


def _constrain_bhnd(x: jnp.ndarray, attn_shard: str) -> jnp.ndarray:
    if attn_shard == "seq":
        return constrain(x, "data", None, "model", None)
    # Heads over TP; the sequence dim rides the "context" ring axis when
    # the mesh has one (layers.constrain maps "seq" → "context" | None), so
    # activations arrive at the ring attention already sequence-sharded.
    return constrain(x, "data", "model", "seq", None)


# ---------------------------------------------------------------------------
# GQA / MHA
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype=jnp.float32):
    dh = cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": layers.linear_init(k1, cfg.d_model, cfg.n_heads * dh,
                                 bias=cfg.qkv_bias, dtype=dtype),
        "wk": layers.linear_init(k2, cfg.d_model, cfg.n_kv_heads * dh,
                                 bias=cfg.qkv_bias, dtype=dtype),
        "wv": layers.linear_init(k3, cfg.d_model, cfg.n_kv_heads * dh,
                                 bias=cfg.qkv_bias, dtype=dtype),
        "wo": layers.linear_init(k4, cfg.n_heads * dh, cfg.d_model, dtype=dtype),
    }


def attention_axes(cfg):
    return {
        "wq": layers.linear_axes(None, "heads", bias=cfg.qkv_bias),
        "wk": layers.linear_axes(None, "kv_heads", bias=cfg.qkv_bias),
        "wv": layers.linear_axes(None, "kv_heads", bias=cfg.qkv_bias),
        "wo": layers.linear_axes("heads", None),
    }


def attention_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    positions: jnp.ndarray | None = None,
    causal: bool = True,
    x_kv: jnp.ndarray | None = None,
    kv_positions: jnp.ndarray | None = None,
    attn_cfg: AttentionConfig | None = None,
    use_rope: bool | None = None,
):
    """Self/cross attention for train & prefill.

    Returns ``(out, (k, v))`` — raw per-head K/V so the serve layer can build
    caches from the prefill pass without re-projecting.
    """
    b, n, _ = x.shape
    attn_cfg = attn_cfg if attn_cfg is not None else cfg.attention
    use_rope = (cfg.pos == "rope") if use_rope is None else use_rope
    src = x if x_kv is None else x_kv

    q = _split_heads(layers.linear_apply(params["wq"], x), cfg.n_heads)
    k = _split_heads(layers.linear_apply(params["wk"], src), cfg.n_kv_heads)
    v = _split_heads(layers.linear_apply(params["wv"], src), cfg.n_kv_heads)

    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(n), (b, n))
        if kv_positions is None:
            kv_positions = (
                positions
                if x_kv is None
                else jnp.broadcast_to(jnp.arange(src.shape[1]), (b, src.shape[1]))
            )
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, kv_positions, cfg.rope_theta)

    q = _constrain_bhnd(q, cfg.attn_shard)
    k = _constrain_bhnd(k, cfg.attn_shard)
    v = _constrain_bhnd(v, cfg.attn_shard)

    o = attend(q, k, v, attn_cfg, causal=causal)
    o = _constrain_bhnd(o, cfg.attn_shard)
    out = layers.linear_apply(params["wo"], _merge_heads(o))
    return out, (k, v)


def _as_pos_vector(cache_index, b: int) -> jnp.ndarray:
    """Normalise cache_index (scalar or (B,)) to a (B,) int32 vector —
    per-slot positions enable continuous batching in the serve engine."""
    idx = jnp.asarray(cache_index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (b,))
    return idx


def cache_insert(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Insert per-batch rows at per-batch positions (ring layout).

    cache: (B, H, S, d); new: (B, H, 1, d); pos: (B,) int32.  Positions are
    absolute; the write slot is ``pos mod S`` — past ``S`` tokens the ring
    wraps and the oldest entries are overwritten (serve.kv_cache ring
    invariants; the engine rides this as sliding-window eviction, attending
    over the most recent ``min(length, S)`` tokens).
    """
    s = cache.shape[2]
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p % s, 0))
    )(cache, new.astype(cache.dtype), pos)


def _live_lengths(length, pos: jnp.ndarray, max_len: int) -> jnp.ndarray:
    """Per-slot live token counts for the decode kernels: the caller-tracked
    total (serve cache ``length``) when given, else derived from the write
    position; always clamped to the ring capacity."""
    total = length if length is not None else pos + 1
    return jnp.minimum(jnp.asarray(total, jnp.int32), max_len)


def attention_decode_fused(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    cache_k_fused: jnp.ndarray,
    perm: jnp.ndarray,  # (Hkv, dh) static permutation for this layer
    cache_index: jnp.ndarray,
    length: jnp.ndarray | None = None,
):
    """Beyond-paper decode: scores read the fused K̂ cache (d/G* columns per
    token) instead of K — (1-1/G*)·½ fewer KV bytes on the memory-bound
    decode path, on top of the split-K kernel's live-length grid
    (``core.api.attend_decode`` → ``kernels.ops.decode_attention``).  K is
    still written (for re-scoring/eviction) but stays cold.  See
    serve.kv_cache / benchmarks/distr_decode.py."""
    from repro.serve import kv_cache as kvc

    b, n, _ = x.shape  # n == 1
    g = cfg.attention.distr.group_size
    pos = _as_pos_vector(cache_index, b)
    q = _split_heads(layers.linear_apply(params["wq"], x), cfg.n_heads)
    k = _split_heads(layers.linear_apply(params["wk"], x), cfg.n_kv_heads)
    v = _split_heads(layers.linear_apply(params["wv"], x), cfg.n_kv_heads)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
    if cache_k is not None:  # raw K optional: pure decode never reads it
        cache_k = cache_insert(cache_k, k, pos)
    cache_v = cache_insert(cache_v, v, pos)
    k_f_new = kvc.fuse_new_k(k, perm, g)
    cache_k_fused = cache_insert(cache_k_fused, k_f_new, pos)

    scale = 1.0 / (cfg.head_dim_**0.5)
    lengths = _live_lengths(length, pos, cache_k_fused.shape[2])
    o = attend_decode(
        q, None, cache_v, cfg.attention, lengths=lengths,
        k_fused=cache_k_fused, perm=perm, group_size=g, scale=scale,
    )
    out = layers.linear_apply(params["wo"], _merge_heads(o.astype(x.dtype)))
    return out, (cache_k, cache_v, cache_k_fused)


def attention_decode_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    cache_index: jnp.ndarray,
    is_cross: bool = False,
    cross_len: jnp.ndarray | None = None,
    length: jnp.ndarray | None = None,
):
    """One-token decode against a (B, Hkv, S, dh) ring cache.

    Self-attention inserts the new K/V at per-slot ``cache_index``;
    cross-attention reads a prefilled cache.  The score/value stages run on
    the split-K flash-decoding kernel via ``core.api.attend_decode`` (exact
    attention — the paper applies DistrAttention to the prefill/score
    stage; see serve.kv_cache for the beyond-paper fused-K̂ decode cache),
    visiting only the ``length`` live KV blocks per slot.
    """
    b, n, _ = x.shape  # n == 1
    pos = _as_pos_vector(cache_index, b)
    q = _split_heads(layers.linear_apply(params["wq"], x), cfg.n_heads)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, pos[:, None], cfg.rope_theta)

    if is_cross:
        lengths = (
            jnp.minimum(cross_len, cache_k.shape[2])
            if cross_len is not None
            else jnp.full((b,), cache_k.shape[2], jnp.int32)
        )
    else:
        k = _split_heads(layers.linear_apply(params["wk"], x), cfg.n_kv_heads)
        v = _split_heads(layers.linear_apply(params["wv"], x), cfg.n_kv_heads)
        if cfg.pos == "rope":
            k = layers.apply_rope(k, pos[:, None], cfg.rope_theta)
        cache_k = cache_insert(cache_k, k, pos)
        cache_v = cache_insert(cache_v, v, pos)
        lengths = _live_lengths(length, pos, cache_k.shape[2])

    o = attend_decode(q, cache_k, cache_v, cfg.attention, lengths=lengths)
    out = layers.linear_apply(params["wo"], _merge_heads(o.astype(x.dtype)))
    return out, (cache_k, cache_v)


def paged_insert(
    pool: jnp.ndarray,
    new: jnp.ndarray,
    block_tables: jnp.ndarray,
    pos: jnp.ndarray,
    count: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Insert a token window into a paged KV pool through the block table.

    pool: (P, Hkv, bs, d); new: (B, Hkv, w, d); block_tables: (B,
    max_blocks) physical ids; pos: (B,) absolute start positions.  Token
    ``t`` of request ``b`` lands at block ``bt[b, (pos+t)//bs]`` offset
    ``(pos+t) mod bs``.  ``count`` (B,) gates writes: rows ``t ≥ count[b]``
    (padding in a chunked prefill, idle decode lanes) are redirected to the
    reserved garbage block so they can never corrupt live KV.
    """
    from repro.kernels.paged_decode import GARBAGE_BLOCK

    bs = pool.shape[2]
    b, hkv, w, d = new.shape
    max_blocks = block_tables.shape[1]
    # One vectorised scatter over all B·w writes (a loop of per-token
    # dynamic_update_slice would copy the whole pool per write on
    # non-donating backends).  Distinct live (block, offset) pairs never
    # collide — distinct requests own distinct blocks — and any number of
    # masked writes may collide on the garbage block, whose content is
    # never read.
    p = pos[:, None] + jnp.arange(w)[None, :]  # (B, w) absolute positions
    blk_idx = jnp.minimum(p // bs, max_blocks - 1)
    blk = jnp.take_along_axis(block_tables, blk_idx, axis=1)  # (B, w)
    live = jnp.arange(w)[None, :] < (
        count[:, None] if count is not None else w
    )
    # Positions past the table's capacity also divert to garbage — a
    # clamped blk_idx would silently overwrite the LAST live block.
    live = live & (p < max_blocks * bs)
    blk = jnp.where(live, blk, GARBAGE_BLOCK)
    vals = new.astype(pool.dtype).transpose(0, 2, 1, 3).reshape(
        b * w, hkv, d
    )
    return pool.at[blk.reshape(-1), :, (p % bs).reshape(-1), :].set(vals)


def attention_decode_paged(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    pool_k: jnp.ndarray | None,
    pool_v: jnp.ndarray,
    block_tables: jnp.ndarray,
    cache_index: jnp.ndarray,
    count: jnp.ndarray | None = None,
    pool_k_fused: jnp.ndarray | None = None,
    perm: jnp.ndarray | None = None,
):
    """Windowed decode against the paged block pool (w = 1 for token
    decode, w = chunk width for chunked prefill).

    x: (B, w, d_model); ``cache_index``: (B,) absolute start positions;
    ``count``: (B,) live tokens in this window (padding rows write to the
    garbage block and their outputs are ignored by the caller).  The
    attention window is banded — token ``t`` sees positions ``≤ pos + t``
    — so a width-``c`` chunk reproduces causal prefill exactly
    (kernels/paged_decode.py).  Fused-K̂ variant: pass ``pool_k_fused`` +
    the layer's static ``perm``; the raw K pool may be None (it is never
    read or written on the fused paged path).

    Decode slides past the table's capacity: the write position wraps
    (``pos mod capacity``), recycling the request's HEAD blocks in place —
    the oldest token is overwritten and the kernel attends the live window
    ``min(pos + w, capacity)`` — the paged analog of the slot engine's
    ring-cache eviction.  RoPE stays at the *absolute* position, exactly
    like the slot ring write, so the two windowed decodes agree.  Prompts
    are admission-bounded below capacity, so chunked prefill never wraps.
    """
    from repro.serve import kv_cache as kvc

    b, w, _ = x.shape
    pos = _as_pos_vector(cache_index, b)
    positions = pos[:, None] + jnp.arange(w)[None, :]
    q = _split_heads(layers.linear_apply(params["wq"], x), cfg.n_heads)
    k = _split_heads(layers.linear_apply(params["wk"], x), cfg.n_kv_heads)
    v = _split_heads(layers.linear_apply(params["wv"], x), cfg.n_kv_heads)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    capacity = block_tables.shape[1] * pool_v.shape[2]
    wpos = pos % capacity
    pool_v = paged_insert(pool_v, v, block_tables, wpos, count)
    scale = 1.0 / (cfg.head_dim_**0.5)
    # Kernel lengths include the whole window (pos + w): live row t's band
    # col < pos + t + 1 then lands exactly on its own position; padded rows
    # only ever widen *their own* (discarded) reads.  Past capacity every
    # pool position is live (the ring overwrote the oldest), so the band
    # clamps to the full table.
    lengths = jnp.minimum(pos + w, capacity)
    if pool_k_fused is not None:
        g = cfg.attention.distr.group_size
        k_f_new = kvc.fuse_new_k(k, perm, g)
        pool_k_fused = paged_insert(pool_k_fused, k_f_new, block_tables, wpos,
                                    count)
        o = attend_decode(
            q, None, pool_v, cfg.attention, lengths=lengths,
            k_fused=pool_k_fused, perm=perm, group_size=g, scale=scale,
            block_tables=block_tables,
        )
        new_pools = (None, pool_v, pool_k_fused)
    else:
        pool_k = paged_insert(pool_k, k, block_tables, wpos, count)
        o = attend_decode(
            q, pool_k, pool_v, cfg.attention, lengths=lengths, scale=scale,
            block_tables=block_tables,
        )
        new_pools = (pool_k, pool_v, None)
    out = layers.linear_apply(params["wo"], _merge_heads(o.astype(x.dtype)))
    return out, new_pools


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank Q, compressed KV cache, decoupled RoPE.
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype=jnp.float32):
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    params = {
        "wq_a": layers.linear_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype),
        "q_norm": layers.rmsnorm_init(cfg.q_lora_rank, dtype),
        "wq_b": layers.linear_init(ks[1], cfg.q_lora_rank, h * (nope + rope_d), dtype=dtype),
        "wkv_a": layers.linear_init(ks[2], cfg.d_model, cfg.kv_lora_rank + rope_d, dtype=dtype),
        "kv_norm": layers.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wk_b": layers.linear_init(ks[3], cfg.kv_lora_rank, h * nope, dtype=dtype),
        "wv_b": layers.linear_init(ks[4], cfg.kv_lora_rank, h * vd, dtype=dtype),
        "wo": layers.linear_init(ks[5], h * vd, cfg.d_model, dtype=dtype),
    }
    return params


def mla_axes(cfg):
    return {
        "wq_a": layers.linear_axes(None, None),
        "q_norm": layers.rmsnorm_axes(),
        "wq_b": layers.linear_axes(None, "heads"),
        "wkv_a": layers.linear_axes(None, None),
        "kv_norm": layers.rmsnorm_axes(),
        "wk_b": layers.linear_axes(None, "heads"),
        "wv_b": layers.linear_axes(None, "heads"),
        "wo": layers.linear_axes("heads", None),
    }


def _mla_qkv(params, x, cfg, positions):
    """Shared projection stage → per-head q_nope/q_rope/k_nope/k_rope/v."""
    b, n, _ = x.shape
    h = cfg.n_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim

    q_l = layers.rmsnorm_apply(params["q_norm"], layers.linear_apply(params["wq_a"], x))
    q = _split_heads(layers.linear_apply(params["wq_b"], q_l), h)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv_a = layers.linear_apply(params["wkv_a"], x)
    c_kv = layers.rmsnorm_apply(params["kv_norm"], kv_a[..., : cfg.kv_lora_rank])
    k_rope_raw = kv_a[..., cfg.kv_lora_rank:][:, None]  # (B, 1, N, rope_d)

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(n), (b, n))
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope_raw, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    positions: jnp.ndarray | None = None,
    causal: bool = True,
    attn_cfg: AttentionConfig | None = None,
):
    """MLA for train/prefill (naive up-projected path).

    DistrAttention grouping applies to the nope sub-dimension only; RoPE dims
    go through the exact score path.
    """
    b, n, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    attn_cfg = attn_cfg if attn_cfg is not None else cfg.attention
    scale = 1.0 / ((nope + rope_d) ** 0.5)

    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    k_nope = _split_heads(layers.linear_apply(params["wk_b"], c_kv), h)
    v = _split_heads(layers.linear_apply(params["wv_b"], c_kv), h)

    q_nope = constrain(q_nope, "data", "model", None, None)
    k_nope = constrain(k_nope, "data", "model", None, None)
    v = constrain(v, "data", "model", None, None)

    if attn_cfg.impl in ("distr", "pallas_distr"):
        # The q_exact/k_exact (RoPE) side-channel only exists on the pure-JAX
        # path, so MLA keeps it for pallas_distr too; GQA/MHA attention is
        # where the kernel custom_vjp path engages (see core.api.attend).
        k_rope_bc = jnp.broadcast_to(k_rope, (b, h, n, rope_d))
        o = distr_attention(
            q_nope, k_nope, v, attn_cfg.distr,
            causal=causal, scale=scale,
            q_exact=q_rope, k_exact=k_rope_bc,
        )
    else:
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, h, n, rope_d))], axis=-1
        )
        o = attend(q_full, k_full, v, attn_cfg, causal=causal, scale=scale)

    o = constrain(o, "data", "model", None, None)
    out = layers.linear_apply(params["wo"], _merge_heads(o))
    return out, (c_kv, k_rope)


def mla_decode_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    cache_ckv: jnp.ndarray,  # (B, S, kv_lora)
    cache_krope: jnp.ndarray,  # (B, S, rope_d)
    cache_index: jnp.ndarray,
):
    """Absorbed-matrix MLA decode: attends in the compressed c_kv space.

    Scores: q_nopeᵀ·W_ukᵀ·c_kv + q_ropeᵀ·k_rope;  output: (P·c_kv)·W_uv.
    The cache stores only (kv_lora + rope_d) per token — MLA's memory win —
    and no per-step up-projection of the full cache is needed.
    """
    b, n, _ = x.shape  # n == 1
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / ((nope + rope_d) ** 0.5)
    pos = _as_pos_vector(cache_index, b)

    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, x, cfg, pos[:, None])

    insert2d = jax.vmap(
        lambda c, nw, p: jax.lax.dynamic_update_slice(c, nw, (p, 0))
    )
    cache_ckv = insert2d(cache_ckv, c_kv_new.astype(cache_ckv.dtype), pos)
    cache_krope = insert2d(
        cache_krope, k_rope_new[:, 0].astype(cache_krope.dtype), pos
    )

    # Absorb W_uk into q: (B,H,1,nope) × (kv_lora, H, nope) → (B,H,1,kv_lora)
    w_uk = params["wk_b"]["w"].reshape(cfg.kv_lora_rank, h, nope)
    q_abs = jnp.einsum("bhnd,chd->bhnc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    # bf16 cache reads + f32 accumulation: no materialised f32 cache copy.
    ckv = cache_ckv  # (B, S, C)
    krp = cache_krope  # (B, S, R)
    s = jnp.einsum("bhnc,bsc->bhns", q_abs.astype(ckv.dtype), ckv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhnr,bsr->bhns", q_rope.astype(krp.dtype), krp,
                       preferred_element_type=jnp.float32)
    s = s * scale
    kv_mask = (
        jnp.arange(ckv.shape[1])[None, :] <= pos[:, None]
    )[:, None, None, :]
    s = jnp.where(kv_mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)

    ctx = jnp.einsum("bhns,bsc->bhnc", p.astype(ckv.dtype), ckv,
                     preferred_element_type=jnp.float32)  # (B,H,1,C)
    w_uv = params["wv_b"]["w"].reshape(cfg.kv_lora_rank, h, vd)
    o = jnp.einsum("bhnc,chd->bhnd", ctx, w_uv.astype(jnp.float32))
    out = layers.linear_apply(params["wo"], _merge_heads(o.astype(x.dtype)))
    return out, (cache_ckv, cache_krope)
