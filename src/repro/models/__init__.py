"""Model zoo: primitive layers, attention (GQA/MLA), MoE, Mamba-2, blocks,
and full-LM assembly for all assigned architectures."""
from repro.models import attention, layers, lm, mamba, moe, transformer

__all__ = ["attention", "layers", "lm", "mamba", "moe", "transformer"]
