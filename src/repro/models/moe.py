"""Mixture-of-Experts layer with expert parallelism.

Three dispatch implementations, selected by ``cfg.moe_impl`` (or "auto"):

* ``dense_onehot`` — GShard-style einsum dispatch with a capacity dim.
  O(T·E·C) memory: only viable at test scale; used when no mesh is active.
* ``ep_a2a``       — production path.  ``shard_map`` over the "model" axis:
  tokens are sequence-sharded, routed assignments are exchanged with
  ``all_to_all`` to their owning expert shard, locally sorted into per-expert
  batches, batch-einsum'd through the shard's experts, and returned.  This is
  the EP pattern that scales to 160-expert DeepSeek-V2 on a 16-way model
  axis.
* ``ep_psum``      — decode path.  Token counts are tiny, so every expert
  shard applies its local experts to all tokens (masked) and a psum over the
  model axis combines; no all_to_all latency on the decode critical path.

Router: softmax → top-k (renormalised), switch-style load-balance aux loss
plus a z-loss for logit drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.utils.jax_compat import axis_size, get_abstract_mesh, shard_map


def moe_init(key, cfg, dtype=jnp.float32):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = d**-0.5
    params = {
        "router": {"w": (jax.random.normal(ks[0], (d, e)) * scale).astype(jnp.float32)},
        "experts": {
            "gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
            "up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
            "down": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(dtype),
        },
    }
    if cfg.n_shared_experts:
        params["shared"] = layers.mlp_init(
            ks[4], d, f * cfg.n_shared_experts, act="silu", dtype=dtype
        )
    return params


def moe_axes(cfg):
    axes = {
        "router": {"w": (None, None)},
        "experts": {
            "gate": ("experts", None, None),
            "up": ("experts", None, None),
            "down": ("experts", None, None),
        },
    }
    if cfg.n_shared_experts:
        axes["shared"] = layers.mlp_axes(act="silu")
    return axes


def _route(router_w: jnp.ndarray, x_flat: jnp.ndarray, cfg):
    """x_flat (T, D) → (weights (T, k), ids (T, k), aux_loss scalar)."""
    logits = x_flat.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    weights, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Switch-transformer load-balance loss + z-loss.
    e = cfg.n_experts
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return weights, ids, aux + 1e-3 * z


def _expert_ffn(gate_w, up_w, down_w, xe: jnp.ndarray) -> jnp.ndarray:
    """Batched SwiGLU over stacked experts.  xe: (E, C, D) → (E, C, D)."""
    gate = jnp.einsum("ecd,edf->ecf", xe, gate_w.astype(xe.dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, up_w.astype(xe.dtype))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, down_w.astype(xe.dtype))


# ---------------------------------------------------------------------------
# dense_onehot — test-scale reference dispatch
# ---------------------------------------------------------------------------


def _moe_dense_onehot(params, x: jnp.ndarray, cfg):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    weights, ids, aux = _route(params["router"]["w"], xf, cfg)
    e, k = cfg.n_experts, cfg.moe_top_k
    # Floor keeps tiny decode batches drop-free (capacity dropping is a
    # throughput trade for big T, not meant to distort 2-token steps).
    cap = max(int(cfg.capacity_factor * t * k / e), min(t, 8))

    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)  # (T, k, E)
    mask = onehot.max(axis=1)  # (T, E) 0/1
    weight_e = (onehot * weights[..., None]).sum(axis=1)  # (T, E)
    # position of each token within its expert queue (first-come order)
    pos = jnp.cumsum(mask, axis=0) - 1.0  # (T, E)
    keep = (pos < cap) * mask
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = keep[..., None] * pos_oh  # (T, E, C)
    combine = (keep * weight_e)[..., None] * pos_oh

    xe = jnp.einsum("tec,td->ecd", dispatch, xf.astype(jnp.float32))
    ye = _expert_ffn(
        params["experts"]["gate"], params["experts"]["up"],
        params["experts"]["down"], xe,
    )
    y = jnp.einsum("tec,ecd->td", combine, ye)
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# ep_a2a — shard_map expert parallelism over the "model" axis
# ---------------------------------------------------------------------------


def _moe_ep_a2a(params, x: jnp.ndarray, cfg, mesh):
    axis = "model"
    batch_axes = tuple(a for a in mesh.axis_names if a != axis)
    pspec_x = jax.sharding.PartitionSpec(batch_axes, axis, None)
    e_spec = jax.sharding.PartitionSpec(axis, None, None)
    r_spec = jax.sharding.PartitionSpec(None, None)
    pspec_scalar = jax.sharding.PartitionSpec()

    def local_moe(xl, router_w, gate_w, up_w, down_w):
        # xl: (b_loc, s_loc, D); gate/up/down: (E_loc, ·, ·) local experts.
        ep = axis_size(axis, mesh)
        bl, sl, d = xl.shape
        t = bl * sl
        xf = xl.reshape(t, d)
        weights, ids, aux = _route(router_w, xf, cfg)
        k = cfg.moe_top_k
        e_loc = cfg.n_experts // ep
        cap = max(int(cfg.capacity_factor * t * k / ep), 8)

        # --- group routed assignments by destination shard --------------
        flat_ids = ids.reshape(-1)  # (T·k,)
        flat_w = weights.reshape(-1)
        dest = flat_ids // e_loc
        order = jnp.argsort(dest)
        dsorted = dest[order]
        counts = jnp.bincount(dest, length=ep)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k) - starts[dsorted]
        keep = pos < cap
        slot = jnp.where(keep, dsorted * cap + pos, ep * cap)  # overflow bin
        src_tok = order // k

        send_x = jnp.zeros((ep * cap + 1, d), xf.dtype).at[slot].set(xf[src_tok])
        send_e = jnp.full((ep * cap + 1,), e_loc, jnp.int32).at[slot].set(
            (flat_ids[order] % e_loc).astype(jnp.int32)
        )
        send_x, send_e = send_x[:-1], send_e[:-1]

        # --- all_to_all: chunk j of shard i → shard j --------------------
        recv_x = jax.lax.all_to_all(
            send_x.reshape(ep, cap, d), axis, split_axis=0, concat_axis=0
        ).reshape(ep * cap, d)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(ep, cap, 1), axis, split_axis=0, concat_axis=0
        ).reshape(ep * cap)

        # --- local per-expert batching (sort by local expert id) ---------
        t_r = ep * cap
        cap2 = max(int(cfg.capacity_factor * t_r / e_loc), 8)
        order2 = jnp.argsort(recv_e)
        esort = recv_e[order2]
        counts2 = jnp.bincount(recv_e, length=e_loc + 1)[: e_loc + 1]
        starts2 = jnp.cumsum(counts2) - counts2
        pos2 = jnp.arange(t_r) - jnp.take(starts2, jnp.minimum(esort, e_loc))
        valid2 = (esort < e_loc) & (pos2 < cap2)
        slot2 = jnp.where(valid2, esort * cap2 + pos2, e_loc * cap2)

        xe = jnp.zeros((e_loc * cap2 + 1, d), jnp.float32)
        xe = xe.at[slot2].set(recv_x[order2].astype(jnp.float32))
        ye = _expert_ffn(gate_w, up_w, down_w, xe[:-1].reshape(e_loc, cap2, d))
        ye_flat = ye.reshape(e_loc * cap2, d)

        # --- undo the local sort, reverse exchange -----------------------
        y_sorted = jnp.where(
            valid2[:, None],
            jnp.take(ye_flat, jnp.minimum(slot2, e_loc * cap2 - 1), axis=0),
            0.0,
        )
        y_recv = jnp.zeros((t_r, d), jnp.float32).at[order2].set(y_sorted)
        y_send = jax.lax.all_to_all(
            y_recv.reshape(ep, cap, d), axis, split_axis=0, concat_axis=0
        ).reshape(t_r, d)

        # --- combine: weighted scatter-add back onto tokens --------------
        contrib = jnp.where(
            keep[:, None],
            jnp.take(y_send, jnp.minimum(slot, t_r - 1), axis=0)
            * flat_w[order][:, None],
            0.0,
        )
        y_tok = jnp.zeros((t, d), jnp.float32).at[src_tok].add(contrib)

        aux = jax.lax.pmean(aux, axis)
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)
        return y_tok.reshape(bl, sl, d).astype(xl.dtype), aux

    y, aux = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(pspec_x, r_spec, e_spec, e_spec, e_spec),
        out_specs=(pspec_x, pspec_scalar),
    )(
        x,
        params["router"]["w"],
        params["experts"]["gate"],
        params["experts"]["up"],
        params["experts"]["down"],
    )
    return y, aux


# ---------------------------------------------------------------------------
# ep_psum — decode path (tiny token counts, no all_to_all)
# ---------------------------------------------------------------------------


def _moe_ep_psum(params, x: jnp.ndarray, cfg, mesh):
    axis = "model"
    batch_axes = tuple(a for a in mesh.axis_names if a != axis)
    pspec_x = jax.sharding.PartitionSpec(batch_axes, None, None)
    e_spec = jax.sharding.PartitionSpec(axis, None, None)
    r_spec = jax.sharding.PartitionSpec(None, None)
    pspec_scalar = jax.sharding.PartitionSpec()

    def local_moe(xl, router_w, gate_w, up_w, down_w):
        ep = axis_size(axis, mesh)
        bl, sl, d = xl.shape
        t = bl * sl
        xf = xl.reshape(t, d)
        weights, ids, aux = _route(router_w, xf, cfg)
        e_loc = cfg.n_experts // ep
        lo = jax.lax.axis_index(axis) * e_loc

        rel = ids - lo  # (T, k)
        in_range = (rel >= 0) & (rel < e_loc)
        oh = jax.nn.one_hot(jnp.where(in_range, rel, 0), e_loc) * (
            jnp.where(in_range, weights, 0.0)
        )[..., None]
        local_w = oh.sum(axis=1)  # (T, e_loc)

        xe = jnp.broadcast_to(xf.astype(jnp.float32), (e_loc, t, d))
        ye = _expert_ffn(gate_w, up_w, down_w, xe)
        y = jnp.einsum("te,etd->td", local_w, ye)
        y = jax.lax.psum(y, axis)
        aux = jax.lax.pmean(aux, axis)
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(bl, sl, d).astype(xl.dtype), aux

    y, aux = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(pspec_x, r_spec, e_spec, e_spec, e_spec),
        out_specs=(pspec_x, pspec_scalar),
    )(
        x,
        params["router"]["w"],
        params["experts"]["gate"],
        params["experts"]["up"],
        params["experts"]["down"],
    )
    return y, aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def _active_mesh():
    try:
        mesh = get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return None
        return mesh
    except Exception:
        return None


def moe_apply(params, x: jnp.ndarray, cfg, *, decode: bool = False):
    """(B, S, D) → (y, aux_loss).  Implementation chosen by cfg/mesh."""
    impl = cfg.moe_impl
    mesh = _active_mesh()

    if impl == "auto":
        if mesh is None:
            impl = "dense_onehot"
        else:
            impl = "ep_psum" if decode else "ep_a2a"

    if impl == "dense_onehot" or mesh is None:
        y, aux = _moe_dense_onehot(params, x, cfg)
    elif impl == "ep_a2a":
        y, aux = _moe_ep_a2a(params, x, cfg, mesh)
    elif impl == "ep_psum":
        y, aux = _moe_ep_psum(params, x, cfg, mesh)
    else:
        raise ValueError(f"unknown moe_impl {impl!r}")

    if "shared" in params:
        y = y + layers.mlp_apply(params["shared"], x, act="silu")
    return y, aux
