"""Deterministic fault injection shared by the serving and training tiers.

Production failure modes don't show up in happy-path tests, so both serve
engines and the training stack expose **named fault points** that an
injected :class:`FaultInjector` can fire deterministically — the chaos
suites (tests/test_chaos.py, tests/test_cluster.py, tests/test_train_chaos.py)
drive each one and assert every request / training run still terminates in
an explicit, recoverable state with no leaked resources.

The catalog is split per domain; :data:`POINTS` is the union a
:class:`FaultSpec` validates against.

Serve points (DESIGN.md §Robustness, §Cluster tier):

  pool_exhausted    block-pool allocation fails even though blocks are free
                    (models fragmentation / a buggy allocator under load);
                    fired inside ``PagedServeEngine.alloc``.
  nan_logits        a request's logits row is poisoned with NaN (models a
                    numerical blow-up in the model step); fired wherever
                    logits are produced (decode tick, prefill chunk, slot
                    decode) — exercises the numeric health guards.
  stuck_step        a model step raises instead of returning (models a hung
                    or crashed device call surfacing as an error); the
                    scheduler retries the culprit a bounded number of times
                    then fails it.  Raised as :class:`InjectedFault`.
  restore_failure   ``restore`` of a preempted request's KV raises (models
                    a host↔device copy failure); retried with exponential
                    backoff, bounded, then the request fails.
  slow_step         the scheduler's clock jumps forward by ``delay``
                    seconds (models a straggling step) — exercises the
                    deadline-expiry path without wall-clock sleeps.
  dead_ring_shard   a ring context-parallel KV shard never arrives at its
                    consumers (models a dead host mid-ring); implemented as
                    ``distributed.ring_attention.dead_shard_fault`` — the
                    ring skips the shard's hops and serves a degraded but
                    finite result.
  mesh_prefill      the whole-prompt ring prefill of a mesh-capable paged
                    replica raises (models a collective timing out / a mesh
                    device lost mid-prefill); fired inside
                    ``PagedServeEngine.prefill_mesh_run`` BEFORE any pool
                    write, so a failed ring prefill never poisons the block
                    pool — the scheduler retries the culprit a bounded
                    number of times then fails it, and the cluster tier's
                    failover replay re-routes it to another capable replica.
                    Raised as :class:`InjectedFault`.
  replica_crash     an entire engine replica's process dies (models OOM
                    kill / host loss in the multi-replica tier); consulted
                    by ``serve.cluster.ClusterRouter`` once per tick per
                    replica with ``uid`` = the REPLICA id — the replica
                    stops heartbeating, the router detects the death after
                    ``heartbeat_misses`` ticks and redelivers its in-flight
                    requests to survivors.

Train points (DESIGN.md §Training robustness):

  ckpt_torn_write     a checkpoint publishes with corrupt bytes (models bit
                      rot / a lying fsync / a partial flush that the atomic
                      rename alone cannot catch); consulted once per
                      ``train.checkpoint.save_checkpoint`` with ``uid`` =
                      the STEP being saved — the published directory fails
                      manifest verification and resume/rollback falls back
                      to the newest *verified* checkpoint.
  nan_grad            the loss goes non-finite inside the jitted step
                      (models a numerical blow-up); the in-step NaN guard
                      suppresses the update and the Trainer counts a skip
                      (``nan_policy`` decides skip vs halt).
  loss_spike          the reported loss/grad-norm jump by ``scale``×
                      (default 64) without the update being suppressed
                      (models silent divergence — bad lr region, corrupt
                      activations); the EWMA/z-score anomaly guard rolls
                      params+opt back to the last verified checkpoint and
                      advances the data stream past the offending window.
  worker_loss         a training worker stops heartbeating for good
                      (``uid`` = the WORKER id); consulted once per
                      supervisor tick per worker — the FailureDetector
                      declares it dead, the supervisor replans the mesh to
                      the survivor count and restores from the last
                      verified checkpoint.
  slow_worker         a worker's simulated step time grows by ``delay``
                      (``uid`` = the WORKER id); feeds the supervisor's
                      per-worker step-time tracking — the StragglerPolicy
                      flags it and, after ``patience`` consecutive flags,
                      the worker is excluded via the same elastic path.
  data_shard_corrupt  a batch arrives with scrambled labels (models a
                      corrupt data shard / reader bug); the resulting loss
                      excursion is the anomaly guard's problem — rollback
                      re-trains past the window on the advanced stream.

Triggers are *counted*: a :class:`FaultSpec` fires on hits
``after ≤ hit < after + times`` of its point (per matching uid), so a
fault can be transient (``times=2``) or persistent (``times=-1``) and every
run is reproducible — including across a rollback, where re-executed steps
keep counting consults and an exhausted spec does not re-fire.
"""
from __future__ import annotations

from dataclasses import dataclass, field

#: Fault points consulted by the serving tier (engines, scheduler, router).
SERVE_POINTS = (
    "pool_exhausted",
    "nan_logits",
    "stuck_step",
    "restore_failure",
    "slow_step",
    "dead_ring_shard",
    "mesh_prefill",
    "replica_crash",
)

#: Fault points consulted by the training tier (checkpoint, Trainer,
#: TrainSupervisor).
TRAIN_POINTS = (
    "ckpt_torn_write",
    "nan_grad",
    "loss_spike",
    "worker_loss",
    "slow_worker",
    "data_shard_corrupt",
)

#: The full catalog a FaultSpec validates against.
POINTS = SERVE_POINTS + TRAIN_POINTS


class InjectedFault(Exception):
    """An injected failure surfacing through an engine primitive.  Carries
    the fault point and the culprit uid so the scheduler can retry / fail
    exactly the affected request and keep the batch alive."""

    def __init__(self, point: str, uid: int | None = None):
        self.point = point
        self.uid = uid
        super().__init__(f"injected fault {point!r} (uid={uid})")


@dataclass
class FaultSpec:
    """One deterministic trigger: fire ``point`` for hits ``after ≤ hit <
    after + times`` (``times=-1`` → forever), optionally restricted to one
    request / worker / step (``uid``).  ``delay`` is the clock jump for
    ``slow_step`` and the step-time inflation for ``slow_worker``;
    ``scale`` the loss multiplier for ``loss_spike`` (0 → the trainer's
    default); ``shards`` the dead set for ``dead_ring_shard``."""

    point: str
    uid: int | None = None
    after: int = 0
    times: int = 1
    delay: float = 0.0
    scale: float = 0.0
    shards: tuple[int, ...] = ()
    _hits: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; catalog: {POINTS}"
            )

    def _matches(self, uid: int | None) -> bool:
        return self.uid is None or uid == self.uid

    def _hit(self) -> bool:
        """Count one hit; True when this hit is inside the firing window."""
        h = self._hits
        self._hits += 1
        if h < self.after:
            return False
        return self.times < 0 or h < self.after + self.times


class FaultInjector:
    """A set of :class:`FaultSpec` triggers consulted at engine fault
    points.  ``fires(point, uid)`` counts one hit on every matching spec
    and returns the first spec whose window covers it (None otherwise) —
    pure host-side bookkeeping, deterministic across runs."""

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...] = ()):
        self.specs = list(specs)

    def fires(self, point: str, uid: int | None = None) -> FaultSpec | None:
        fired = None
        for s in self.specs:
            if s.point == point and s._matches(uid):
                if s._hit() and fired is None:
                    fired = s
        return fired

    def raise_if(self, point: str, uid: int | None = None) -> None:
        if self.fires(point, uid) is not None:
            raise InjectedFault(point, uid)

    def dead_shards(self) -> frozenset[int]:
        """Union of shard ids across active ``dead_ring_shard`` specs (for
        wiring into ``distributed.ring_attention.dead_shard_fault``)."""
        out: set[int] = set()
        for s in self.specs:
            if s.point == "dead_ring_shard":
                out.update(s.shards)
        return frozenset(out)


#: Engines default to this — zero per-tick overhead when nothing is injected.
NULL_INJECTOR = FaultInjector(())
