"""The single wall-clock source for serve/train time reads.

Every serve/train component takes an injectable ``clock`` (the chaos suites
inject tick clocks so deadlines, TTFT, and trace timestamps are
deterministic) and defaults to :data:`perf_clock` via :func:`resolve_clock`.
No other module under ``src/repro/serve`` or ``src/repro/train`` may call
``time.perf_counter()`` / ``time.monotonic()`` / ``time.time()`` directly —
a bare read there would bypass injection and make trace timestamps
non-deterministic under fault injection.  tests/test_obs.py enforces this
with a source scan whitelisting only this module (plus the tune/ measurement
harness and benchmarks/, which time *hardware*, not lifecycle events).
"""
from __future__ import annotations

import time

#: The production clock: monotonic, sub-µs resolution, not wall-time-adjusted.
perf_clock = time.perf_counter


def resolve_clock(clock):
    """``clock or perf_clock`` without treating a falsy callable as unset."""
    return perf_clock if clock is None else clock
