"""Unified observability layer: tracing, typed metrics, roofline utilization.

Zero-dependency (stdlib only) so every layer — kernels/tune, serve, cluster,
train, launch, benchmarks — can emit without import cycles or new wheels:

  * :mod:`repro.obs.clock` — the ONE wall-clock source for serve/train time
    reads; everything else takes an injectable ``clock`` (tick clocks in the
    chaos suites) and defaults to it.
  * :mod:`repro.obs.trace` — bounded ring-buffer :class:`TraceRecorder` with
    nested sync spans, async request-lifecycle spans, and instant events,
    exported as Chrome ``trace_event`` JSON (Perfetto-loadable).  A process-
    global recorder (default: no-op) lets deep layers (autotuner sweeps)
    emit without threading a parameter through every constructor.
  * :mod:`repro.obs.metrics` — typed registry (counters / gauges / fixed-
    bucket histograms) that *wraps* the frozen counter schemas
    (``lifecycle.COUNTER_KEYS``, ``cluster.ROUTER_COUNTER_KEYS``,
    ``train.elastic.COUNTER_KEYS``) behind pull-style bindings; Prometheus
    text exposition + JSON snapshot.
  * :mod:`repro.obs.utilization` — joins measured timings against the
    roofline cost models (roofline.analysis) into an achieved-fraction-of-
    roofline column, gated by benchmarks/regress.py.
  * :mod:`repro.obs.validate` — schema validators for the exported trace /
    metrics artifacts (CI runs them on the bench-smoke exports).
"""
from repro.obs.clock import perf_clock, resolve_clock
from repro.obs.trace import (
    NULL_RECORDER,
    NullRecorder,
    TraceRecorder,
    get_recorder,
    set_recorder,
    use_recorder,
)
from repro.obs.metrics import (
    MetricsRegistry,
    STEP_TIME_BUCKETS_S,
    TPOT_BUCKETS_S,
    TTFT_BUCKETS_S,
    router_registry,
    serving_registry,
    train_registry,
)
from repro.obs.utilization import (
    achieved_fraction,
    roofline_lower_bound_s,
    utilization_columns,
)

__all__ = [
    "perf_clock",
    "resolve_clock",
    "TraceRecorder",
    "NullRecorder",
    "NULL_RECORDER",
    "get_recorder",
    "set_recorder",
    "use_recorder",
    "MetricsRegistry",
    "TTFT_BUCKETS_S",
    "TPOT_BUCKETS_S",
    "STEP_TIME_BUCKETS_S",
    "serving_registry",
    "router_registry",
    "train_registry",
    "roofline_lower_bound_s",
    "achieved_fraction",
    "utilization_columns",
]
