"""Span-based structured tracing with Chrome ``trace_event`` JSON export.

One :class:`TraceRecorder` holds a *bounded* ring buffer of completed events
(oldest evicted first) plus a separate stack of currently-open sync spans —
eviction can therefore never corrupt a span that is still open, no matter
how many events flood in between its begin and its end.  Timestamps come
from an injectable clock (obs.clock), so a chaos test driving a tick clock
gets bit-deterministic traces.

Event taxonomy (DESIGN.md §Observability):

  * **sync spans** (``with rec.span("train/step", step=i): ...``) —
    Chrome phase ``"X"`` (complete: ts + dur), nested by the call stack;
    the trainer's per-step data/step/ckpt phases use these.
  * **async spans** (``rec.begin("request", uid)`` … ``rec.end("request",
    uid, **row)``) — Chrome phases ``"b"``/``"e"``, correlated by ``id``:
    a request's life crosses many scheduler ticks, so its span cannot nest
    on any one call stack.  Engine-local uid counters collide across
    replicas; :meth:`TraceRecorder.ns` hands each emitting component a
    namespace so ids stay globally unique (``id = "3:7"``).
  * **instants** (``rec.instant("preempt", uid=9)``) — Chrome phase
    ``"i"``: status transitions, preemption/restore, degradation level
    changes, mesh prefills, failover replays, checkpoint/rollback marks,
    autotuner picks.

Export is :meth:`to_chrome` — ``{"traceEvents": [...]}`` loadable directly
in Perfetto / ``chrome://tracing``; still-open spans export as ``"B"``
events so nothing in flight is hidden.

The process-global recorder (:func:`get_recorder` / :func:`set_recorder`,
default :data:`NULL_RECORDER`) is how layers without a constructor
parameter path (the autotuner's measurement sweeps) emit: ``--trace`` on
the launchers and benchmark driver installs a real recorder there.
:class:`NullRecorder` implements the same surface as no-ops so call sites
are unconditional — tests/test_obs.py benchmark-asserts the disabled path
costs nothing measurable per call.
"""
from __future__ import annotations

import itertools
import json
from collections import deque
from contextlib import contextmanager

from repro.obs.clock import resolve_clock

#: Default ring-buffer capacity (completed events).
DEFAULT_MAXLEN = 65536


class _Span:
    """Re-entrant handle for one open sync span (lives on the recorder's
    open stack, never in the ring buffer, until it closes)."""

    __slots__ = ("rec", "name", "args", "tid", "t0")

    def __init__(self, rec: "TraceRecorder", name: str, tid: int, args: dict):
        self.rec = rec
        self.name = name
        self.tid = tid
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = self.rec.clock()
        self.rec._open.append(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        self.rec._open.remove(self)
        self.rec._push({
            "name": self.name, "ph": "X", "t": self.t0,
            "dur": self.rec.clock() - self.t0,
            "tid": self.tid, "args": self.args,
        })
        return False


class TraceRecorder:
    """Bounded structured-trace recorder on an injectable clock.

    ``maxlen`` bounds the *completed*-event ring; open sync spans are
    tracked separately and immune to eviction.  ``enabled`` is a cheap
    instrumentation-site guard (always True here; the NullRecorder's is
    False) — call sites may branch on it before building expensive args.
    """

    enabled = True

    def __init__(self, *, clock=None, maxlen: int = DEFAULT_MAXLEN,
                 pid: int = 0):
        self.clock = resolve_clock(clock)
        self.pid = pid
        self.events: deque = deque(maxlen=maxlen)
        self._open: list[_Span] = []
        self._ns = itertools.count(1)
        self.dropped = 0  # completed events evicted by the ring bound

    # -- emission ---------------------------------------------------------

    def ns(self) -> int:
        """A fresh id namespace for one emitting component (engine,
        scheduler, router): async-span ids are ``"<ns>:<local id>"`` so
        engine-local uid counters never collide across replicas."""
        return next(self._ns)

    def _push(self, ev: dict) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def span(self, name: str, *, tid: int = 0, **args) -> _Span:
        """Sync nested span context manager (Chrome ``"X"``)."""
        return _Span(self, name, tid, args)

    def begin(self, name: str, span_id, *, tid: int = 0, **args) -> None:
        """Open an async span correlated by ``span_id`` (Chrome ``"b"``)."""
        self._push({"name": name, "ph": "b", "t": self.clock(),
                    "id": str(span_id), "tid": tid, "args": args})

    def end(self, name: str, span_id, *, tid: int = 0, **args) -> None:
        """Close the async span ``span_id`` (Chrome ``"e"``).  ``args`` on
        the end event carry the request's terminal metrics row — the
        bit-consistency anchor tests compare against ``metrics()``."""
        self._push({"name": name, "ph": "e", "t": self.clock(),
                    "id": str(span_id), "tid": tid, "args": args})

    def instant(self, name: str, *, tid: int = 0, **args) -> None:
        """Point event (Chrome ``"i"``, thread scope)."""
        self._push({"name": name, "ph": "i", "t": self.clock(),
                    "tid": tid, "args": args})

    # -- export -----------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON object format (Perfetto-loadable).

        Clock units export as microseconds: a tick clock's tick becomes
        1 µs — proportions survive, and the format stays uniform."""
        out = []
        for ev in self.events:
            rec = {
                "name": ev["name"], "ph": ev["ph"],
                "ts": ev["t"] * 1e6, "pid": self.pid, "tid": ev["tid"],
                "args": ev["args"],
            }
            if ev["ph"] == "X":
                rec["dur"] = ev["dur"] * 1e6
            if "id" in ev:
                rec["id"] = ev["id"]
                rec["cat"] = "async"  # b/e events require a category
            if ev["ph"] == "i":
                rec["s"] = "t"
            out.append(rec)
        for sp in self._open:  # still-open sync spans: visible, unclosed
            out.append({
                "name": sp.name, "ph": "B", "ts": sp.t0 * 1e6,
                "pid": self.pid, "tid": sp.tid, "args": sp.args,
            })
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)


class _NullSpan:
    """Shared, allocation-free context manager for disabled tracing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """No-op recorder with the full TraceRecorder surface.  Installed by
    default, so instrumentation sites are unconditional and cost one
    attribute lookup + one empty call when tracing is off."""

    enabled = False
    events = ()
    dropped = 0

    def ns(self) -> int:
        return 0

    def span(self, name, *, tid=0, **args):
        return _NULL_SPAN

    def begin(self, name, span_id, *, tid=0, **args) -> None:
        pass

    def end(self, name, span_id, *, tid=0, **args) -> None:
        pass

    def instant(self, name, *, tid=0, **args) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"dropped_events": 0}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


NULL_RECORDER = NullRecorder()

_current = NULL_RECORDER


def get_recorder():
    """The process-global recorder (NULL_RECORDER unless --trace installed
    one).  Constructors resolve ``trace or get_recorder()`` so explicitly
    injected recorders always win."""
    return _current


def set_recorder(rec) -> None:
    global _current
    _current = rec if rec is not None else NULL_RECORDER


@contextmanager
def use_recorder(rec):
    """Scoped global-recorder install (tests; benchmark runs)."""
    global _current
    prev = _current
    _current = rec if rec is not None else NULL_RECORDER
    try:
        yield rec
    finally:
        _current = prev
