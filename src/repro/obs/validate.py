"""Schema validators for exported observability artifacts.

CI's bench-smoke job exports a Chrome trace + metrics snapshot from
``benchmarks/run.py --smoke --trace ... --metrics-out ...`` and runs

    python -m repro.obs.validate --trace t.json --metrics m.json

which exits non-zero with a readable problem list if either artifact
violates its schema.  The checks are intentionally structural (stdlib only,
no jsonschema): every field Perfetto / the regress gate actually relies on.
"""
from __future__ import annotations

import argparse
import json
import sys

_PHASES = {"X", "B", "E", "b", "e", "i"}


def validate_chrome_trace(doc) -> list[str]:
    """Problems (empty = valid) with a Chrome ``trace_event`` object doc."""
    probs: list[str] = []
    if not isinstance(doc, dict):
        return [f"trace doc must be an object, got {type(doc).__name__}"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["trace doc lacks a traceEvents list"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            probs.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                probs.append(f"{where}: missing {field!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            probs.append(f"{where}: unknown phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            probs.append(f"{where}: ts must be a non-negative number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                probs.append(f"{where}: complete event needs dur >= 0")
        if ph in ("b", "e"):
            if "id" not in ev:
                probs.append(f"{where}: async event needs an id")
            if "cat" not in ev:
                probs.append(f"{where}: async event needs a cat")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            probs.append(f"{where}: instant needs scope s in t/p/g")
    # Every async end must match an open begin with the same (name, id).
    open_async: set = set()
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            continue
        key = (ev.get("name"), ev.get("id"))
        if ev.get("ph") == "b":
            open_async.add(key)
        elif ev.get("ph") == "e" and key not in open_async:
            probs.append(f"traceEvents[{i}]: end without begin for {key}")
    return probs


def validate_metrics_snapshot(doc) -> list[str]:
    """Problems (empty = valid) with a MetricsRegistry.snapshot() doc."""
    probs: list[str] = []
    if not isinstance(doc, dict):
        return [f"metrics doc must be an object, got {type(doc).__name__}"]
    if doc.get("schema") != 1:
        probs.append(f"unknown metrics schema {doc.get('schema')!r}")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(section), dict):
            probs.append(f"missing {section!r} object")
    for name, v in (doc.get("counters") or {}).items():
        if not isinstance(v, (int, float)) or v < 0:
            probs.append(f"counter {name}: must be a non-negative number")
    for name, v in (doc.get("gauges") or {}).items():
        if not isinstance(v, (int, float)):
            probs.append(f"gauge {name}: must be a number")
    for name, h in (doc.get("histograms") or {}).items():
        if not isinstance(h, dict):
            probs.append(f"histogram {name}: not an object")
            continue
        buckets, counts = h.get("buckets"), h.get("counts")
        if not isinstance(buckets, list) or sorted(buckets) != buckets:
            probs.append(f"histogram {name}: buckets must ascend")
            continue
        if not isinstance(counts, list) or len(counts) != len(buckets) + 1:
            probs.append(f"histogram {name}: need len(buckets)+1 counts")
            continue
        if any((not isinstance(c, int)) or c < 0 for c in counts):
            probs.append(f"histogram {name}: counts must be ints >= 0")
        elif h.get("count") != sum(counts):
            probs.append(f"histogram {name}: count != sum(counts)")
    return probs


def _check(path: str, validator) -> list[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {p}" for p in validator(doc)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate exported trace/metrics artifacts")
    ap.add_argument("--trace", help="Chrome trace JSON to validate")
    ap.add_argument("--metrics", help="metrics snapshot JSON to validate")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to validate: pass --trace and/or --metrics")
    probs: list[str] = []
    if args.trace:
        probs += _check(args.trace, validate_chrome_trace)
    if args.metrics:
        probs += _check(args.metrics, validate_metrics_snapshot)
    for p in probs:
        print(f"VALIDATE FAIL {p}")
    if not probs:
        print("validate: artifacts conform")
    return 1 if probs else 0


if __name__ == "__main__":
    sys.exit(main())
