"""Measured-vs-roofline utilization: the column that makes "fast as the
hardware allows" a tracked number instead of an assertion.

``roofline/analysis.py`` carries the model side — per-call FLOP and HBM-byte
costs plus the TPU v5e hardware constants.  This module joins a *measured*
time against that model:

  lower bound  t_roof = max(flops / PEAK_FLOPS, bytes / HBM_BW)
  utilization  u      = t_roof / t_measured          (achieved fraction)

``u`` close to 1.0 means the kernel runs at the binding roofline term;
``u`` > 1.0 means the cost model under-counts (a model bug worth failing
on).  On the CPU interpreter the fractions are tiny but still meaningful as
a *band*: regress.py keys its utilization bounds per-backend, so the
interpreter rows get (floor > 0, ceiling ≤ 1) while real-TPU rows can carry
tight floors (ROADMAP: the real-TPU validation sweep re-anchors here).

``utilization_columns`` is the benchmark-writer helper: it turns one
roofline cost dict (e.g. ``decode_attention_cost(...)``) plus a measured
microsecond timing into the stamped record columns.
"""
from __future__ import annotations

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def roofline_lower_bound_s(flops: float, hbm_bytes: float, *,
                           peak_flops: float = PEAK_FLOPS,
                           hbm_bw: float = HBM_BW) -> float:
    """Minimum achievable seconds: the slower of the compute and memory
    terms (the classic roofline ridge)."""
    if flops < 0 or hbm_bytes < 0:
        raise ValueError("flops/bytes must be non-negative")
    return max(flops / peak_flops, hbm_bytes / hbm_bw)


def achieved_fraction(measured_s: float, flops: float, hbm_bytes: float, *,
                      peak_flops: float = PEAK_FLOPS,
                      hbm_bw: float = HBM_BW) -> float:
    """Fraction of the roofline lower bound actually achieved (0..1 on a
    correct cost model; >1 flags the model, not the kernel)."""
    if measured_s <= 0:
        raise ValueError(f"measured_s must be positive, got {measured_s}")
    bound = roofline_lower_bound_s(flops, hbm_bytes,
                                   peak_flops=peak_flops, hbm_bw=hbm_bw)
    return bound / measured_s


def utilization_columns(cost: dict, measured_us: float) -> dict:
    """Benchmark-record columns from a roofline cost dict + measured µs.

    ``cost`` is any analysis.py cost dict carrying ``total_flops`` and
    ``hbm_bytes`` (decode_attention_cost, paged_decode_attention_cost).
    """
    flops = float(cost["total_flops"])
    hbm_bytes = float(cost["hbm_bytes"])
    bound_s = roofline_lower_bound_s(flops, hbm_bytes)
    return {
        "roofline_flops": flops,
        "roofline_hbm_bytes": hbm_bytes,
        "roofline_lower_bound_us": bound_s * 1e6,
        "roofline_util": achieved_fraction(measured_us * 1e-6, flops,
                                           hbm_bytes),
    }
