"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

The registry *wraps* — never replaces — the frozen counter schemas the
engines, router, and trainer already expose (``lifecycle.COUNTER_KEYS``,
``cluster.ROUTER_COUNTER_KEYS``, ``train.elastic.COUNTER_KEYS``):
:meth:`MetricsRegistry.bind_counters` registers one pull-style source whose
keys ARE the frozen schema (``counters_snapshot()`` zero-fills against it),
and every bound name is claimed exactly once — binding the same schema
twice, or colliding with a typed metric, raises.  tests/test_obs.py asserts
each frozen key appears exactly once per component and that the exported
values equal ``counters_snapshot()`` verbatim.

Two exports:

  * :meth:`to_prometheus` — Prometheus text exposition (``# HELP`` /
    ``# TYPE``, cumulative ``_bucket{le=...}`` histograms);
  * :meth:`snapshot` — a JSON-ready dict the benchmarks and ``--metrics-out``
    persist (obs.validate checks its schema in CI).

Histogram buckets are fixed at registration (Prometheus semantics: merging
across processes only works when buckets agree).  The provided defaults
cover the quantities the stack actually tracks: TTFT and TPOT in clock
units (seconds on the wall clock, ticks under an injected tick clock — the
decade grid covers both) and train step time in seconds.
"""
from __future__ import annotations

import math

#: Decade-ish grids: meaningful for wall-clock seconds AND tick clocks.
TTFT_BUCKETS_S = (0.001, 0.005, 0.02, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0)
TPOT_BUCKETS_S = (0.0005, 0.002, 0.01, 0.05, 0.2, 1.0, 2.0, 5.0)
STEP_TIME_BUCKETS_S = (0.01, 0.05, 0.2, 0.5, 1.0, 2.0, 5.0, 15.0, 60.0)


def _fmt(v) -> str:
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Counter:
    """Monotonic counter (push-style)."""

    mtype = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v


class Gauge:
    """Point-in-time value; ``fn`` makes it pull-style (read at export)."""

    mtype = "gauge"

    def __init__(self, name: str, help: str = "", fn=None):
        self.name, self.help, self.fn = name, help, fn
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class Histogram:
    """Fixed-bucket histogram (upper bounds ascending; +Inf implicit)."""

    mtype = "histogram"

    def __init__(self, name: str, help: str = "", buckets=TTFT_BUCKETS_S):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram {name}: buckets must ascend")
        self.name, self.help = name, help
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


class MetricsRegistry:
    """Name-unique registry of typed metrics + bound counter schemas."""

    def __init__(self):
        self._metrics: dict[str, object] = {}  # insertion-ordered
        self._bound: list[tuple[str, tuple, object, str]] = []
        self._names: set[str] = set()

    # -- registration -----------------------------------------------------

    def _claim(self, name: str) -> None:
        if name in self._names:
            raise ValueError(f"metric {name!r} already registered")
        self._names.add(name)

    def counter(self, name: str, help: str = "") -> Counter:
        self._claim(name)
        m = Counter(name, help)
        self._metrics[name] = m
        return m

    def gauge(self, name: str, help: str = "", fn=None) -> Gauge:
        self._claim(name)
        m = Gauge(name, help, fn)
        self._metrics[name] = m
        return m

    def histogram(self, name: str, help: str = "",
                  buckets=TTFT_BUCKETS_S) -> Histogram:
        self._claim(name)
        m = Histogram(name, help, buckets)
        self._metrics[name] = m
        return m

    def bind_counters(self, prefix: str, snapshot_fn, keys=None,
                      help: str = "") -> tuple:
        """Bind a frozen counter schema as pull-style counters named
        ``<prefix>_<key>``.  ``keys=None`` reads them from one snapshot —
        the zero-filled frozen schema itself.  Every name is claimed now,
        so a double bind (or a typed-metric collision) raises immediately:
        the 'every frozen key appears exactly once' guarantee."""
        if keys is None:
            keys = tuple(snapshot_fn().keys())
        for k in keys:
            self._claim(f"{prefix}_{k}")
        self._bound.append((prefix, tuple(keys), snapshot_fn, help))
        return tuple(keys)

    # -- export -----------------------------------------------------------

    def _bound_samples(self):
        for prefix, keys, fn, help in self._bound:
            snap = fn()
            for k in keys:
                yield f"{prefix}_{k}", float(snap.get(k, 0)), help

    def to_prometheus(self) -> str:
        lines: list[str] = []
        for name, value, help in self._bound_samples():
            lines.append(f"# HELP {name} {help}".rstrip())
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name} {_fmt(value)}")
        for m in self._metrics.values():
            lines.append(f"# HELP {m.name} {m.help}".rstrip())
            lines.append(f"# TYPE {m.name} {m.mtype}")
            if m.mtype == "histogram":
                cum = m.cumulative()
                for ub, c in zip(m.buckets, cum):
                    lines.append(
                        f'{m.name}_bucket{{le="{_fmt(ub)}"}} {c}')
                lines.append(f'{m.name}_bucket{{le="+Inf"}} {cum[-1]}')
                lines.append(f"{m.name}_sum {_fmt(m.sum)}")
                lines.append(f"{m.name}_count {m.count}")
            else:
                lines.append(f"{m.name} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-ready snapshot (schema checked by obs.validate)."""
        out = {"schema": 1, "counters": {}, "gauges": {}, "histograms": {}}
        for name, value, _ in self._bound_samples():
            out["counters"][name] = value
        for m in self._metrics.values():
            if m.mtype == "counter":
                out["counters"][m.name] = m.value
            elif m.mtype == "gauge":
                out["gauges"][m.name] = m.value
            else:
                out["histograms"][m.name] = {
                    "buckets": list(m.buckets),
                    "counts": list(m.counts),
                    "sum": m.sum,
                    "count": m.count,
                }
        return out


# -- component bindings ------------------------------------------------------
# Duck-typed on the public surfaces (counters_snapshot / metrics / history)
# so obs never imports serve/train — no cycles, and fakes bind identically.


def _observe_rows(ttft: Histogram, tpot: Histogram, rows) -> None:
    for row in rows:
        if row.get("ttft_s") is not None:
            ttft.observe(row["ttft_s"])
        if row.get("tpot_s") is not None:
            tpot.observe(row["tpot_s"])


def serving_registry(engine, prefix: str = "serve") -> MetricsRegistry:
    """One-shot registry over an engine (slot or paged): the frozen
    ``lifecycle.COUNTER_KEYS`` bound pull-style, queue/degrade gauges, and
    TTFT/TPOT histograms filled from the ``metrics()`` rows at call time."""
    reg = MetricsRegistry()
    reg.bind_counters(prefix, engine.counters_snapshot,
                      help="engine robustness counter (frozen schema)")
    reg.gauge(f"{prefix}_queue_depth", "requests waiting for admission",
              fn=engine.queue_depth)
    reg.gauge(f"{prefix}_degrade_level", "degradation controller level",
              fn=engine.degrade_level)
    ttft = reg.histogram(f"{prefix}_ttft_s", "time to first token",
                         buckets=TTFT_BUCKETS_S)
    tpot = reg.histogram(f"{prefix}_tpot_s", "mean inter-token time",
                         buckets=TPOT_BUCKETS_S)
    _observe_rows(ttft, tpot, engine.metrics())
    return reg


def router_registry(router) -> MetricsRegistry:
    """Registry over a ClusterRouter: its own frozen ROUTER_COUNTER_KEYS
    plus the live replicas' aggregated engine counters, and cluster-level
    TTFT/TPOT histograms from the router's ledger metrics."""
    reg = MetricsRegistry()
    reg.bind_counters("router", router.counters_snapshot,
                      help="router counter (frozen schema)")
    reg.bind_counters("cluster", router.cluster_counters,
                      help="engine counters summed over live replicas")
    ttft = reg.histogram("cluster_ttft_s", "time to first emitted token",
                         buckets=TTFT_BUCKETS_S)
    tpot = reg.histogram("cluster_tpot_s", "mean inter-token time",
                         buckets=TPOT_BUCKETS_S)
    _observe_rows(ttft, tpot, router.metrics())
    return reg


def train_registry(trainer, prefix: str = "train") -> MetricsRegistry:
    """Registry over a Trainer (or TrainSupervisor): the frozen
    ``train.elastic.COUNTER_KEYS`` bound pull-style, the current step as a
    gauge, and a step-time histogram from the history records."""
    reg = MetricsRegistry()
    reg.bind_counters(prefix, trainer.counters_snapshot,
                      help="train robustness counter (frozen schema)")
    target = getattr(trainer, "trainer", trainer)  # supervisor wraps one
    reg.gauge(f"{prefix}_step", "current optimizer step",
              fn=lambda: target.step)
    hist = reg.histogram(f"{prefix}_step_time_s", "wall time per step",
                         buckets=STEP_TIME_BUCKETS_S)
    for rec in getattr(target, "history", []):
        if "sec" in rec:
            hist.observe(rec["sec"])
    return reg
