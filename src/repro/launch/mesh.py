"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer jax; all meshes here are
    Auto-typed, which is also the old default — pass it only when it exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:   (pod=2, data=16, model=16) = 512 chips; "pod" is pure DP
    (gradient all-reduce crosses the inter-pod links only once per step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, context_parallel: int = 1):
    """Mesh over whatever devices exist (tests / examples / CPU).

    ``context_parallel > 1`` adds a "context" axis for ring
    sequence-parallel attention (distributed.ring_attention): the sequence
    dimension shards over it, so it is *not* a data-parallel axis —
    sharding rules (distributed.sharding, models.layers.constrain) exclude
    it from batch-dim expansion."""
    n = len(jax.devices())
    if n % (model_parallel * context_parallel):
        raise ValueError(
            f"{n} device(s) cannot host model_parallel={model_parallel} × "
            f"context_parallel={context_parallel} (need a divisor of the "
            f"device count)"
        )
    if context_parallel > 1:
        return compat_make_mesh(
            (n // (model_parallel * context_parallel), context_parallel,
             model_parallel),
            ("data", "context", "model"),
        )
    return compat_make_mesh((n // model_parallel, model_parallel), ("data", "model"))
