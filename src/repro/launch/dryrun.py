import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) needs 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh, attaches NamedShardings to
every input's ShapeDtypeStruct (params, optimizer state, batch / KV cache),
lowers the real train_step / prefill / decode_step, compiles it, and records
``memory_analysis()`` + ``cost_analysis()`` + the collective schedule for
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--impl distr|xla_flash]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.utils.jax_compat import set_mesh
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config, input_specs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.roofline import analysis as roof
from repro.serve import kv_cache
from repro.serve.serve_step import make_decode_step, make_prefill
from repro.train.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.utils import tree_bytes

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def tpu_memory_estimate(cfg, shape, mesh, p_shapes) -> dict:
    """Analytic per-chip HBM estimate for the real TPU target.

    The CPU dry-run backend float-normalises bf16 → f32 (its 'wide.*'
    computations), inflating ``memory_analysis`` temps by up to 2× on bf16
    models; this estimate is the TPU-side budget check reported next to it.
    """
    devs = int(mesh.size)
    model_par = int(mesh.shape.get("model", 1))
    dp = devs // model_par
    param_b = tree_bytes(p_shapes)  # fp32 master params
    out = {"params": param_b / devs}
    if shape.kind == "train":
        out["opt_state"] = 2 * param_b / devs  # adam m+v fp32
        tokens = shape.global_batch * shape.seq_len
        # per-layer bf16 residual carry, sequence-sharded over model
        out["saved_carries"] = cfg.n_layers * tokens * cfg.d_model * 2 / devs
        # logits + softmax grads (bf16 fwd + f32 bwd ≈ 6 B/elem)
        out["logits"] = tokens / dp * cfg.padded_vocab / model_par * 6
        out["transient"] = 2 * 2**30  # block-level working set, bounded
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        out["activations"] = 4 * tokens * cfg.d_model * 2 / devs
        out["kv_cache"] = (
            2 * cfg.n_layers * tokens * cfg.n_kv_heads * cfg.head_dim_ * 2 / devs
            if not cfg.is_attention_free
            else 0
        )
        out["transient"] = 2 * 2**30
    else:  # decode
        from repro.serve import kv_cache as kvc

        cache_b = tree_bytes(kvc.cache_struct(cfg, shape.global_batch, shape.seq_len))
        out["kv_cache"] = cache_b / devs
        out["transient"] = 1 * 2**30
    out["total"] = sum(out.values())
    return {k: int(v) for k, v in out.items()}


def _struct_with(shapes_tree, shardings_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree,
        shardings_tree,
    )


def _batch_struct(specs: dict, mesh):
    shard = shd.batch_shardings(specs, mesh)
    return {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shard[k])
        for k, v in specs.items()
    }


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  impl: str | None = None, overrides: dict | None = None):
    """→ (lowered, meta) for one cell; raises on skip."""
    cfg = get_config(arch)
    if impl:
        cfg = cfg.replace(attention=cfg.attention.with_impl(impl))
    if overrides:
        overrides = dict(overrides)
        if overrides.pop("distr_decode", False):
            cfg = cfg.replace(
                attention=dataclasses.replace(cfg.attention, distr_decode=True)
            )
        if overrides:
            cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    reason = cfg.skip_reason(shape)
    if reason:
        raise SkipCell(reason)

    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
    axes = lm.param_axes(cfg)
    p_shard = shd.param_shardings(axes, p_shapes, mesh, fsdp=cfg.fsdp)
    p_struct = _struct_with(p_shapes, p_shard)
    total, active = roof.active_params(cfg, p_shapes)

    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "devices": int(mesh.size),
        "impl": cfg.attention.impl,
        "total_params": total,
        "active_params": active,
        "model_flops": roof.model_flops(cfg, shape, active),
        "tpu_memory_estimate": tpu_memory_estimate(cfg, shape, mesh, p_shapes),
    }

    with set_mesh(mesh):
        if shape.kind == "train":
            o_shapes = jax.eval_shape(adamw_init, p_shapes)
            o_shard = {
                "m": p_shard,
                "v": p_shard,
                "count": shd.replicated(mesh),
            }
            o_struct = _struct_with(o_shapes, o_shard)
            batch = _batch_struct(input_specs(cfg, shape), mesh)
            step_struct = jax.ShapeDtypeStruct((), jnp.int32,
                                               sharding=shd.replicated(mesh))
            opt_cfg = OptimizerConfig(total_steps=10_000)
            fn = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(
                fn, donate_argnums=(0, 1),
                out_shardings=(p_shard, o_shard, None),
            ).lower(p_struct, o_struct, batch, step_struct)
        elif shape.kind == "prefill":
            batch = _batch_struct(input_specs(cfg, shape), mesh)
            fn = make_prefill(cfg, shape.seq_len)
            kwargs = {k: v for k, v in batch.items() if k != "tokens"}
            lowered = jax.jit(fn).lower(p_struct, batch["tokens"], **kwargs)
        else:  # decode
            b = shape.global_batch
            cache_shapes = kv_cache.cache_struct(cfg, b, shape.seq_len)
            cache_pspec = kv_cache.cache_pspecs(
                cfg, mesh, batch=b, max_len=shape.seq_len
            )
            cache_shard = {
                k: NamedSharding(mesh, cache_pspec[k]) for k in cache_shapes
            }
            cache_struct_in = _struct_with(cache_shapes, cache_shard)
            dp = shd.dp_axes_for(mesh, b)
            tok = jax.ShapeDtypeStruct(
                (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(dp, None))
            )
            pos = jax.ShapeDtypeStruct(
                (b,), jnp.int32, sharding=NamedSharding(mesh, P(dp))
            )
            fn = make_decode_step(cfg)
            lowered = jax.jit(
                fn, donate_argnums=(2,), out_shardings=(None, cache_shard)
            ).lower(p_struct, tok, cache_struct_in, pos)
    return lowered, meta


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             impl: str | None = None, save: bool = True,
             tag: str = "", overrides: dict | None = None) -> dict:
    t0 = time.time()
    try:
        lowered, meta = build_lowered(
            arch, shape_name, multi_pod=multi_pod, impl=impl,
            overrides=overrides,
        )
    except SkipCell as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "pod2x16x16" if multi_pod else "16x16",
               "status": "skipped", "reason": str(e)}
        print(f"[dryrun] SKIP {arch} × {shape_name}: {e}")
        if save:
            _save(rec, tag)
        return rec

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    terms = roof.roofline(compiled)
    rec = {
        **meta,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            # peak live ≈ args + temps − donated aliases (per device)
            "per_device_total": int(
                mem.argument_size_in_bytes
                + mem.temp_size_in_bytes
                + mem.output_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "roofline": terms.as_dict(),
        "useful_flops_ratio": (
            meta["model_flops"] / meta["devices"] / terms.flops_per_dev
            if terms.flops_per_dev
            else None
        ),
    }
    print(
        f"[dryrun] OK {arch} × {shape_name} × {rec['mesh']} "
        f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)\n"
        f"  mem/device: {rec['memory']['per_device_total']/2**30:.2f} GiB "
        f"(args {mem.argument_size_in_bytes/2**30:.2f} + temp "
        f"{mem.temp_size_in_bytes/2**30:.2f} GiB; TPU est "
        f"{meta['tpu_memory_estimate']['total']/2**30:.2f} GiB)\n"
        f"  roofline: compute {terms.compute_s*1e3:.2f} ms | memory "
        f"{terms.memory_s*1e3:.2f} ms | collective {terms.collective_s*1e3:.2f} ms "
        f"→ {terms.dominant}-bound; useful-FLOPs ratio "
        f"{rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)}"
    )
    if save:
        _save(rec, tag)
    return rec


def _save(rec: dict, tag: str = "") -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
    if rec.get("impl") and rec["impl"] != "distr":
        name += f"_{rec['impl']}"
    if tag:
        name += f"_{tag}"
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--impl", default=None,
                    help="attention impl override (e.g. xla_flash baseline)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. attn_shard=heads)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        overrides[k] = v

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, impl=args.impl,
                         tag=args.tag, overrides=overrides or None)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} × {shape} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")


if __name__ == "__main__":
    main()
