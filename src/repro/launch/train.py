"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 200 --batch 8 --seq 128 --workdir /tmp/run1

On a real cluster this process is started once per host (jax.distributed
initialises from the TPU/GKE environment); on this container it drives the
same Trainer on CPU with the reduced configs.  Elastic restart: rerunning
with the same --workdir resumes from the latest checkpoint on whatever
device count is available (mesh-agnostic checkpoints).
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_host_mesh
from repro.train.anomaly import AnomalyConfig
from repro.train.data import BinaryShardData, SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--impl", default=None, help="attention impl override")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--data", default=None,
                    help="glob of .bin token shards (default: synthetic)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--context-parallel", type=int, default=1,
                    help="ring sequence-parallel attention degree: shards "
                         "the sequence axis over a 'context' mesh axis "
                         "(distributed.ring_attention), so max trainable "
                         "sequence length scales with this instead of HBM "
                         "per chip")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--anomaly-z", type=float, default=8.0,
                    help="z-score threshold of the loss/grad-norm spike "
                         "detector (rolls back to the last verified "
                         "checkpoint; 0 disables the guard)")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="consecutive no-progress anomaly rollbacks before "
                         "the run halts (AnomalyHalt)")
    ap.add_argument("--supervise", type=int, default=0, metavar="N",
                    help="run under TrainSupervisor with N simulated "
                         "workers: heartbeat failure detection, straggler "
                         "exclusion, remesh + verified-checkpoint restore "
                         "on worker loss (0 = plain Trainer)")
    ap.add_argument("--tune", choices=["off", "analytic", "measure"],
                    default=None,
                    help="block-size autotuning mode (sets REPRO_TUNE; "
                         "default: inherit the environment)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run "
                         "(per-step data/fwd_bwd spans, checkpoint and "
                         "rollback instants; Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a typed metrics snapshot of the run")
    args = ap.parse_args()

    if args.tune:
        os.environ["REPRO_TUNE"] = args.tune

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.impl:
        cfg = cfg.replace(attention=cfg.attention.with_impl(args.impl))
    if args.context_parallel > 1:
        from dataclasses import replace as dc_replace

        cfg = cfg.replace(
            attention=dc_replace(cfg.attention, context_axis="context")
        )

    mesh = None
    if len(jax.devices()) > 1 or args.context_parallel > 1:
        mesh = make_host_mesh(args.model_parallel, args.context_parallel)
        print(f"[train] mesh: {dict(mesh.shape)}")

    # Resolve (and under measure mode, sweep + persist) the training-shape
    # attention blocks up front, so the first jitted step never hides a
    # timing run.  Explicit config ints pass through untouched.  Under the
    # mesh context the tuner keys go per-shard when context parallelism is
    # on (the ring streams one shard per device, not the global sequence).
    from repro.utils.jax_compat import maybe_set_mesh

    acfg = cfg.attention
    if acfg.impl != "reference" and (acfg.block_q is None or acfg.block_k is None):
        from repro.core.api import resolve_attention_blocks

        with maybe_set_mesh(mesh):
            blocks = resolve_attention_blocks(
                acfg, d=cfg.head_dim_, n_q=args.seq,
                dtype="bfloat16" if cfg.compute_dtype == "bfloat16" else "float32",
                causal=True, bwd=True,  # training traces the backward kernels
            )
        print(f"[train] attention blocks ({os.environ.get('REPRO_TUNE', 'off')}): "
              f"{blocks}")

    opt_cfg = OptimizerConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        schedule=cfg.schedule,
        grad_accum=args.grad_accum,
    )
    if args.data:
        import glob

        data = BinaryShardData(sorted(glob.glob(args.data)), args.batch, args.seq)
    else:
        data = SyntheticLMData(cfg.vocab, args.batch, args.seq, seed=args.seed)

    os.makedirs(args.workdir, exist_ok=True)
    anomaly = AnomalyConfig(
        enabled=args.anomaly_z > 0,
        z_threshold=args.anomaly_z or 8.0,
        max_rollbacks=args.max_rollbacks,
    )
    rec = None
    if args.trace:
        from repro.obs import TraceRecorder, set_recorder

        rec = TraceRecorder()
        set_recorder(rec)  # autotune measurement spans ride the global

    trainer = Trainer(cfg, opt_cfg, data, workdir=args.workdir, mesh=mesh,
                      seed=args.seed, ckpt_every=args.ckpt_every,
                      anomaly=anomaly, trace=rec)
    source = trainer
    if args.supervise > 0:
        from repro.train.supervisor import TrainSupervisor

        sup = TrainSupervisor(trainer, num_workers=args.supervise,
                              model_parallel=args.model_parallel, trace=rec)
        hist = sup.run(args.steps)
        print(f"[train] supervisor counters: {sup.counters_snapshot()}")
        source = sup
    else:
        hist = trainer.run(args.steps)
    if hist:
        print(f"[train] done: loss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f} "
              f"over {len(hist)} steps")
    if rec is not None:
        rec.save(args.trace)
        print(f"[train] trace: {args.trace} ({len(rec.events)} events)")
    if args.metrics_out:
        import json

        from repro.obs import train_registry

        with open(args.metrics_out, "w") as f:
            json.dump(train_registry(source).snapshot(), f, indent=1)
        print(f"[train] metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
