"""Serving launcher: continuous-batching engine over a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
      --requests 8 --max-new 16

``--trace PATH`` records per-request lifecycle spans (admit → prefill →
decode → terminal) as a Chrome trace_event JSON loadable in Perfetto;
``--metrics-out PATH`` writes the typed metrics snapshot
(``repro.obs.metrics.serving_registry``) over the engine's frozen
counter schema plus TTFT/TPOT histograms.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm
from repro.obs import TraceRecorder, perf_clock, serving_registry
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to serve")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a typed metrics snapshot of the run")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(0)
    if args.ckpt:
        template = jax.eval_shape(lambda k: lm.init_params(k, cfg), key)
        _, params, _, _ = ckpt.load_checkpoint(args.ckpt, template)
    else:
        params = lm.init_params(key, cfg)

    rec = TraceRecorder() if args.trace else None
    eng = ServeEngine(cfg, params, max_slots=args.max_slots,
                      max_len=args.max_len, temperature=args.temperature,
                      trace=rec)
    rng = np.random.default_rng(0)
    t0 = perf_clock()
    for _ in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 17)).tolist()
        eng.add_request(prompt, max_new_tokens=args.max_new)
    done = eng.run_to_completion()
    dt = perf_clock() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.generated[:12]}")
    if rec is not None:
        rec.save(args.trace)
        print(f"[serve] trace: {args.trace} ({len(rec.events)} events)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(serving_registry(eng).snapshot(), f, indent=1)
        print(f"[serve] metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
