"""Locality-sensitive hashing over embedding-dimension columns (paper §3.2).

A column ``q ∈ R^l`` (``l`` = Q-block row count) is projected to ``N' = 16``
dimensions, sign-binarised, and the 16-bit word is decoded with the *inverse*
Gray code so that codewords differing in one low-order bit map to adjacent
integers.  Sorting the resulting hashes yields the grouping permutation.

The paper uses a 2^N' Gray-code lookup table sized for GPU tensor-core
fragments; on TPU we use the closed-form prefix-XOR decode instead (no VMEM
table) — see DESIGN.md §7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Width of the LSH projection (paper's N'). 16 bits is plenty to order d<=256
# columns and the closed-form Gray decode keeps everything in int32.
N_PRIME = 16


def make_projection(key: jax.Array, block_len: int, n_prime: int = N_PRIME) -> jax.Array:
    """Random signed projection ``R ∈ {±1}^{n_prime × block_len}``.

    Generated once ahead of time (paper: "the projection matrix is randomly
    generated in prior") and shared across layers/heads; regenerating it per
    step would only add noise.
    """
    bits = jax.random.bernoulli(key, 0.5, (n_prime, block_len))
    return jnp.where(bits, 1.0, -1.0).astype(jnp.float32)


def inverse_gray(codes: jax.Array) -> jax.Array:
    """Decode a Gray codeword to its rank (prefix XOR).

    Consecutive ranks differ by a single bit, so interpreting the sign
    pattern as a Gray codeword and sorting by rank clusters near-identical
    sign patterns — the TPU-friendly replacement for the paper's 2^N' lookup
    table.
    """
    codes = codes.astype(jnp.uint32)
    for shift in (1, 2, 4, 8, 16):
        codes = codes ^ (codes >> shift)
    return codes.astype(jnp.int32)


def _morton16(a: jax.Array, b: jax.Array) -> jax.Array:
    """Interleave two 8-bit integers into a 16-bit Z-order code."""

    def spread(x):
        x = x.astype(jnp.uint32)
        x = (x | (x << 4)) & 0x0F0F
        x = (x | (x << 2)) & 0x3333
        x = (x | (x << 1)) & 0x5555
        return x

    return ((spread(a) << 1) | spread(b)).astype(jnp.int32)


def hash_columns(
    block: jax.Array, proj: jax.Array, method: str = "sign_gray"
) -> jax.Array:
    """Hash each embedding-dim column of ``block``.

    Args:
      block: ``(..., l, d)`` — one Q block (leading dims are batch/head/etc).
      proj:  ``(n_prime, l)`` projection from :func:`make_projection`.
      method:
        ``"sign_gray"`` — the paper's literal scheme: sign-binarise the N'
          projections, decode as Gray rank.  Direction-only: for data in the
          positive orthant (and scalar columns at l=1) it degenerates — see
          DESIGN.md §7 and benchmarks/errors.py.
        ``"proj_morton"`` — beyond-paper, same cost: quantise the first two
          projections to 8 bits each (per-block min/max) and Z-order
          interleave.  Magnitude-aware; reproduces the paper's reported error
          magnitudes on its uniform(0,1) study.

    Returns:
      ``(..., d)`` int32 hash per column.
    """
    # (..., n_prime, d): project every column q ∈ R^l to R^{n_prime}.
    projected = jnp.einsum("pl,...ld->...pd", proj, block.astype(jnp.float32))
    if method == "sign_gray":
        bits = (projected > 0).astype(jnp.uint32)
        n_prime = proj.shape[0]
        weights = (2 ** jnp.arange(n_prime - 1, -1, -1, dtype=jnp.uint32))
        codes = jnp.einsum("p,...pd->...d", weights, bits).astype(jnp.uint32)
        return inverse_gray(codes)
    if method == "proj_morton":
        p = projected[..., :2, :]  # (..., 2, d)
        lo = p.min(axis=-1, keepdims=True)
        hi = p.max(axis=-1, keepdims=True)
        u = (p - lo) / jnp.maximum(hi - lo, 1e-9)
        q8 = jnp.clip((u * 255.0).astype(jnp.int32), 0, 255)
        return _morton16(q8[..., 0, :], q8[..., 1, :])
    raise ValueError(f"unknown LSH method {method!r}")


def permutation_from_hashes(hashes: jax.Array) -> jax.Array:
    """Stable argsort of hashes → grouping permutation over d (paper Fig. 5)."""
    return jnp.argsort(hashes, axis=-1, stable=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("method",))
def lsh_permutation(
    block: jax.Array, proj: jax.Array, method: str = "sign_gray"
) -> jax.Array:
    """Convenience: block ``(..., l, d)`` → permutation ``(..., d)``."""
    return permutation_from_hashes(hash_columns(block, proj, method))
