"""Approximate-attention baselines the paper evaluates against (§4.1).

Faithful-in-spirit JAX implementations at the mechanism level (the paper's
baselines are full model forks; here they are drop-in attention functions so
the comparison isolates the attention mechanism itself):

* ``hydra_attention``   — Hydra Attention (Bolya et al. 2022): heads == d,
  cosine-similarity kernel ⇒ global context vector, O(N·d) — eliminates the
  attention matrix entirely.
* ``focused_linear_attention`` — Flatten Transformer (Han et al. 2023):
  focused (power-normalised) feature map + linear attention, O(N·d²).
* ``lowrank_attention`` — Primal/Linformer-style: keys/values projected to a
  fixed low rank r over the sequence dim, softmax over r, O(N·r·d).
* ``sampled_attention`` — HyperAttention-flavoured: attention restricted to
  an LSH-style uniform sample of key positions (sub-quadratic sampling of
  the score matrix).

All are GQA-aware via K/V head broadcast and used by benchmarks/compare.py
(Tables 5/7/8 analogue) and examples/attention_showcase.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_kv(q, k, v):
    hq, hkv = q.shape[1], k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return k, v


def hydra_attention(q, k, v, *, causal: bool = False, scale=None):
    """O(Nd): normalize, aggregate k⊙v globally (or causally via cumsum)."""
    k, v = _expand_kv(q, k, v)
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    kn = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
    kv = kn * v  # (B, H, N, d)
    if causal:
        ctx = jnp.cumsum(kv, axis=2)
    else:
        ctx = jnp.sum(kv, axis=2, keepdims=True)
    return (qn * ctx).astype(q.dtype)


def focused_linear_attention(q, k, v, *, causal: bool = False, scale=None,
                             focus_p: float = 3.0):
    """Flatten-style focused linear attention."""
    k, v = _expand_kv(q, k, v)

    def feat(x):
        x = jax.nn.relu(x) + 1e-6
        norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
        xp = x**focus_p
        return xp / (jnp.linalg.norm(xp, axis=-1, keepdims=True) + 1e-6) * norm

    qf, kf = feat(q.astype(jnp.float32)), feat(k.astype(jnp.float32))
    vf = v.astype(jnp.float32)
    if causal:
        kv = jnp.cumsum(kf[..., :, None] * vf[..., None, :], axis=2)
        z = jnp.cumsum(kf, axis=2)
        num = jnp.einsum("bhnd,bhndp->bhnp", qf, kv)
        den = jnp.einsum("bhnd,bhnd->bhn", qf, z)[..., None]
    else:
        kv = jnp.einsum("bhnd,bhnp->bhdp", kf, vf)
        z = kf.sum(axis=2)
        num = jnp.einsum("bhnd,bhdp->bhnp", qf, kv)
        den = jnp.einsum("bhnd,bhd->bhn", qf, z)[..., None]
    return (num / jnp.maximum(den, 1e-6)).astype(q.dtype)


def lowrank_attention(q, k, v, *, rank: int = 64, causal: bool = False,
                      scale=None, seed: int = 0):
    """Linformer/Primal-style: project K/V over the sequence to rank r.

    Causal masking is incompatible with sequence projection (known
    limitation of this family — documented in the paper's related work);
    causal=True falls back to block-triangular masking of the projected
    scores, matching common Linformer ports.
    """
    k, v = _expand_kv(q, k, v)
    b, h, n, d = q.shape
    r = min(rank, n)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    proj = jax.random.normal(jax.random.PRNGKey(seed), (n, r)) / (n / r) ** 0.5
    kp = jnp.einsum("bhnd,nr->bhrd", k.astype(jnp.float32), proj)
    vp = jnp.einsum("bhnd,nr->bhrd", v.astype(jnp.float32), proj)
    s = jnp.einsum("bhnd,bhrd->bhnr", q.astype(jnp.float32), kp) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhnr,bhrd->bhnd", p, vp).astype(q.dtype)


def sampled_attention(q, k, v, *, keep: int = 256, causal: bool = False,
                      scale=None, seed: int = 0):
    """HyperAttention-flavoured: softmax over a sampled subset of keys."""
    k, v = _expand_kv(q, k, v)
    b, h, n, d = q.shape
    m = min(keep, n)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    idx = jnp.sort(
        jax.random.choice(jax.random.PRNGKey(seed), n, (m,), replace=False)
    )
    ks = k[:, :, idx]
    vs = v[:, :, idx]
    s = jnp.einsum(
        "bhnd,bhmd->bhnm", q.astype(jnp.float32), ks.astype(jnp.float32)
    ) * scale
    if causal:
        mask = idx[None, :] <= jnp.arange(n)[:, None]
        s = jnp.where(mask, s, -1e30)
        # rows with no sampled key ≤ position fall back to uniform-over-first
        s = jnp.where(mask.any(-1, keepdims=True), s, 0.0)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhnm,bhmd->bhnd", p, vs.astype(jnp.float32)).astype(q.dtype)


BASELINES = {
    "hydra": hydra_attention,
    "flatten": focused_linear_attention,
    "primal_lowrank": lowrank_attention,
    "hyper_sampled": sampled_attention,
}
