"""Exact attention references.

``reference_attention``          — naive softmax(QKᵀ)V oracle (fp32 softmax).
``blockwise_flash_reference``    — FlashAttention-2 double loop (online
softmax) in pure JAX; numerically equals the oracle and mirrors the block
structure DistrAttention plugs into (paper §2.2.2 / Fig. 3).

Both are GQA-aware: ``q`` is ``(B, Hq, N, d)``; ``k``/``v`` are
``(B, Hkv, N, d)`` with ``Hq % Hkv == 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group_queries(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, Hq, N, d) → (B, Hkv, r, N, d) with r = Hq // Hkv."""
    b, hq, n, d = q.shape
    if hq % n_kv:
        raise ValueError(f"Hq={hq} not divisible by Hkv={n_kv}")
    return q.reshape(b, n_kv, hq // n_kv, n, d)


def causal_mask(n_q: int, n_k: int, q_offset: int = 0) -> jnp.ndarray:
    """Boolean mask (n_q, n_k): True where key j may attend to query i."""
    qi = q_offset + jnp.arange(n_q)[:, None]
    kj = jnp.arange(n_k)[None, :]
    return kj <= qi


def reference_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
    kv_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Naive exact attention oracle.

    kv_mask: optional ``(B, Nk)`` bool — False keys are masked out (padding /
    unfilled KV-cache slots).
    """
    b, hq, n, d = q.shape
    n_kv = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d**0.5)

    # bf16 operands + f32 accumulation (preferred_element_type): no
    # materialised f32 copies of Q/K/V — §Perf iteration 1.
    qg = _group_queries(q, n_kv)
    s = jnp.einsum(
        "bgrnd,bgmd->bgrnm", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        s = jnp.where(causal_mask(n, k.shape[2]), s, NEG_INF)
    if kv_mask is not None:
        s = jnp.where(kv_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bgrnm,bgmd->bgrnd", p.astype(q.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(b, hq, n, v.shape[-1]).astype(q.dtype)


def blockwise_flash_reference(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 128,
    block_k: int = 128,
    causal: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    """FA-2 style blockwise exact attention (online softmax), pure JAX.

    Ragged sequence lengths are handled in-place: inputs are padded to the
    block grid (mirroring ``kernels.ops._pad_seq``) and the dead KV tail is
    masked out, so every length stays on the O(N)-memory blockwise path —
    there is no dense fallback.
    """
    b, hq, n, d = q.shape
    dv = v.shape[-1]
    n_kv, nk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    r = hq // n_kv

    def _pad_seq(x, block, axis):
        pad = (-x.shape[axis]) % block
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad)
            x = jnp.pad(x, widths)
        return x

    q = _pad_seq(q, block_q, 2)
    k = _pad_seq(k, block_k, 2)
    v = _pad_seq(v, block_k, 2)
    n_pad, nk_pad = q.shape[2], k.shape[2]

    nq_blocks = n_pad // block_q
    nk_blocks = nk_pad // block_k

    qg = _group_queries(q, n_kv)  # (b, g, r, n, d) — compute dtype
    kf = k
    vf = v

    def outer(_, iq):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, iq * block_q, block_q, axis=3)

        def inner(carry, ik):
            acc, m_i, l_i = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kf, ik * block_k, block_k, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vf, ik * block_k, block_k, axis=2)
            s = jnp.einsum(
                "bgrnd,bgmd->bgrnm", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if causal or nk_pad != nk:
                qi = iq * block_q + jnp.arange(block_q)[:, None]
                kj = ik * block_k + jnp.arange(block_k)[None, :]
                mask = kj <= qi if causal else kj < nk
                if causal and nk_pad != nk:  # dead padded keys
                    mask = jnp.logical_and(mask, kj < nk)
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_i * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrnm,bgmd->bgrnd", p.astype(q.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, n_kv, r, block_q, dv), jnp.float32)
        m0 = jnp.full((b, n_kv, r, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, r, block_q), jnp.float32)
        (acc, _, l_i), _ = jax.lax.scan(
            inner, (acc0, m0, l0), jnp.arange(nk_blocks)
        )
        return None, (acc / l_i[..., None]).astype(q.dtype)

    # Remat per Q block — see core.distr_attention (avoids storing every
    # block's score tile for the backward pass).
    outer = jax.checkpoint(outer, prevent_cse=False)
    _, blocks = jax.lax.scan(outer, None, jnp.arange(nq_blocks))
    # blocks: (nq, b, g, r, block_q, dv) → (b, hq, n, dv)
    o = jnp.moveaxis(blocks, 0, 3).reshape(b, n_kv, r, n_pad, dv)
    return o.reshape(b, hq, n_pad, dv)[:, :, :n, :].astype(q.dtype)
