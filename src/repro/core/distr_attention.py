"""DistrAttention — block-wise grouped-dimension attention (paper §3).

Pure-JAX implementation; this is the XLA path used by the dry-run/roofline so
``cost_analysis()`` sees true FLOPs.  The Pallas TPU kernel
(``repro.kernels.distr_attention``) implements the identical math fused.

Structure (paper Fig. 6): Q is split into row-blocks of ``block_q``.  Each
block hashes its d columns with LSH (over ℝ^block_q), sorts, and derives one
permutation; the permutation samples the block's Q columns and fuses (sums)
*every* K row-block it meets — which is exactly why Q is the sampled side:
one permutation serves the whole inner loop (paper §3.3).

Scores are computed over the reduced dimension d/G*; softmax and the PV
product are unchanged, so the full N×N context is preserved.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import grouping, lsh
from repro.core.flash_reference import NEG_INF


@dataclass(frozen=True)
class DistrConfig:
    """The paper's tunables.

    group_size: the sampling rate G* (2, 4, 8, 16).  d_eff = d / G*.
    block_q / block_k: the (l, m) block sizes of §3.3.1.  ``None`` = auto:
      resolved by the block-size autotuner (repro.tune) at dispatch; note
      block_q is also the LSH permutation granularity, so tuning it trades
      grouping locality against tile efficiency.
    block_k_bwd: KV tile of the backward dQ̂/dKV kernels (``None`` = fwd
      block_k, or the independently-measured pick under REPRO_TUNE=measure;
      block_q stays pinned in the backward — it defines the grouping).
    estimator: "sample" (paper) | "mean" (beyond-paper variant).
    shared_kv_perm: beyond-paper — derive one permutation per KV group from
      the mean of its query heads, so fused K̂ is computed once per KV head
      instead of once per Q head (memory win for GQA; slight error increase).
    proj_seed: seed for the fixed LSH projection.
    """

    group_size: int = 2
    block_q: int | None = 128
    block_k: int | None = 128
    # Backward KV tile for the dQ̂/dKV kernels.  ``None`` = auto: the fwd
    # block_k, or — under REPRO_TUNE=measure — an independently-measured
    # pick per backward kernel.  block_q has no backward override on
    # purpose: it is the LSH grouping granularity and must stay pinned
    # (asserted in tune/autotune.py).
    block_k_bwd: int | None = None
    estimator: str = "sample"
    shared_kv_perm: bool = False
    proj_seed: int = 0
    # "sign_gray" = the paper's hash; "proj_morton" = magnitude-aware variant
    # (same cost, lower error on positive-orthant data — see core/lsh.py).
    hash_method: str = "sign_gray"

    def d_eff(self, d: int) -> int:
        return d // self.group_size

    def resolved(
        self, d: int, n: int, *, dtype: str = "float32",
        causal: bool = False, xla: bool = True,
        interpret: bool | None = None,
    ) -> "DistrConfig":
        """Fill ``None`` block sizes via the autotuner (repro.tune); explicit
        ints pass through unchanged.  A *partial* pin gets the static 128
        default for the free dim (same policy as the flash resolvers —
        mixing a pinned dim into a jointly-tuned pair would produce a tile
        the sweep never validated)."""
        from dataclasses import replace

        if self.block_q is not None and self.block_k is not None:
            return self
        if self.block_q is not None or self.block_k is not None:
            return replace(
                self, block_q=self.block_q or 128, block_k=self.block_k or 128
            )
        from repro.tune.autotune import resolve_block_sizes

        bs = resolve_block_sizes(
            "xla_distr" if xla else "distr", d=d, n=n, dtype=dtype,
            group_size=self.group_size, causal=causal,
            interpret=False if xla else interpret,
        )
        return replace(self, block_q=bs.block_q, block_k=bs.block_k)


def _pad_to_multiple(x: jnp.ndarray, block: int, axis: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[axis]
    pad = (-n) % block
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def compute_block_permutations(
    q: jnp.ndarray, cfg: DistrConfig, proj: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Per-Q-block LSH permutations.

    q: (B, H, N, d) with N divisible by block_q → perms (B, H, nq, d).
    """
    b, h, n, d = q.shape
    nq = n // cfg.block_q
    if proj is None:
        proj = lsh.make_projection(jax.random.PRNGKey(cfg.proj_seed), cfg.block_q)
    blocks = q.reshape(b, h, nq, cfg.block_q, d)
    return lsh.lsh_permutation(blocks, proj, cfg.hash_method)


def distr_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: DistrConfig = DistrConfig(),
    *,
    causal: bool = False,
    scale: float | None = None,
    proj: jnp.ndarray | None = None,
    kv_mask: jnp.ndarray | None = None,
    q_exact: jnp.ndarray | None = None,
    k_exact: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Block-wise DistrAttention.  GQA-aware.

    q: (B, Hq, N, d);  k, v: (B, Hkv, Nk, d), Hq % Hkv == 0.  d_v may differ
    from d (MLA).

    q_exact / k_exact: optional extra feature slices whose scores are
    computed exactly (not grouped) and added before the softmax.  Used for
    MLA's RoPE sub-dimensions, where fusing rows would break the rotation
    structure (DESIGN.md §4).  Shapes (B, Hq, N, d_e) / (B, Hkv, Nk, d_e).
    """
    b, hq, n, d = q.shape
    dv = v.shape[-1]
    n_kv = k.shape[1]
    r = hq // n_kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    cfg = cfg.resolved(
        d, max(n, k.shape[2]),
        dtype="bfloat16" if q.dtype == jnp.bfloat16 else "float32",
        causal=causal, xla=True,
    )
    g = cfg.group_size
    dg = cfg.d_eff(d)

    q, pad_q = _pad_to_multiple(q, cfg.block_q, axis=2)
    n_padded = q.shape[2]
    nq = n_padded // cfg.block_q
    nk = k.shape[2]

    if proj is None:
        proj = lsh.make_projection(jax.random.PRNGKey(cfg.proj_seed), cfg.block_q)

    # --- Stage 1: per-Q-block permutations (the lightweight LSH stage, §4.8).
    perms = compute_block_permutations(q, cfg, proj)  # (b, hq, nq, d)
    if cfg.shared_kv_perm:
        # One permutation per KV group: hash the mean query block of the group.
        q_mean = q.reshape(b, n_kv, r, n_padded, d).mean(axis=2)
        perms = compute_block_permutations(q_mean, cfg, proj)  # (b, hkv, nq, d)
        perms = jnp.broadcast_to(
            perms[:, :, None], (b, n_kv, r, nq, d)
        ).reshape(b, hq, nq, d)

    q_blocks = q.reshape(b, hq, nq, cfg.block_q, d)
    if cfg.estimator == "sample":
        q_hat = grouping.sample_columns(q_blocks, perms, g)
    elif cfg.estimator == "mean":
        q_hat = grouping.mean_columns(q_blocks, perms, g)
    else:
        raise ValueError(f"unknown estimator {cfg.estimator!r}")
    # (b, hq, nq, block_q, dg)

    # Keep K/V in the compute dtype: fusion gathers at bf16 width and the
    # einsums accumulate in f32 via preferred_element_type (§Perf iter 1).
    kf = k
    vf = v
    if q_exact is not None:
        q_exact, _ = _pad_to_multiple(q_exact, cfg.block_q, axis=2)
        de = q_exact.shape[-1]
        qe_blocks = q_exact.reshape(b, hq, nq, cfg.block_q, de)
        kef = k_exact

    def one_q_block(iq, q_hat_blk, perm_blk, qe_blk):
        """q_hat_blk: (b,hq,block_q,dg); perm_blk: (b,hq,d) → (b,hq,block_q,dv)."""
        # Fuse K under this block's permutation.  K is per-KV-head; the
        # permutation is per-Q-head, so fuse in grouped layout.  take_along_axis
        # broadcasts K's singleton r-axis against the per-Q-head permutations.
        perm_g = perm_blk.reshape(b, n_kv, r, d)
        k_hat = grouping.fuse_columns(kf[:, :, None], perm_g, g)
        # (b, hkv, r, nk, dg) in compute dtype.  Keep the fused keys and the
        # score rows sharded along the *sequence* axis so a seq-sharded K
        # never re-gathers inside the Q-block scan; the softmax's row stats
        # turn into tiny (l,)-vector all-reduces instead (flash-decoding
        # style) — §Perf iter 4b.
        from repro.models.layers import constrain as _c

        k_hat = _c(k_hat, "data", None, None, "model", None)
        qg = q_hat_blk.reshape(b, n_kv, r, cfg.block_q, dg)
        s = jnp.einsum(
            "bgrld,bgrnd->bgrln", qg, k_hat,
            preferred_element_type=jnp.float32,
        )
        s = _c(s, "data", None, None, None, "model")
        if qe_blk is not None:
            # Exact (ungrouped) feature slice, e.g. MLA RoPE dims.
            qe = qe_blk.reshape(b, n_kv, r, cfg.block_q, -1)
            s = s + jnp.einsum(
                "bgrld,bgnd->bgrln", qe, kef,
                preferred_element_type=jnp.float32,
            )
        s = s * scale
        if causal:
            qi = iq * cfg.block_q + jnp.arange(cfg.block_q)[:, None]
            kj = jnp.arange(nk)[None, :]
            s = jnp.where(kj <= qi, s, NEG_INF)
        if kv_mask is not None:
            s = jnp.where(kv_mask[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bgrln,bgnd->bgrld", p.astype(q.dtype), vf,
            preferred_element_type=jnp.float32,
        )
        # Cast inside the scan body: the stacked ys (and their grads) stay in
        # the compute dtype instead of f32 (2× scan-carry memory otherwise).
        return o.reshape(b, hq, cfg.block_q, dv).astype(q.dtype)

    if q_exact is None:

        def scan_body(_, inputs):
            iq, q_hat_blk, perm_blk = inputs
            return None, one_q_block(iq, q_hat_blk, perm_blk, None)

        xs = (jnp.arange(nq), jnp.moveaxis(q_hat, 2, 0), jnp.moveaxis(perms, 2, 0))
    else:

        def scan_body(_, inputs):
            iq, q_hat_blk, perm_blk, qe_blk = inputs
            return None, one_q_block(iq, q_hat_blk, perm_blk, qe_blk)

        xs = (
            jnp.arange(nq),
            jnp.moveaxis(q_hat, 2, 0),
            jnp.moveaxis(perms, 2, 0),
            jnp.moveaxis(qe_blocks, 2, 0),
        )

    # Remat per Q block: without this the scan VJP saves every block's
    # (l × N) score matrix — tens of GiB per layer at 4k×4k — instead of
    # recomputing them during the backward sweep (FA-2's whole point).
    scan_body = jax.checkpoint(scan_body, prevent_cse=False)
    _, blocks = jax.lax.scan(scan_body, None, xs)
    # blocks: (nq, b, hq, block_q, dv)
    out = jnp.moveaxis(blocks, 0, 2).reshape(b, hq, n_padded, dv)
    if pad_q:
        out = out[:, :, :n, :]
    return out.astype(q.dtype)


def distr_scores(
    q: jnp.ndarray,
    k: jnp.ndarray,
    cfg: DistrConfig = DistrConfig(),
    *,
    scale: float = 1.0,
    proj: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """The approximate score matrix Ŝ alone (used by the paper's error study,
    Tables 3-4).  q, k: (B, H, N, d) → (B, H, N, N)."""
    b, h, n, d = q.shape
    cfg = cfg.resolved(
        d, n, dtype="bfloat16" if q.dtype == jnp.bfloat16 else "float32",
        xla=True,
    )
    q, pad_q = _pad_to_multiple(q, cfg.block_q, axis=2)
    nq = q.shape[2] // cfg.block_q
    if proj is None:
        proj = lsh.make_projection(jax.random.PRNGKey(cfg.proj_seed), cfg.block_q)
    perms = compute_block_permutations(q, cfg, proj)
    q_blocks = q.reshape(b, h, nq, cfg.block_q, d)
    if cfg.estimator == "sample":
        q_hat = grouping.sample_columns(q_blocks, perms, cfg.group_size)
    else:
        q_hat = grouping.mean_columns(q_blocks, perms, cfg.group_size)
    # K broadcast over the nq axis; one fused K̂ per Q-block permutation.
    k_hat = grouping.fuse_columns(k[:, :, None].astype(jnp.float32), perms, cfg.group_size)
    # q_hat: (b,h,nq,l,dg); k_hat: (b,h,nq,N,dg)
    s = jnp.einsum("bhqld,bhqnd->bhqln", q_hat.astype(jnp.float32), k_hat) * scale
    s = s.reshape(b, h, q.shape[2], k.shape[2])
    if pad_q:
        s = s[:, :, :n]
    return s
