"""Block-size selection (paper §3.3.1), re-derived for the TPU memory system.

The paper's model (GPU):
  I(l, m) = (N/l) · (l·d + 2·N·d + l·d)      # HBM I/O count: max l wins
  l, m ≡ 0 (mod N'=16)                        # tensor-core fragment quantum
  W_b · M_s / (w·(l·d + 2·m·d)) ≥ 2·N_T       # warp occupancy bound

TPU re-derivation (DESIGN.md §2):
  * quantisation unit is the 128-wide lane/MXU tile, not 16;
  * the "shared memory" is VMEM (~16 MiB/core) and must hold the Q tile,
    one K and one V tile (double-buffered by Mosaic ⇒ ×2 on K/V), the fp32
    accumulator (l×d), and the l×m score tile;
  * the occupancy constraint becomes a VMEM-fit constraint; the MXU is kept
    busy as long as l·m ≥ 128².

Selection rule is the paper's: maximise l first (minimises HBM I/O), then
maximise m (fewer grid steps / less per-step overhead), subject to fit.
"""
from __future__ import annotations

from dataclasses import dataclass

LANE = 128  # TPU lane width / MXU tile edge.


@dataclass(frozen=True)
class TpuSpec:
    vmem_bytes: int = 16 * 1024 * 1024
    # Fraction of VMEM the attention working set may claim (Mosaic needs
    # headroom for semaphores/spills and we double-buffer K/V).
    usable_fraction: float = 0.8
    lane: int = LANE


def working_set_bytes(
    l: int, m: int, d: int, *, w: int = 2, group_size: int = 1, acc_bytes: int = 4
) -> int:
    """VMEM bytes for one (Q-block, K-block) step of (Distr)FlashAttention.

    Q tile l×d, double-buffered K and V tiles m×d each, fp32 accumulator l×d,
    fp32 softmax stats 2×l, score tile l×m.  With DistrAttention the score
    matmul reads sampled Q (l×d/G*) and fused K̂ (m×d/G*), which live
    alongside their sources.
    """
    dg = d // group_size
    q_side = l * d * w + (l * dg * w if group_size > 1 else 0)
    kv_side = 2 * (m * d * w) * 2  # K and V, double buffered
    k_hat = m * dg * acc_bytes if group_size > 1 else 0
    acc = l * d * acc_bytes + 2 * l * acc_bytes
    scores = l * m * acc_bytes
    return q_side + kv_side + k_hat + acc + scores


def io_count(l: int, n: int, d: int) -> int:
    """The paper's I(l, m): HBM element I/Os — independent of m."""
    return (n // l) * (2 * l * d + 2 * n * d)


def select_block_sizes(
    d: int,
    *,
    n: int = 4096,
    group_size: int = 1,
    spec: TpuSpec = TpuSpec(),
    w: int = 2,
    max_l: int = 1024,
    max_m: int = 1024,
) -> tuple[int, int]:
    """Pick (l, m): maximise l, then m, subject to VMEM fit and 128-alignment.

    Mirrors Table 2's procedure with TPU constants.
    """
    budget = int(spec.vmem_bytes * spec.usable_fraction)
    best = None
    l = (max_l // spec.lane) * spec.lane
    while l >= spec.lane:
        m = (max_m // spec.lane) * spec.lane
        while m >= spec.lane:
            if working_set_bytes(l, m, d, w=w, group_size=group_size) <= budget:
                best = (l, m)
                break
            m -= spec.lane
        if best is not None:
            break
        l -= spec.lane
    if best is None:
        # Degenerate: fall back to the minimum aligned tile.
        best = (spec.lane, spec.lane)
    return best


def enumerate_block_sizes(
    d: int,
    *,
    group_size: int = 1,
    spec: TpuSpec = TpuSpec(),
    w: int = 2,
    max_l: int = 1024,
    max_m: int = 1024,
) -> list[tuple[int, int, int]]:
    """All legal (l, m, working_set_bytes) — the "best" search of Table 2."""
    budget = int(spec.vmem_bytes * spec.usable_fraction)
    out = []
    for l in range(spec.lane, max_l + 1, spec.lane):
        for m in range(spec.lane, max_m + 1, spec.lane):
            ws = working_set_bytes(l, m, d, w=w, group_size=group_size)
            if ws <= budget:
                out.append((l, m, ws))
    return out
