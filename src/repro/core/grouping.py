"""Sampling and fusion over grouped embedding-dim columns (paper §3.1-3.2).

Given a permutation that orders similar columns next to each other, groups are
the consecutive runs of ``group_size`` permuted columns:

* ``sample``  — pick one representative Q column per group (paper's sampling);
* ``fuse``    — sum the K columns of each group (paper's fusion);
* ``mean``    — beyond-paper estimator: average the Q columns instead of
  sampling one; pairs with fused K as (1/G*)(Σq)(Σk) and empirically halves
  the Ŝ error at the cost of a cheap segment-sum on Q.
"""
from __future__ import annotations

import jax.numpy as jnp


def _take_columns(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather embedding-dim columns: x ``(..., n, d)``, idx ``(..., k)``."""
    # Broadcast idx over the row axis.
    return jnp.take_along_axis(x, idx[..., None, :], axis=-1)


def sampled_indices(perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Representative column index per group: first column in sorted order."""
    return perm[..., ::group_size]


def sample_columns(x: jnp.ndarray, perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Q-side sampling: ``(..., n, d) → (..., n, d // group_size)``."""
    return _take_columns(x, sampled_indices(perm, group_size))


def sample_q_heads(q: jnp.ndarray, perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Sample Q columns under a *per-KV-head* static permutation (the decode
    cache's fixed grouping — serve.kv_cache.static_perms).

    q: ``(B, Hq, n, d)``; perm: ``(Hkv, d)`` with Hq a multiple of Hkv →
    ``(B, Hq, n, d // group_size)``.  Every query head in a GQA group shares
    its KV head's permutation.  Single home for the kernel wrapper, the
    reference dispatch, and the serve cache (they must agree exactly).
    """
    b, hq, n, d = q.shape
    hkv = perm.shape[0]
    idx = sampled_indices(perm, group_size)  # (Hkv, d/g)
    qg = q.reshape(b, hkv, hq // hkv, n, d)
    out = jnp.take_along_axis(qg, idx[None, :, None, None, :], axis=-1)
    return out.reshape(b, hq, n, d // group_size)


def fuse_columns(x: jnp.ndarray, perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """K-side fusion: permute columns then sum each run of ``group_size``.

    ``(..., n, d) → (..., n, d // group_size)``
    """
    d = x.shape[-1]
    if d % group_size:
        raise ValueError(f"d={d} not divisible by group_size={group_size}")
    permuted = _take_columns(x, perm)
    new_shape = permuted.shape[:-1] + (d // group_size, group_size)
    return permuted.reshape(new_shape).sum(axis=-1)


def mean_columns(x: jnp.ndarray, perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Beyond-paper Q estimator: group mean instead of a single sample."""
    return fuse_columns(x, perm, group_size) / group_size


def reduce_qk(
    q: jnp.ndarray,
    k: jnp.ndarray,
    perm: jnp.ndarray,
    group_size: int,
    estimator: str = "sample",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the paper's reduction to a (Q block, K block) pair.

    Args:
      q: ``(..., l, d)`` query block.
      k: ``(..., m, d)`` key block (NOT transposed).
      perm: ``(..., d)`` grouping permutation derived from the Q block.
      group_size: the paper's sampling rate ``G*``.
      estimator: ``"sample"`` (paper) or ``"mean"`` (beyond-paper).

    Returns:
      ``(q_hat, k_hat)`` with trailing dim ``d // group_size``.  The score
      block ``q_hat @ k_hat^T`` approximates ``q @ k^T`` (still scaled by
      1/sqrt(d) downstream — the fused sum stands in for the full d-term dot
      product).
    """
    if estimator == "sample":
        q_hat = sample_columns(q, perm, group_size)
    elif estimator == "mean":
        q_hat = mean_columns(q, perm, group_size)
    else:
        raise ValueError(f"unknown estimator {estimator!r}")
    k_hat = fuse_columns(k, perm, group_size)
    return q_hat, k_hat
