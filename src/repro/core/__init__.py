"""DistrAttention core — the paper's contribution as composable JAX modules."""
from repro.core.api import IMPLS, AttentionConfig, attend
from repro.core.distr_attention import DistrConfig, distr_attention, distr_scores
from repro.core.flash_reference import (
    blockwise_flash_reference,
    reference_attention,
)
from repro.core import block_size, grouping, lsh

__all__ = [
    "IMPLS",
    "AttentionConfig",
    "DistrConfig",
    "attend",
    "block_size",
    "blockwise_flash_reference",
    "distr_attention",
    "distr_scores",
    "grouping",
    "lsh",
    "reference_attention",
]
