"""Unified attention dispatch — the framework-facing entry point.

``AttentionConfig`` selects the implementation:

  reference    — naive exact softmax oracle
  xla_flash    — FA-2 blockwise exact, pure JAX (XLA path)
  distr        — DistrAttention, pure JAX (XLA path; dry-run default)
  pallas_flash — Pallas TPU FA-2 kernel (interpret auto-detected per backend)
  pallas_distr — Pallas TPU DistrAttention kernel (interpret auto-detected)

Models call :func:`attend` and never touch implementations directly, so a
single config flag flips an architecture between exact and DistrAttention —
the paper's "flexibility" knob (speed vs accuracy via group_size).

The Pallas paths are differentiable (``kernels.ops`` wires ``custom_vjp``
to the fused FA-2-style backward kernels), so training under
``pallas_flash`` / ``pallas_distr`` runs the kernel path end-to-end instead
of the ``jax.checkpoint``-scan XLA fallback (DESIGN.md §Backward).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp

from repro.core.distr_attention import DistrConfig, distr_attention
from repro.core.flash_reference import blockwise_flash_reference, reference_attention

IMPLS = ("reference", "xla_flash", "distr", "pallas_flash", "pallas_distr")


@dataclass(frozen=True)
class AttentionConfig:
    impl: str = "xla_flash"
    distr: DistrConfig = field(default_factory=DistrConfig)
    # Kernel block sizes for the exact paths (distr block sizes live in
    # DistrConfig so the paper's (l, m) study has one home).
    block_q: int = 128
    block_k: int = 128
    # Pallas interpret mode: None = auto (compiled on TPU, interpreter on
    # the CPU container); set explicitly only to force one mode.
    interpret: bool | None = None
    # Beyond-paper: serve-side fused-K̂ decode cache under a static
    # permutation (see serve.kv_cache); cuts K-cache read bytes by 1/G*.
    distr_decode: bool = False

    def with_impl(self, impl: str) -> "AttentionConfig":
        return replace(self, impl=impl)


def attend(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: AttentionConfig,
    *,
    causal: bool = False,
    scale: float | None = None,
    kv_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Multi-head attention with the configured implementation.

    q: (B, Hq, N, d);  k, v: (B, Hkv, Nk, d).
    """
    if cfg.impl == "reference":
        return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
    if cfg.impl == "xla_flash":
        if kv_mask is not None:
            # Blockwise path has no kv_mask plumbing; the oracle handles it.
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        n = q.shape[2]
        if n < cfg.block_q or n % cfg.block_q or k.shape[2] % cfg.block_k:
            return reference_attention(q, k, v, causal=causal, scale=scale)
        return blockwise_flash_reference(
            q, k, v, block_q=cfg.block_q, block_k=cfg.block_k, causal=causal, scale=scale
        )
    if cfg.impl == "distr":
        return distr_attention(
            q, k, v, cfg.distr, causal=causal, scale=scale, kv_mask=kv_mask
        )
    if cfg.impl == "pallas_flash":
        if kv_mask is not None:
            # Kernels have no kv_mask plumbing; the oracle handles it.
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        from repro.kernels import ops  # deferred: kernels are optional at import

        return ops.flash_attention(
            q, k, v, causal=causal, scale=scale,
            block_q=cfg.block_q, block_k=cfg.block_k, interpret=cfg.interpret,
        )
    if cfg.impl == "pallas_distr":
        if kv_mask is not None:
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        from repro.kernels import ops

        return ops.distr_attention(
            q, k, v, cfg.distr, causal=causal, scale=scale, interpret=cfg.interpret,
        )
    raise ValueError(f"unknown attention impl {cfg.impl!r}; choose from {IMPLS}")


def attend_decode(
    q: jnp.ndarray,
    k: jnp.ndarray | None,
    v: jnp.ndarray,
    cfg: AttentionConfig,
    *,
    lengths: jnp.ndarray | None = None,
    k_fused: jnp.ndarray | None = None,
    perm: jnp.ndarray | None = None,
    group_size: int = 1,
    scale: float | None = None,
) -> jnp.ndarray:
    """Decode-path attention dispatch: one (or a few speculative) query
    tokens against a (B, Hkv, S, d) KV cache with per-slot live ``lengths``.

    Every impl except ``reference`` routes to the split-K flash-decoding
    Pallas op (``kernels.ops.decode_attention``) — per-token KV traffic then
    scales with the live length, not S.  ``reference`` keeps the pure-JAX
    masked-softmax oracle (the parity baseline in tests).  The fused-K̂
    variant is selected by passing ``k_fused`` + ``perm`` + ``group_size``
    (see serve.kv_cache); ``k`` may be None in that case.  ``scale`` always
    refers to the full head dim (default 1/√d from V) on both paths.
    """
    if cfg.impl not in IMPLS:
        raise ValueError(
            f"unknown attention impl {cfg.impl!r}; choose from {IMPLS}"
        )
    scale = float(scale) if scale is not None else 1.0 / (v.shape[-1] ** 0.5)
    if cfg.impl == "reference":
        from repro.core import grouping

        nk = (k_fused if k_fused is not None else k).shape[2]
        kv_mask = (
            jnp.arange(nk)[None, :] < lengths[:, None]
            if lengths is not None
            else None
        )
        if k_fused is not None:
            q_s = grouping.sample_q_heads(q, perm, group_size)
            return reference_attention(
                q_s, k_fused.astype(q_s.dtype), v.astype(q_s.dtype),
                causal=False, scale=scale, kv_mask=kv_mask,
            )
        return reference_attention(
            q, k.astype(q.dtype), v.astype(q.dtype),
            causal=False, scale=scale, kv_mask=kv_mask,
        )
    from repro.kernels import ops  # deferred: kernels are optional at import

    return ops.decode_attention(
        q, k, v, lengths=lengths, k_fused=k_fused, perm=perm,
        group_size=group_size, scale=scale, block_k=cfg.block_k,
        interpret=cfg.interpret,
    )
