"""Unified attention dispatch — the framework-facing entry point.

``AttentionConfig`` selects the implementation:

  reference    — naive exact softmax oracle
  xla_flash    — FA-2 blockwise exact, pure JAX (XLA path)
  distr        — DistrAttention, pure JAX (XLA path; dry-run default)
  pallas_flash — Pallas TPU FA-2 kernel (interpret auto-detected per backend)
  pallas_distr — Pallas TPU DistrAttention kernel (interpret auto-detected)

Models call :func:`attend` and never touch implementations directly, so a
single config flag flips an architecture between exact and DistrAttention —
the paper's "flexibility" knob (speed vs accuracy via group_size).

The Pallas paths are differentiable (``kernels.ops`` wires ``custom_vjp``
to the fused FA-2-style backward kernels), so training under
``pallas_flash`` / ``pallas_distr`` runs the kernel path end-to-end instead
of the ``jax.checkpoint``-scan XLA fallback (DESIGN.md §Backward).

They also scale past one device's HBM: with ``context_axis`` set and an
active mesh carrying that axis, dispatch goes to
``distributed.ring_attention`` — ring sequence-parallel attention over the
same kernels (DESIGN.md §Context parallelism).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp

from repro.core.distr_attention import DistrConfig, distr_attention
from repro.core.flash_reference import blockwise_flash_reference, reference_attention
# Leaf imports only (no repro deps): the resolver itself is imported lazily in
# resolve_attention_blocks to keep repro.core ↔ repro.tune import-order-free.
from repro.tune.block_sizes import BlockSizes
from repro.tune.cache import dtype_str as _dtype_str

IMPLS = ("reference", "xla_flash", "distr", "pallas_flash", "pallas_distr")

# Resolution kind per impl for the block-size autotuner (repro.tune); the
# XLA path is keyed separately from the Pallas kernel — same analytic
# search space, different measured optimum.  The distr impls are absent:
# their dispatch reads DistrConfig's blocks, resolved via
# DistrConfig.resolved (kinds "xla_distr" / "distr").
_TUNE_KIND = {
    "xla_flash": "xla_flash",
    "pallas_flash": "flash",
}


@dataclass(frozen=True)
class AttentionConfig:
    impl: str = "xla_flash"
    distr: DistrConfig = field(default_factory=DistrConfig)
    # Kernel block sizes for the exact paths (distr block sizes live in
    # DistrConfig so the paper's (l, m) study has one home).  ``None`` means
    # "auto": resolved at dispatch by the block-size autotuner according to
    # REPRO_TUNE (off → static 128, analytic → paper §3.3.1 rule, measure →
    # measured best from the persistent cache; DESIGN.md §Autotuning).
    block_q: int | None = None
    block_k: int | None = None
    # Decode split-K length — a separate knob from the fwd KV tile (pinning
    # prefill tiles must not override the decode split's own tuning).
    # None = auto (REPRO_TUNE, keyed per cache capacity).
    block_k_decode: int | None = None
    # Pallas interpret mode: None = auto (compiled on TPU, interpreter on
    # the CPU container); set explicitly only to force one mode.
    interpret: bool | None = None
    # Context parallelism: name of the mesh axis the sequence dimension is
    # ring-sharded over.  When set and the active mesh has that axis (size
    # > 1), the Pallas impls dispatch to distributed.ring_attention — Q/K/V
    # shard on the sequence axis, KV rotates hop-by-hop, partial (O, LSE)
    # merge online — so max sequence length scales with ring size instead
    # of HBM per chip.  Short sequences (< ring size × 128) stay on one
    # device: a ring hop is not worth its ppermute below a full lane tile.
    # For model-integrated use, name the mesh axis
    # distributed.sharding.CONTEXT_AXIS ("context"): the built-in sharding
    # rules special-case that literal to keep the batch dim off the ring.
    context_axis: str | None = None
    # Beyond-paper: serve-side fused-K̂ decode cache under a static
    # permutation (see serve.kv_cache); cuts K-cache read bytes by 1/G*.
    distr_decode: bool = False

    def with_impl(self, impl: str) -> "AttentionConfig":
        return replace(self, impl=impl)

    def degraded(self, group_size: int) -> "AttentionConfig":
        """The overload-degradation dial (serve.degrade): this config with
        prefill switched onto DistrAttention at grouping fraction
        1/``group_size``.  ``group_size ≤ 1`` returns the config unchanged
        (the engine's exact path — degradation is fully reversible).  The
        Pallas impls degrade to the Pallas distr kernel, the XLA paths to
        the pure-JAX distr implementation, so the backend family (and its
        interpret/tuning setup) is preserved; every other knob rides along
        via ``replace``."""
        if group_size <= 1:
            return self
        impl = "pallas_distr" if self.impl.startswith("pallas") else "distr"
        return replace(
            self, impl=impl, distr=replace(self.distr, group_size=group_size)
        )


def _active_context_mesh(context_axis: str | None):
    """The active mesh when it carries a >1-sized ``context_axis``, else
    None (no mesh set, axis missing, or trivially sized — the single-device
    paths apply)."""
    if not context_axis:
        return None
    from repro.utils.jax_compat import get_abstract_mesh

    mesh = get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    if context_axis not in mesh.axis_names:
        return None
    return mesh if int(mesh.shape[context_axis]) > 1 else None


def _ring_dispatch(cfg: AttentionConfig, q, k, v, *, causal, scale, kv_mask):
    """Route to distributed.ring_attention when context parallelism applies;
    returns None to fall through to the single-device paths."""
    if cfg.impl not in ("pallas_flash", "pallas_distr") or kv_mask is not None:
        return None
    if q.shape[2] != k.shape[2]:  # ring is self-attention only (cross-attn
        return None  # keeps the single-device kernels)
    mesh = _active_context_mesh(cfg.context_axis)
    if mesh is None:
        return None
    from repro.distributed import ring_attention as ring

    p = int(mesh.shape[cfg.context_axis])
    if q.shape[2] < p * ring.MIN_RING_SHARD:
        return None
    if cfg.impl == "pallas_flash":
        blocks = None
        if cfg.block_q is not None or cfg.block_k is not None:
            blocks = BlockSizes.from_pair(cfg.block_q or 128, cfg.block_k or 128)
        return ring.ring_flash_attention(
            q, k, v, mesh, axis=cfg.context_axis, causal=causal, scale=scale,
            blocks=blocks, interpret=cfg.interpret,
        )
    return ring.ring_distr_attention(
        q, k, v, cfg.distr, mesh, axis=cfg.context_axis, causal=causal,
        scale=scale, interpret=cfg.interpret,
    )


def resolve_attention_blocks(
    cfg: AttentionConfig,
    *,
    d: int,
    n_q: int,
    n_k: int | None = None,
    dtype: str = "float32",
    causal: bool = False,
    bwd: bool = False,
) -> BlockSizes:
    """Concrete :class:`BlockSizes` for one dispatch site.

    Explicit ints in the config always win; both-``None`` resolves through
    the autotuner under the key (impl-kind, backend, dtype, d, G*,
    seq-bucket, causal).  A *partial* pin (one int, one None) uses the
    static default for the free dim — mixing a pinned dim into a tuned
    pair measured for a different combination would produce a tile the
    search never validated.  ``bwd=True`` (training warm-up) additionally
    resolves the backward dQ/dKV keys in measure mode; forward-only
    dispatch leaves them to resolve lazily at backward-trace time.
    Shape-only — safe to call while tracing.

    Under context parallelism (``cfg.context_axis`` naming an active mesh
    axis) the tuner key is *per-shard*: the sequence bucket is the length
    one ring device actually streams, ``context_shard_len(n, P)``, not the
    global N — matching what distributed.ring_attention resolves at
    dispatch.
    """
    n_k = n_k if n_k is not None else n_q
    mesh = _active_context_mesh(cfg.context_axis)
    if mesh is not None and cfg.impl.startswith("pallas") and n_q == n_k:
        # Mirror the _ring_dispatch guards (self-attention, long enough to
        # fill a shard per device): warming a bucket the dispatch will
        # never route to the ring would leave the *real* bucket cold and
        # the measure-mode sweep would fire inside the first jitted step.
        from repro.distributed.ring_attention import (
            MIN_RING_SHARD, context_shard_len,
        )

        p = int(mesh.shape[cfg.context_axis])
        if n_q >= p * MIN_RING_SHARD:
            n_q = n_k = context_shard_len(n_q, p)
    if cfg.impl in ("distr", "pallas_distr"):
        # The distr dispatch reads DistrConfig's blocks, not ours — resolve
        # (or pass through) those, so warm-up and launcher logs report the
        # blocks that actually execute.
        dcfg = cfg.distr.resolved(
            d, max(n_q, n_k), dtype=dtype, causal=causal,
            xla=(cfg.impl == "distr"), interpret=cfg.interpret,
        )
        if bwd and cfg.impl == "pallas_distr":
            # Training warm-up: pre-resolve (measure mode: sweep + persist)
            # the backward block_k keys too — block_q stays pinned as the
            # LSH grouping granularity.
            from repro.tune.autotune import get_autotuner, tune_mode

            if dcfg.block_k_bwd is None and tune_mode() == "measure":
                tuner = get_autotuner()
                kw = dict(
                    block_q=dcfg.block_q, d=d, n=max(n_q, n_k), dtype=dtype,
                    group_size=dcfg.group_size, causal=causal,
                    interpret=cfg.interpret, fwd_block_k=dcfg.block_k,
                )
                tuner.resolve_distr_bwd("distr_dq", **kw)
                tuner.resolve_distr_bwd("distr_dkv", **kw)
        return BlockSizes.from_pair(dcfg.block_q, dcfg.block_k)
    if cfg.block_q is not None or cfg.block_k is not None:
        # Fully pinned, or a partial pin (free dim → static default).
        return BlockSizes.from_pair(cfg.block_q or 128, cfg.block_k or 128)
    kind = _TUNE_KIND.get(cfg.impl)
    if kind is None:  # reference oracle: blocks unused
        return BlockSizes.from_pair(128, 128)
    interpret = cfg.interpret if cfg.impl.startswith("pallas") else False
    from repro.tune.autotune import resolve_block_sizes

    return resolve_block_sizes(
        kind, d=d, n=max(n_q, n_k), dtype=dtype, causal=causal,
        interpret=interpret, bwd=bwd,
    )


def attend(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: AttentionConfig,
    *,
    causal: bool = False,
    scale: float | None = None,
    kv_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Multi-head attention with the configured implementation.

    q: (B, Hq, N, d);  k, v: (B, Hkv, Nk, d).

    When ``cfg.context_axis`` names an axis of the active mesh, the Pallas
    impls run ring sequence-parallel (distributed.ring_attention): Q/K/V
    shard over the sequence axis, KV rotates around the ring, and partial
    (O, LSE) merge online — the same kernels, one shard per device.
    """
    ring_out = _ring_dispatch(
        cfg, q, k, v, causal=causal, scale=scale, kv_mask=kv_mask
    )
    if ring_out is not None:
        return ring_out
    if cfg.impl == "reference":
        return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
    if cfg.impl == "xla_flash":
        if kv_mask is not None:
            # Blockwise path has no kv_mask plumbing; the oracle handles it.
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        bs = resolve_attention_blocks(
            cfg, d=q.shape[-1], n_q=q.shape[2], n_k=k.shape[2],
            dtype=_dtype_str(q), causal=causal,
        )
        # Ragged lengths stay blockwise: blockwise_flash_reference pads and
        # masks internally (no silent O(N²) dense fallback).
        return blockwise_flash_reference(
            q, k, v, block_q=bs.block_q, block_k=bs.block_k, causal=causal, scale=scale
        )
    if cfg.impl == "distr":
        return distr_attention(
            q, k, v, cfg.distr, causal=causal, scale=scale, kv_mask=kv_mask
        )
    if cfg.impl == "pallas_flash":
        if kv_mask is not None:
            # Kernels have no kv_mask plumbing; the oracle handles it.
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        from repro.kernels import ops  # deferred: kernels are optional at import

        bs = resolve_attention_blocks(
            cfg, d=q.shape[-1], n_q=q.shape[2], n_k=k.shape[2],
            dtype=_dtype_str(q), causal=causal,
        )
        return ops.flash_attention(
            q, k, v, causal=causal, scale=scale, blocks=bs,
            interpret=cfg.interpret,
        )
    if cfg.impl == "pallas_distr":
        if kv_mask is not None:
            return reference_attention(q, k, v, causal=causal, scale=scale, kv_mask=kv_mask)
        from repro.kernels import ops

        return ops.distr_attention(
            q, k, v, cfg.distr, causal=causal, scale=scale, interpret=cfg.interpret,
        )
    raise ValueError(f"unknown attention impl {cfg.impl!r}; choose from {IMPLS}")


def attend_decode(
    q: jnp.ndarray,
    k: jnp.ndarray | None,
    v: jnp.ndarray,
    cfg: AttentionConfig,
    *,
    lengths: jnp.ndarray | None = None,
    k_fused: jnp.ndarray | None = None,
    perm: jnp.ndarray | None = None,
    group_size: int = 1,
    scale: float | None = None,
    block_tables: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Decode-path attention dispatch: one (or a few speculative /
    chunked-prefill) query tokens against a KV cache with per-slot live
    ``lengths``.

    Contiguous caches (``block_tables=None``): k/v are (B, Hkv, S, d)
    slabs; every impl except ``reference`` routes to the split-K
    flash-decoding Pallas op (``kernels.ops.decode_attention``) — per-token
    KV traffic then scales with the live length, not S.

    Paged caches (``block_tables`` (B, max_blocks) int32): k/v are shared
    (P, Hkv, block_size, d) pools and the KV stream goes through the
    scalar-prefetched block table (``kernels.ops.paged_decode_attention``);
    the ``reference`` oracle gathers the table into a contiguous cache
    first (the parity baseline in tests).  Multi-token ``q`` is banded —
    query token ``i`` of the window sees positions
    ``< length − (q_len − 1 − i)`` — which is what chunked prefill rides.

    ``reference`` keeps the pure-JAX masked-softmax oracle.  The fused-K̂
    variant is selected by passing ``k_fused`` + ``perm`` + ``group_size``
    (see serve.kv_cache); ``k`` may be None in that case.  ``scale`` always
    refers to the full head dim (default 1/√d from V) on both paths.
    """
    if cfg.impl not in IMPLS:
        raise ValueError(
            f"unknown attention impl {cfg.impl!r}; choose from {IMPLS}"
        )
    scale = float(scale) if scale is not None else 1.0 / (v.shape[-1] ** 0.5)
    if block_tables is not None:
        return _attend_decode_paged(
            q, k, v, cfg, lengths=lengths, k_fused=k_fused, perm=perm,
            group_size=group_size, scale=scale, block_tables=block_tables,
        )
    if cfg.impl == "reference":
        from repro.core import grouping

        nk = (k_fused if k_fused is not None else k).shape[2]
        kv_mask = (
            jnp.arange(nk)[None, :] < lengths[:, None]
            if lengths is not None
            else None
        )
        if k_fused is not None:
            q_s = grouping.sample_q_heads(q, perm, group_size)
            return reference_attention(
                q_s, k_fused.astype(q_s.dtype), v.astype(q_s.dtype),
                causal=False, scale=scale, kv_mask=kv_mask,
            )
        return reference_attention(
            q, k.astype(q.dtype), v.astype(q.dtype),
            causal=False, scale=scale, kv_mask=kv_mask,
        )
    from repro.kernels import ops  # deferred: kernels are optional at import

    return ops.decode_attention(
        q, k, v, lengths=lengths, k_fused=k_fused, perm=perm,
        group_size=group_size, scale=scale, block_k=cfg.block_k_decode,
        interpret=cfg.interpret,
    )


def _gather_paged(pool: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """(P, Hkv, bs, d) pool + (B, max_blocks) table → (B, Hkv, max_blocks·bs,
    d) contiguous per-request cache (the reference/oracle materialisation the
    kernel path exists to avoid)."""
    gathered = jnp.take(pool, block_tables, axis=0)  # (B, mb, Hkv, bs, d)
    b, mb, hkv, bs, d = gathered.shape
    return gathered.transpose(0, 2, 1, 3, 4).reshape(b, hkv, mb * bs, d)


def _attend_decode_paged(q, k, v, cfg, *, lengths, k_fused, perm, group_size,
                         scale, block_tables):
    if cfg.impl == "reference":
        from repro.core import grouping

        bs = v.shape[2]
        capacity = block_tables.shape[1] * bs
        # Like the kernel op, lengths are NOT clamped to capacity: a padded
        # window overhanging it must not shift live rows' causal bands.
        lengths = (
            jnp.asarray(lengths, jnp.int32)
            if lengths is not None
            else jnp.full((q.shape[0],), capacity, jnp.int32)
        )
        q_len = q.shape[2]
        nk = capacity
        col = jnp.arange(nk)[None, None, :]  # (1, 1, Nk)
        row = jnp.arange(q_len)[None, :, None]  # (1, q_len, 1)
        # Banded live window per query row (degenerate for q_len = 1).
        band = col < (lengths[:, None, None] - (q_len - 1 - row))
        v_c = _gather_paged(v, block_tables).astype(q.dtype)
        if k_fused is not None:
            q_r = grouping.sample_q_heads(q, perm, group_size)
            k_c = _gather_paged(k_fused, block_tables).astype(q.dtype)
        else:
            q_r = q
            k_c = _gather_paged(k, block_tables).astype(q.dtype)
        outs = [
            reference_attention(
                q_r[:, :, i : i + 1], k_c, v_c, causal=False, scale=scale,
                kv_mask=band[:, i],
            )
            for i in range(q_len)
        ]
        return jnp.concatenate(outs, axis=2) if q_len > 1 else outs[0]
    from repro.kernels import ops  # deferred: kernels are optional at import

    return ops.paged_decode_attention(
        q, k, v, block_tables=block_tables, lengths=lengths,
        k_fused_pool=k_fused, perm=perm, group_size=group_size, scale=scale,
        interpret=cfg.interpret,
    )
