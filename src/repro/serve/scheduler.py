"""Continuous-batching scheduler over the paged KV cache.

The slot engine admits a request only when a whole slot (a ``max_len`` KV
slab) frees up — admission is *slot*-bound.  This scheduler makes admission
*memory*-bound and *budget*-bound instead, deciding every tick:

  * **Token-budget admission.**  Each tick spends at most
    ``token_budget`` tokens of model work: one token per running decode
    lane plus chunked-prefill tokens for the head of the queue.  New work
    is admitted every step, not only when a sequence finishes.

  * **Chunked prefill interleaved with decode.**  Prompts are processed in
    ``prefill_chunk``-token windows that ride the *paged decode kernel*
    (banded multi-token windows — serve_step.make_paged_step), so a long
    prompt never stalls running decodes for its full length: each tick runs
    some prefill chunks AND the batched decode tick.

  * **FCFS with preempt-on-pool-exhaustion.**  Requests start in arrival
    order.  When the pool can't grow a *running* request for its next
    decode token, the latest-arrived block holder is preempted — its whole
    KV is evicted to host (serve.paged.evict_to_host), its blocks freed —
    and it resumes bit-identically later (the KV is copied back, not
    recomputed).  Admission and restores never preempt: they wait for
    genuinely free blocks (two restores evicting each other would thrash
    without a token of progress), so the oldest request always advances
    and nothing starves (the pool must hold ≥ one full-length request).

  * **Request lifecycle control** (serve.lifecycle).  Every request ends in
    exactly one terminal status: per-request deadlines (TTFT and
    end-to-end) are checked each tick against the injectable clock
    (``expired``); a bounded waiting queue sheds the *newest* arrival when
    full (``rejected``); ``cancel`` frees a request's blocks immediately
    (``cancelled``); numeric-health and fault failures quarantine exactly
    the offending request (``failed``) — the batch keeps running.

  * **Mesh one-tick admission.**  A mesh-capable paged engine
    (``PagedServeEngine(mesh=)``) exposes ``prefill_mesh_run`` +
    ``mesh_prefill_ready``: a long prompt's whole prefill runs as ONE
    exact ring sequence-parallel forward across the engine's mesh and its
    K/V lands in the (single-device) block pool in the same tick —
    replacing ceil(n/chunk) chunked ticks at no accuracy cost.  Short
    prompts keep chunked prefill (nothing to amortise).

  * **Graceful degradation** (serve.degrade).  An optional hysteresis
    controller watches queue depth (and optionally rolling p50 TTFT) and,
    under sustained overload, switches *new* prompts from exact chunked
    prefill onto one whole-prompt DistrAttention forward
    (``engine.prefill_full_run``) at a per-level grouping fraction — TTFT
    collapses to a single tick at a per-request-recorded accuracy cost —
    then dials back to exact within ``down_after × max_level`` ticks of the
    pressure draining.

  * **Fault containment** (serve.faults).  Engine primitives may raise
    :class:`~repro.serve.faults.InjectedFault` (or its real-world
    equivalents): a failing model step is retried ``step_max_retries``
    times before the culprit alone is failed; a failing ``restore`` backs
    off exponentially (``restore_backoff_ticks`` doubling) for
    ``restore_max_retries`` attempts.  A *global-stall* watchdog fails the
    queue head if nothing in the scheduler progressed for
    ``watchdog_ticks`` consecutive ticks with work present — per-entry
    watchdogs would shoot legitimately queued requests under overload.

  * **Per-request metrics.**  TTFT (submit → first sampled token) and TPOT
    (mean inter-token time after the first) from an injectable clock —
    the serving benchmark's P50/P99 comes from here — plus the terminal
    status and degradation level per request, and scheduler-level
    ``counters()`` (shed / expired / cancelled / failed / retries /
    degraded prefills).

The scheduler is pure policy: it talks to the engine through a small
primitive surface (``lane_*``, ``alloc``, ``prefill_chunk_run``,
``prefill_full_run``, ``decode_tick``, ``evict``/``restore``/``release``)
so the decision logic is unit-testable without a model (tests/test_paged.py
and tests/test_chaos.py fake the engine).
"""
from __future__ import annotations

from collections import Counter, deque
from dataclasses import dataclass, field

import numpy as np

from repro.obs.clock import resolve_clock
from repro.obs.trace import get_recorder
from repro.serve.degrade import DegradationController, DegradeConfig
from repro.serve.faults import NULL_INJECTOR, InjectedFault
from repro.serve import lifecycle


@dataclass
class SchedulerConfig:
    max_batch: int = 8  # concurrent decode lanes
    prefill_chunk: int = 32  # chunked-prefill window (one jit bucket)
    # Model tokens processed per tick (decode lanes + prefill chunks);
    # 0 → max_batch + 2·prefill_chunk (one decode tick + two chunks).
    token_budget: int = 0
    # Bounded waiting queue: submissions past this depth are shed
    # (rejected) instead of queued — reject-newest keeps every accepted
    # request's latency bounded.  None → unbounded (the historical
    # behaviour).
    max_waiting: int | None = None
    # Global-stall watchdog: ticks with work present but zero progress
    # anywhere (no chunk, token, restore, admission, or finish) before the
    # queue head is failed.  Must exceed the restore backoff horizon
    # (sum of restore_backoff_ticks · 2^k) or the watchdog would fire
    # mid-backoff.
    watchdog_ticks: int = 16
    # Bounded retry-with-backoff for a faulting ``restore`` (raise — a
    # False return is a capacity wait, not a fault, and costs no retry).
    restore_max_retries: int = 4
    restore_backoff_ticks: int = 1  # doubles per attempt
    # Bounded retry for a faulting model step (prefill chunk / full
    # prefill / decode tick raising InjectedFault).
    step_max_retries: int = 2

    def budget(self) -> int:
        return self.token_budget or (self.max_batch + 2 * self.prefill_chunk)


@dataclass
class RequestMetrics:
    t_submit: float = 0.0
    t_first_token: float | None = None
    t_done: float | None = None
    n_preemptions: int = 0

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    def tpot(self, n_generated: int) -> float | None:
        if self.t_done is None or self.t_first_token is None:
            return None
        if n_generated <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n_generated - 1)


@dataclass
class Entry:
    """Scheduler-side state for one request (engine's Request rides along)."""
    req: object  # serve.engine.Request
    prompt_done: int = 0  # prompt tokens prefilled so far
    length: int = 0  # live KV tokens in the pool
    next_token: int | None = None  # sampled, not yet fed to decode
    lane: int | None = None
    evicted: bool = False
    restore_tries: int = 0  # consecutive *faulting* restores (not waits)
    restore_next_tick: int = 0  # backoff: no restore attempt before this
    step_tries: int = 0  # consecutive faulting model steps
    metrics: RequestMetrics = field(default_factory=RequestMetrics)

    @property
    def uid(self) -> int:
        return self.req.uid


class Scheduler:
    """FCFS continuous batching with chunked prefill and preemption."""

    def __init__(self, cfg: SchedulerConfig, *, clock=None,
                 degrade: DegradeConfig | DegradationController | None = None,
                 faults=NULL_INJECTOR, trace=None):
        self.cfg = cfg
        self.clock = resolve_clock(clock)
        if isinstance(degrade, DegradeConfig):
            degrade = DegradationController(degrade)
        self.degrade = degrade
        self.faults = faults
        self.trace = trace if trace is not None else get_recorder()
        self._tns = self.trace.ns()  # async-span id namespace (obs.trace)
        self.waiting: deque[Entry] = deque()
        self.running: dict[int, Entry] = {}  # lane → entry
        self.done: list[Entry] = []
        self.counters: Counter = Counter()
        self._tick = 0
        # The slow_step fault (and nothing else) advances this: deadline
        # checks see submit-relative time self.clock() + offset, so a
        # straggling step expires requests without wall-clock sleeps.
        self._clock_offset = 0.0
        self._stall_ticks = 0
        self._level = 0  # degradation level chosen this tick
        self._last_level = 0  # last level a degrade_level instant recorded

    def _now(self) -> float:
        return self.clock() + self._clock_offset

    # -- queue ----------------------------------------------------------

    def submit(self, req) -> Entry | None:
        """Queue a request — or shed it (status ``rejected``, returns None)
        when the bounded waiting queue is full.  Reject-newest: accepted
        requests keep their FCFS position and latency bound; the caller
        learns the verdict immediately from ``req.status``."""
        e = Entry(req=req)
        e.metrics.t_submit = self._now()
        self.trace.begin("request", f"{self._tns}:{e.uid}", uid=e.uid,
                         prompt_len=len(req.prompt),
                         max_new=req.max_new_tokens)
        if (self.cfg.max_waiting is not None
                and len(self.waiting) >= self.cfg.max_waiting):
            self.counters["shed"] += 1
            self.trace.instant("shed", uid=e.uid)
            e.metrics.t_done = e.metrics.t_submit
            req.status = lifecycle.REJECTED
            self.done.append(e)
            self.trace.end("request", f"{self._tns}:{e.uid}",
                           **self._metric_row(e))
            return None
        req.status = lifecycle.QUEUED
        self.waiting.append(e)
        return e

    def cancel(self, uid: int, engine) -> bool:
        """Terminate ``uid`` now, wherever it is (waiting, mid-prefill,
        running, or evicted): its blocks / lane / host copy are freed this
        call, not at the next tick.  Returns False for unknown or already-
        terminal uids."""
        for e in list(self.waiting):
            if e.uid == uid:
                self.waiting.remove(e)
                self._finalize(e, engine, lifecycle.CANCELLED)
                self.counters["cancelled"] += 1
                return True
        for e in list(self.running.values()):
            if e.uid == uid:
                self._finalize(e, engine, lifecycle.CANCELLED)
                self.counters["cancelled"] += 1
                return True
        return False

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _metric_row(self, e: Entry) -> dict:
        """The per-request metrics row.  ONE builder feeds both metrics()
        and the trace's async end-event args, so the exported trace is
        bit-consistent with metrics() by construction."""
        return {
            "uid": e.uid,
            "ttft_s": e.metrics.ttft,
            "tpot_s": e.metrics.tpot(len(e.req.generated)),
            "n_generated": len(e.req.generated),
            "n_preemptions": e.metrics.n_preemptions,
            "status": getattr(e.req, "status", lifecycle.DONE),
            "degrade_group": getattr(e.req, "degrade_group", 1),
        }

    def metrics(self) -> list[dict]:
        return [self._metric_row(e) for e in self.done]

    # -- termination ----------------------------------------------------

    def _finalize(self, e: Entry, engine, status: str) -> None:
        """Move an entry to its terminal status, freeing whatever it holds
        (lane, pool blocks, host copy — ``release`` covers all three)."""
        if e.lane is not None:
            self.running.pop(e.lane, None)
            e.lane = None
        if e.evicted or engine.holds_blocks(e):
            engine.release(e)
            e.evicted = False
        e.req.status = status
        e.metrics.t_done = self._now()
        self.done.append(e)
        self.trace.end("request", f"{self._tns}:{e.uid}",
                       **self._metric_row(e))

    def _fail(self, e: Entry, engine, kind: str, finished: list) -> None:
        self._finalize(e, engine, lifecycle.FAILED)
        self.counters[kind] += 1
        finished.append(e.req)

    def _expire_pass(self, engine, finished: list) -> bool:
        """Deadline sweep: TTFT deadlines apply until the first token
        (entries still waiting / mid-prefill); end-to-end deadlines apply
        for the whole request.  Running entries always hold a first token
        (lanes are only assigned after it), so only e2e applies there."""
        now = self._now()
        progressed = False
        for e in list(self.waiting):
            r = e.req
            d_ttft = getattr(r, "deadline_ttft", None)
            d_e2e = getattr(r, "deadline_e2e", None)
            waited = now - e.metrics.t_submit
            if (d_ttft is not None and e.metrics.t_first_token is None
                    and waited > d_ttft) or (d_e2e is not None
                                             and waited > d_e2e):
                self.waiting.remove(e)
                self._finalize(e, engine, lifecycle.EXPIRED)
                self.counters["expired"] += 1
                finished.append(r)
                progressed = True
        for e in list(self.running.values()):
            d_e2e = getattr(e.req, "deadline_e2e", None)
            if d_e2e is not None and now - e.metrics.t_submit > d_e2e:
                self._finalize(e, engine, lifecycle.EXPIRED)
                self.counters["expired"] += 1
                finished.append(e.req)
                progressed = True
        return progressed

    def _ttft_p50(self) -> float | None:
        """Rolling p50 TTFT over the last 32 finished requests (degrade
        controller signal; None until one finishes)."""
        vals = [e.metrics.ttft for e in self.done[-32:]
                if e.metrics.ttft is not None]
        if not vals:
            return None
        return float(np.median(vals))

    def counters_snapshot(self) -> dict:
        """Frozen to ``lifecycle.COUNTER_KEYS`` (zero-filled): the schema
        the cluster router's health model reads — see lifecycle.py."""
        return lifecycle.counters_view(self.counters)

    # -- preemption -----------------------------------------------------

    def _requeue(self, victim: Entry) -> None:
        """Put a preempted entry back into the waiting queue at its ARRIVAL
        position (by uid).  The queue is always uid-sorted — new arrivals
        append in uid order and re-insertions bisect — so a just-evicted
        runner can never jump ahead of an older evicted request already
        waiting for its restore."""
        idx = 0
        for e in self.waiting:
            if e.uid > victim.uid:
                break
            idx += 1
        self.waiting.insert(idx, victim)

    def _preempt_newest_holder(self, engine, grower: Entry) -> bool:
        """Evict the latest-arrived request holding pool blocks (vLLM's
        LIFO victim: the oldest keeps its memory, guaranteeing head-of-line
        progress) — *including* ``grower`` itself: when the growing request
        is the newest holder, LIFO demands it self-preempts rather than
        stealing an older request's memory.  Candidates are the running
        set plus partially-prefilled waiters (they hold blocks too).
        Returns True when an eviction freed memory the grower may retry
        with; False when the grower itself was evicted (stop growing it)
        or nothing holds blocks."""
        cands = list(self.running.values()) + [
            e for e in self.waiting
            if not e.evicted and engine.holds_blocks(e)
        ]
        if not cands:
            return False
        victim = max(cands, key=lambda e: e.uid)
        engine.evict(victim)
        victim.evicted = True
        victim.req.status = lifecycle.PREEMPTED
        victim.metrics.n_preemptions += 1
        self.trace.instant("preempt", uid=victim.uid)
        if victim.lane is not None:
            del self.running[victim.lane]
            victim.lane = None
            self._requeue(victim)
        # else: a partially-prefilled waiter — already queued in uid order.
        return victim is not grower

    def _alloc_or_preempt(self, engine, entry: Entry, n_tokens: int) -> bool:
        """Cover ``n_tokens`` positions for a RUNNING ``entry``, preempting
        newest block holders until it fits.  Only decode growth preempts:
        admission and restores wait for genuinely free blocks instead —
        evicting a runner to admit (or re-admit) another would let two
        restores thrash evicting each other within one tick, with no token
        of progress in between.  Returns False when the entry itself got
        evicted (it was the newest holder) — the caller must skip it."""
        while not engine.alloc(entry, n_tokens):
            if not self._preempt_newest_holder(engine, grower=entry):
                return False
        return True

    # -- prompt completion ----------------------------------------------

    def _finish_prompt(self, engine, head: Entry, logits_row,
                       finished: list) -> None:
        """Prompt fully prefilled: health-check the last-position logits,
        sample the first token, and either finish (max_new_tokens=1 / eos)
        or move to a decode lane."""
        row = np.asarray(logits_row, np.float32)
        if not np.isfinite(row).all():
            # Numeric quarantine: a non-finite distribution poisons only
            # this request — its blocks free now, the batch keeps running.
            self._fail(head, engine, "failed_numeric", finished)
            return
        tok = engine.sample_one(logits_row)
        head.req.generated.append(tok)
        head.next_token = tok
        head.metrics.t_first_token = self._now()
        self.trace.instant("first_token", uid=head.uid)
        # The first token may already satisfy the stop conditions
        # (max_new_tokens=1 / eos): finish without a decode tick —
        # the slot engine's contract, and one saved decode.
        if (len(head.req.generated) >= head.req.max_new_tokens
                or (head.req.eos_id is not None
                    and tok == head.req.eos_id)):
            head.req.done = True
            self._finalize(head, engine, lifecycle.DONE)
            finished.append(head.req)
            return
        head.req.status = lifecycle.RUNNING
        head.lane = engine.free_lane()
        self.running[head.lane] = head

    def _step_fault(self, engine, e: Entry, finished: list) -> bool:
        """Bounded retry for a faulting model step.  Returns True when the
        entry was failed (budget exhausted), False when it should retry."""
        e.step_tries += 1
        self.counters["step_retries"] += 1
        if e.step_tries > self.cfg.step_max_retries:
            self._fail(e, engine, "failed_fault", finished)
            return True
        return False

    # -- the tick -------------------------------------------------------

    def tick(self, engine) -> list:
        """One scheduling step.  Returns newly *terminal* Requests — done,
        expired, cancelled-by-deadline, or failed this tick (rejected and
        explicitly cancelled requests terminate inside submit()/cancel())."""
        self._tick += 1
        finished: list = []
        progressed = False

        # A straggling step: the injected delay ages every in-flight
        # deadline before the sweep below.
        spec = self.faults.fires("slow_step")
        if spec is not None:
            self._clock_offset += spec.delay

        progressed |= self._expire_pass(engine, finished)

        if self.degrade is not None:
            self._level = self.degrade.observe(
                len(self.waiting), self._ttft_p50()
            )
            if self._level != self._last_level:
                self.trace.instant("degrade_level", level=self._level)
                self._last_level = self._level

        budget = self.cfg.budget()
        budget -= len(self.running)  # decode phase reserved first

        # ---- admission / chunked prefill (FCFS head of queue) ----------
        # The head is POPPED before any allocation: preemption pushes
        # victims onto the queue front mid-allocation, so indexing the
        # queue while holding the head would pop the wrong entry.  Any
        # path that leaves the head unfinished puts it back in front
        # (it is the oldest entry, so FCFS order is preserved).
        while (budget > 0 and self.waiting
               and len(self.running) < self.cfg.max_batch):
            head = self.waiting.popleft()
            if head.evicted:
                if head.restore_next_tick > self._tick:
                    # Backing off after a faulting restore: hold the FCFS
                    # head (younger entries would jump it) — decode lanes
                    # keep draining meanwhile.
                    self.waiting.appendleft(head)
                    break
                # Whole-request restore: needs its full block count back,
                # from genuinely FREE blocks (no preemption — see
                # _alloc_or_preempt).  Until then the head waits; running
                # lanes keep finishing and freeing.
                try:
                    restored = engine.restore(head)
                except InjectedFault:
                    # A raise is a FAULT (host↔device copy failure) and
                    # spends retry budget; a False return is a capacity
                    # wait and never does.
                    head.restore_tries += 1
                    self.counters["restore_retries"] += 1
                    if head.restore_tries > self.cfg.restore_max_retries:
                        self._fail(head, engine, "failed_fault", finished)
                        progressed = True
                        continue
                    head.restore_next_tick = self._tick + (
                        self.cfg.restore_backoff_ticks
                        << (head.restore_tries - 1)
                    )
                    self.waiting.appendleft(head)
                    break
                if not restored:
                    self.waiting.appendleft(head)
                    break
                head.evicted = False
                head.restore_tries = 0
                progressed = True
                self.trace.instant("restore", uid=head.uid)
                if head.prompt_done == len(head.req.prompt):
                    head.req.status = lifecycle.RUNNING
                    head.lane = engine.free_lane()
                    self.running[head.lane] = head
                else:
                    # Preempted mid-prefill: back in front — the next
                    # iteration resumes its chunked prefill.
                    head.req.status = lifecycle.PREFILL
                    self.waiting.appendleft(head)
                continue
            if head.prompt_done == 0 and not engine.can_admit(head):
                # Admission watermark (vLLM-style): don't start a prompt
                # unless its whole prefill + one decode-growth block fits
                # in FREE memory now — admitting on a chunk-by-chunk
                # basis over-commits the pool and forces later decode-
                # growth preemptions (evict + restore round-trips that
                # cost far more than the wait).
                self.waiting.appendleft(head)
                break
            if (head.prompt_done == 0
                    and hasattr(engine, "prefill_mesh_run")
                    and engine.mesh_prefill_ready(len(head.req.prompt))):
                # Mesh admission: one whole-prompt EXACT prefill across the
                # engine's context-parallel ring replaces ceil(n/chunk)
                # chunks — the long prompt's TTFT collapses to a single
                # tick with no accuracy cost (the degraded branch below
                # stays the overload valve for non-mesh engines).
                n = len(head.req.prompt)
                if not engine.alloc(head, n):
                    self.waiting.appendleft(head)
                    break
                head.req.status = lifecycle.PREFILL
                try:
                    row = engine.prefill_mesh_run(head)
                except InjectedFault:
                    # mesh_prefill / stuck_step raise BEFORE any pool
                    # write, so the retry re-runs against clean blocks.
                    if self._step_fault(engine, head, finished):
                        progressed = True
                    else:
                        self.waiting.appendleft(head)
                    break
                head.step_tries = 0
                head.prompt_done = n
                head.length = n
                self.counters["mesh_prefills"] += 1
                self.trace.instant("mesh_prefill", uid=head.uid, n=n)
                budget -= n
                progressed = True
                self._finish_prompt(engine, head, row, finished)
                continue
            if (self._level > 0 and head.prompt_done == 0
                    and hasattr(engine, "prefill_full_run")):
                # Degraded admission: one whole-prompt DistrAttention
                # forward instead of ceil(n/chunk) exact chunks — TTFT
                # under overload collapses to a single tick, the accuracy
                # cost is recorded on the request (degrade_group).
                n = len(head.req.prompt)
                if not engine.alloc(head, n):
                    self.waiting.appendleft(head)
                    break
                group = self.degrade.group_size
                head.req.status = lifecycle.PREFILL
                try:
                    row = engine.prefill_full_run(head, group)
                except InjectedFault:
                    if self._step_fault(engine, head, finished):
                        progressed = True
                    else:
                        self.waiting.appendleft(head)
                    break
                head.step_tries = 0
                head.prompt_done = n
                head.length = n
                head.req.degrade_group = group
                self.counters["degraded_prefills"] += 1
                self.trace.instant("degraded_prefill", uid=head.uid,
                                   group=group)
                budget -= n
                progressed = True
                self._finish_prompt(engine, head, row, finished)
                continue
            chunk = min(
                self.cfg.prefill_chunk,
                len(head.req.prompt) - head.prompt_done,
                budget,
            )
            if chunk <= 0:
                self.waiting.appendleft(head)
                break
            if not engine.alloc(head, head.prompt_done + chunk):
                # Admission waits for free blocks rather than preempting.
                self.waiting.appendleft(head)
                break
            head.req.status = lifecycle.PREFILL
            try:
                logits_last = engine.prefill_chunk_run(head, chunk)
            except InjectedFault:
                if self._step_fault(engine, head, finished):
                    progressed = True
                else:
                    self.waiting.appendleft(head)
                break
            head.step_tries = 0
            head.prompt_done += chunk
            head.length = head.prompt_done
            budget -= chunk
            progressed = True
            if head.prompt_done == len(head.req.prompt):
                # Prompt complete: the final chunk's last live row is the
                # exact last-position distribution → first token now.
                self._finish_prompt(engine, head, logits_last, finished)
            else:
                # Partial prefill: back to the front; the loop (or the
                # next tick) continues this prompt's chunks first.
                self.waiting.appendleft(head)

        # ---- decode tick over all running lanes ------------------------
        if self.running:
            # Decode writes one token at position `length` per lane: make
            # sure every lane's table covers it (preempting if needed).
            for lane in sorted(self.running):
                e = self.running.get(lane)
                if e is None:
                    continue
                if not self._alloc_or_preempt(engine, e, e.length + 1):
                    if e.evicted:
                        # The grower was the newest holder and self-
                        # preempted (LIFO): it decodes after a restore.
                        continue
                    # Oldest request alone can't grow: capacity bug — the
                    # constructor guarantees one full request fits.
                    raise RuntimeError(
                        f"request {e.uid} cannot grow to {e.length + 1} "
                        "tokens with an empty pool"
                    )
            if self.running:
                try:
                    with self.trace.span("decode", n_lanes=len(self.running)):
                        out = engine.decode_tick(self.running)
                    # Engines return (tokens, ok_mask); legacy fakes
                    # returning bare tokens get an all-healthy mask.
                    if isinstance(out, tuple):
                        toks, ok = out
                    else:
                        toks, ok = out, np.ones((len(out),), bool)
                except InjectedFault as f:
                    # The whole batched step is lost (nothing was written:
                    # engines raise before mutating pools) but only the
                    # culprit spends retry budget; everyone else just
                    # loses one tick, bounded by step_max_retries.
                    culprit = next(
                        (x for x in self.running.values()
                         if x.uid == f.uid), None,
                    )
                    if culprit is not None and self._step_fault(
                            engine, culprit, finished):
                        progressed = True
                else:
                    now = self._now()
                    for lane, e in list(self.running.items()):
                        if not ok[lane]:
                            # Numeric quarantine: only the offending lane
                            # dies; the other lanes' KV and tokens are
                            # untouched (per-row independence).
                            self._fail(e, engine, "failed_numeric",
                                       finished)
                            progressed = True
                            continue
                        e.step_tries = 0
                        t = int(toks[lane])
                        e.req.generated.append(t)
                        e.next_token = t
                        e.length += 1
                        progressed = True
                        limit = (len(e.req.generated)
                                 >= e.req.max_new_tokens)
                        hit_eos = (
                            e.req.eos_id is not None and t == e.req.eos_id
                        )
                        # Window-decoding engines slide past the table
                        # bound (head-block recycling) — only engines
                        # without the ring-write invariant force-finish
                        # at capacity.
                        full = (
                            not getattr(engine, "window_decode", False)
                            and e.length >= engine.capacity_tokens - 1
                        )
                        if limit or hit_eos or full:
                            e.req.done = True
                            self._finalize(e, engine, lifecycle.DONE)
                            finished.append(e.req)

        # ---- global-stall watchdog -------------------------------------
        # Per-entry no-progress timers would shoot legitimately queued
        # requests under overload; the global form only fires when NOTHING
        # moved — a wedged allocator / dead engine — and then fails the
        # FCFS head (the entry the whole queue is stuck behind).  Failing
        # it is itself progress, so the counter resets and termination
        # stays bounded.
        if progressed or not self.has_work():
            self._stall_ticks = 0
        else:
            self._stall_ticks += 1
            if self._stall_ticks >= self.cfg.watchdog_ticks:
                if self.waiting:
                    victim = self.waiting.popleft()
                else:
                    victim = min(self.running.values(), key=lambda x: x.uid)
                self.trace.instant("watchdog", uid=victim.uid)
                self._fail(victim, engine, "watchdog_fails", finished)
                self._stall_ticks = 0
        return finished
