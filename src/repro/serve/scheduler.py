"""Continuous-batching scheduler over the paged KV cache.

The slot engine admits a request only when a whole slot (a ``max_len`` KV
slab) frees up — admission is *slot*-bound.  This scheduler makes admission
*memory*-bound and *budget*-bound instead, deciding every tick:

  * **Token-budget admission.**  Each tick spends at most
    ``token_budget`` tokens of model work: one token per running decode
    lane plus chunked-prefill tokens for the head of the queue.  New work
    is admitted every step, not only when a sequence finishes.

  * **Chunked prefill interleaved with decode.**  Prompts are processed in
    ``prefill_chunk``-token windows that ride the *paged decode kernel*
    (banded multi-token windows — serve_step.make_paged_step), so a long
    prompt never stalls running decodes for its full length: each tick runs
    some prefill chunks AND the batched decode tick.

  * **FCFS with preempt-on-pool-exhaustion.**  Requests start in arrival
    order.  When the pool can't grow a *running* request for its next
    decode token, the latest-arrived block holder is preempted — its whole
    KV is evicted to host (serve.paged.evict_to_host), its blocks freed —
    and it resumes bit-identically later (the KV is copied back, not
    recomputed).  Admission and restores never preempt: they wait for
    genuinely free blocks (two restores evicting each other would thrash
    without a token of progress), so the oldest request always advances
    and nothing starves (the pool must hold ≥ one full-length request).

  * **Per-request metrics.**  TTFT (submit → first sampled token) and TPOT
    (mean inter-token time after the first) from an injectable clock —
    the serving benchmark's P50/P99 comes from here.

The scheduler is pure policy: it talks to the engine through a small
primitive surface (``lane_*``, ``alloc``, ``prefill_chunk_run``,
``decode_tick``, ``evict``/``restore``/``release``) so the decision logic
is unit-testable without a model (tests/test_paged.py fakes the engine).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class SchedulerConfig:
    max_batch: int = 8  # concurrent decode lanes
    prefill_chunk: int = 32  # chunked-prefill window (one jit bucket)
    # Model tokens processed per tick (decode lanes + prefill chunks);
    # 0 → max_batch + 2·prefill_chunk (one decode tick + two chunks).
    token_budget: int = 0

    def budget(self) -> int:
        return self.token_budget or (self.max_batch + 2 * self.prefill_chunk)


@dataclass
class RequestMetrics:
    t_submit: float = 0.0
    t_first_token: float | None = None
    t_done: float | None = None
    n_preemptions: int = 0

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    def tpot(self, n_generated: int) -> float | None:
        if self.t_done is None or self.t_first_token is None:
            return None
        if n_generated <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n_generated - 1)


@dataclass
class Entry:
    """Scheduler-side state for one request (engine's Request rides along)."""
    req: object  # serve.engine.Request
    prompt_done: int = 0  # prompt tokens prefilled so far
    length: int = 0  # live KV tokens in the pool
    next_token: int | None = None  # sampled, not yet fed to decode
    lane: int | None = None
    evicted: bool = False
    metrics: RequestMetrics = field(default_factory=RequestMetrics)

    @property
    def uid(self) -> int:
        return self.req.uid


class Scheduler:
    """FCFS continuous batching with chunked prefill and preemption."""

    def __init__(self, cfg: SchedulerConfig, *, clock=time.perf_counter):
        self.cfg = cfg
        self.clock = clock
        self.waiting: deque[Entry] = deque()
        self.running: dict[int, Entry] = {}  # lane → entry
        self.done: list[Entry] = []

    # -- queue ----------------------------------------------------------

    def submit(self, req) -> Entry:
        e = Entry(req=req)
        e.metrics.t_submit = self.clock()
        self.waiting.append(e)
        return e

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def metrics(self) -> list[dict]:
        out = []
        for e in self.done:
            out.append({
                "uid": e.uid,
                "ttft_s": e.metrics.ttft,
                "tpot_s": e.metrics.tpot(len(e.req.generated)),
                "n_generated": len(e.req.generated),
                "n_preemptions": e.metrics.n_preemptions,
            })
        return out

    # -- preemption -----------------------------------------------------

    def _requeue(self, victim: Entry) -> None:
        """Put a preempted entry back into the waiting queue at its ARRIVAL
        position (by uid).  The queue is always uid-sorted — new arrivals
        append in uid order and re-insertions bisect — so a just-evicted
        runner can never jump ahead of an older evicted request already
        waiting for its restore."""
        idx = 0
        for e in self.waiting:
            if e.uid > victim.uid:
                break
            idx += 1
        self.waiting.insert(idx, victim)

    def _preempt_newest_holder(self, engine, grower: Entry) -> bool:
        """Evict the latest-arrived request holding pool blocks (vLLM's
        LIFO victim: the oldest keeps its memory, guaranteeing head-of-line
        progress) — *including* ``grower`` itself: when the growing request
        is the newest holder, LIFO demands it self-preempts rather than
        stealing an older request's memory.  Candidates are the running
        set plus partially-prefilled waiters (they hold blocks too).
        Returns True when an eviction freed memory the grower may retry
        with; False when the grower itself was evicted (stop growing it)
        or nothing holds blocks."""
        cands = list(self.running.values()) + [
            e for e in self.waiting
            if not e.evicted and engine.holds_blocks(e)
        ]
        if not cands:
            return False
        victim = max(cands, key=lambda e: e.uid)
        engine.evict(victim)
        victim.evicted = True
        victim.metrics.n_preemptions += 1
        if victim.lane is not None:
            del self.running[victim.lane]
            victim.lane = None
            self._requeue(victim)
        # else: a partially-prefilled waiter — already queued in uid order.
        return victim is not grower

    def _alloc_or_preempt(self, engine, entry: Entry, n_tokens: int) -> bool:
        """Cover ``n_tokens`` positions for a RUNNING ``entry``, preempting
        newest block holders until it fits.  Only decode growth preempts:
        admission and restores wait for genuinely free blocks instead —
        evicting a runner to admit (or re-admit) another would let two
        restores thrash evicting each other within one tick, with no token
        of progress in between.  Returns False when the entry itself got
        evicted (it was the newest holder) — the caller must skip it."""
        while not engine.alloc(entry, n_tokens):
            if not self._preempt_newest_holder(engine, grower=entry):
                return False
        return True

    # -- the tick -------------------------------------------------------

    def tick(self, engine) -> list:
        """One scheduling step.  Returns newly finished Requests."""
        budget = self.cfg.budget()
        budget -= len(self.running)  # decode phase reserved first
        tick_finished: list = []

        # ---- admission / chunked prefill (FCFS head of queue) ----------
        # The head is POPPED before any allocation: preemption pushes
        # victims onto the queue front mid-allocation, so indexing the
        # queue while holding the head would pop the wrong entry.  Any
        # path that leaves the head unfinished puts it back in front
        # (it is the oldest entry, so FCFS order is preserved).
        while budget > 0 and self.waiting and len(self.running) < self.cfg.max_batch:
            head = self.waiting.popleft()
            if head.evicted:
                # Whole-request restore: needs its full block count back,
                # from genuinely FREE blocks (no preemption — see
                # _alloc_or_preempt).  Until then the head waits; running
                # lanes keep finishing and freeing.
                if not engine.restore(head):
                    self.waiting.appendleft(head)
                    break
                head.evicted = False
                if head.prompt_done == len(head.req.prompt):
                    head.lane = engine.free_lane()
                    self.running[head.lane] = head
                else:
                    # Preempted mid-prefill: back in front — the next
                    # iteration resumes its chunked prefill.
                    self.waiting.appendleft(head)
                continue
            if head.prompt_done == 0 and not engine.can_admit(head):
                # Admission watermark (vLLM-style): don't start a prompt
                # unless its whole prefill + one decode-growth block fits
                # in FREE memory now — admitting on a chunk-by-chunk
                # basis over-commits the pool and forces later decode-
                # growth preemptions (evict + restore round-trips that
                # cost far more than the wait).
                self.waiting.appendleft(head)
                break
            chunk = min(
                self.cfg.prefill_chunk,
                len(head.req.prompt) - head.prompt_done,
                budget,
            )
            if chunk <= 0:
                self.waiting.appendleft(head)
                break
            if not engine.alloc(head, head.prompt_done + chunk):
                # Admission waits for free blocks rather than preempting.
                self.waiting.appendleft(head)
                break
            logits_last = engine.prefill_chunk_run(head, chunk)
            head.prompt_done += chunk
            head.length = head.prompt_done
            budget -= chunk
            if head.prompt_done == len(head.req.prompt):
                # Prompt complete: the final chunk's last live row is the
                # exact last-position distribution → first token now.
                tok = engine.sample_one(logits_last)
                head.req.generated.append(tok)
                head.next_token = tok
                head.metrics.t_first_token = self.clock()
                # The first token may already satisfy the stop conditions
                # (max_new_tokens=1 / eos): finish without a decode tick —
                # the slot engine's contract, and one saved decode.
                if (
                    len(head.req.generated) >= head.req.max_new_tokens
                    or (head.req.eos_id is not None
                        and tok == head.req.eos_id)
                ):
                    head.req.done = True
                    head.metrics.t_done = self.clock()
                    engine.release(head)
                    self.done.append(head)
                    tick_finished.append(head.req)
                    continue
                head.lane = engine.free_lane()
                self.running[head.lane] = head
            else:
                # Partial prefill: back to the front; the loop (or the
                # next tick) continues this prompt's chunks first.
                self.waiting.appendleft(head)

        # ---- decode tick over all running lanes ------------------------
        finished = tick_finished
        if self.running:
            # Decode writes one token at position `length` per lane: make
            # sure every lane's table covers it (preempting if needed).
            for lane in sorted(self.running):
                e = self.running.get(lane)
                if e is None:
                    continue
                if not self._alloc_or_preempt(engine, e, e.length + 1):
                    if e.evicted:
                        # The grower was the newest holder and self-
                        # preempted (LIFO): it decodes after a restore.
                        continue
                    # Oldest request alone can't grow: capacity bug — the
                    # constructor guarantees one full request fits.
                    raise RuntimeError(
                        f"request {e.uid} cannot grow to {e.length + 1} "
                        "tokens with an empty pool"
                    )
            if self.running:
                toks = engine.decode_tick(self.running)
                now = self.clock()
                for lane, e in list(self.running.items()):
                    t = int(toks[lane])
                    e.req.generated.append(t)
                    e.next_token = t
                    e.length += 1
                    limit = len(e.req.generated) >= e.req.max_new_tokens
                    hit_eos = (
                        e.req.eos_id is not None and t == e.req.eos_id
                    )
                    full = e.length >= engine.capacity_tokens - 1
                    if limit or hit_eos or full:
                        e.req.done = True
                        e.metrics.t_done = now
                        engine.release(e)
                        del self.running[lane]
                        e.lane = None
                        self.done.append(e)
                        finished.append(e.req)
        return finished
