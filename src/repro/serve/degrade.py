"""Graceful-degradation controller: the DistrAttention accuracy↔speed dial
driven by serving pressure.

DistrAttention's core knob — embedding-dimension grouping at fraction
1/G* (PAPER.md §4) — is *tunable per call*, unlike Linformer-style fixed
projections that bake one approximation into the weights.  That makes it
exactly the dial a serving tier needs for graceful degradation: under
sustained overload, dial **prefill** (the compute-bound phase where the
paper's kernel wins) onto progressively coarser grouping fractions; when
pressure drains, dial back to the engine's configured exact path.  The
accuracy cost is attributed per request (``Request.degrade_group`` in
``metrics()``), never silent.

The controller is pure tick-driven policy with hysteresis — no wall clock,
no model state — so it is unit-testable with a counted loop and its
return-to-exact bound is provable: after pressure drops below the low
watermark, level 0 is reached within ``down_after × max_level`` ticks
(asserted in tests/test_chaos.py).

Escalation signal: waiting-queue depth (primary, deterministic) and
optionally the rolling p50 TTFT.  One level step per decision — no jumping
straight to the coarsest grouping on a single bad tick.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class DegradeConfig:
    """Hysteresis policy for the degradation dial.

    group_sizes: G* per escalation level; level 0 is always the engine's
      configured (exact) prefill path, level L ≥ 1 runs DistrAttention
      prefill at ``group_sizes[L-1]``.
    high_watermark / low_watermark: waiting-queue depths.  Pressure =
      depth > high (or rolling p50 TTFT > ttft_p50_high_s, when set);
      drain = depth ≤ low (and TTFT below the threshold).
    up_after / down_after: consecutive pressure (resp. drain) ticks before
      one level step up (resp. down) — the hysteresis band that stops the
      dial from flapping on a bursty queue.
    """

    group_sizes: tuple[int, ...] = (2, 4)
    high_watermark: int = 6
    low_watermark: int = 1
    up_after: int = 2
    down_after: int = 4
    ttft_p50_high_s: float | None = None

    def __post_init__(self):
        if not self.group_sizes or any(g < 2 for g in self.group_sizes):
            raise ValueError(
                "group_sizes must be non-empty with every G* ≥ 2 "
                "(level 0 is implicitly the exact path)"
            )
        if self.low_watermark > self.high_watermark:
            raise ValueError("low_watermark must be ≤ high_watermark")
        if self.up_after < 1 or self.down_after < 1:
            raise ValueError("up_after / down_after must be ≥ 1")

    @property
    def max_level(self) -> int:
        return len(self.group_sizes)

    def group_for(self, level: int) -> int:
        """G* for a level (1 = exact, i.e. no grouping)."""
        if level <= 0:
            return 1
        return self.group_sizes[min(level, self.max_level) - 1]

    def return_bound_ticks(self) -> int:
        """Upper bound on ticks from any level back to exact once pressure
        stays below the low watermark (the reversibility guarantee)."""
        return self.down_after * self.max_level


class DegradationController:
    """Tick-driven hysteresis state machine over :class:`DegradeConfig`."""

    def __init__(self, cfg: DegradeConfig):
        self.cfg = cfg
        self.level = 0
        self._over = 0  # consecutive pressure ticks
        self._under = 0  # consecutive drain ticks
        self.transitions: list[tuple[int, int]] = []  # (tick#, new level)
        self._ticks = 0

    @property
    def group_size(self) -> int:
        return self.cfg.group_for(self.level)

    def observe(self, queue_depth: int, ttft_p50: float | None = None) -> int:
        """One scheduler tick's pressure reading; returns the level to use
        for prefills started this tick."""
        self._ticks += 1
        c = self.cfg
        hot = queue_depth > c.high_watermark
        if c.ttft_p50_high_s is not None and ttft_p50 is not None:
            hot = hot or ttft_p50 > c.ttft_p50_high_s
        cool = queue_depth <= c.low_watermark and not hot
        self._over = self._over + 1 if hot else 0
        self._under = self._under + 1 if cool else 0
        if self._over >= c.up_after and self.level < c.max_level:
            self.level += 1
            self._over = 0
            self.transitions.append((self._ticks, self.level))
        elif self._under >= c.down_after and self.level > 0:
            self.level -= 1
            self._under = 0
            self.transitions.append((self._ticks, self.level))
        return self.level
