"""Continuous-batching serving engine.

Slot-based: the decode cache holds ``max_slots`` sequences; requests are
prefilled one at a time (bucketed prompt padding bounds recompiles) and their
caches inserted into free slots; every ``step()`` advances *all* active slots
by one token in a single jitted decode.  Finished sequences free their slot
immediately — the vLLM-style continuous batching pattern at step granularity.

Long generations: for ring-layout caches (GQA ``length``-tracked) decoding
continues *past* ``max_len`` with sliding-window eviction — the ring write
(``pos mod S``) overwrites the oldest token and the kernels attend over the
live window ``min(length, max_len)``, so a slot serves arbitrarily long
outputs at bounded memory.  Families without the ring invariant (MLA / SSM /
hybrid / enc-dec) still finish before wrap.

Construction also warms the block-size autotuner (``repro.tune``) for every
prefill bucket and the decode split — under ``REPRO_TUNE=measure`` the
timing sweeps run once here, never inside a serving step.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kv_cache
from repro.serve.sampler import sample
from repro.serve.serve_step import make_decode_step, make_prefill
from repro.tune.autotune import warm_engine
from repro.utils.jax_compat import maybe_set_mesh


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_slots: int = 8, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0, mesh=None):
        """``mesh``: optional device mesh.  When it carries the axis named
        by ``cfg.attention.context_axis``, long-prompt prefill (sequence ≥
        ring size × 128) runs ring sequence-parallel attention
        (distributed.ring_attention) — prompt length then scales with ring
        size instead of one device's HBM.  Decode stays single-device: a
        one-token query never fills a ring shard."""
        if cfg.family == "encdec":
            raise NotImplementedError(
                "engine drives decoder-only archs; use serve_step directly "
                "for enc-dec"
            )
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        self.mesh = mesh
        self._uid = itertools.count()
        self._rng = jax.random.PRNGKey(seed)

        # Resolve every block-size key this engine's steps will hit (prefill
        # buckets + decode split) before the first request arrives; under
        # REPRO_TUNE=measure the sweeps run and persist here, once.  The
        # mesh context keys long-prompt buckets per ring shard.
        with maybe_set_mesh(mesh):
            self.tuned_blocks = warm_engine(cfg, max_len)

        self.cache = kv_cache.init_cache(cfg, max_slots, max_len)
        self.pos = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots, 1), jnp.int32)
        self.active: dict[int, Request] = {}  # slot -> request
        self.pending: list[Request] = []
        self.finished: list[Request] = []

        self._decode = jax.jit(make_decode_step(cfg))
        self._prefills: dict[int, object] = {}

    # ------------------------------------------------------------------
    def add_request(self, prompt: list[int], *, max_new_tokens: int = 32,
                    eos_id: int | None = None) -> int:
        req = Request(next(self._uid), list(prompt), max_new_tokens, eos_id)
        self.pending.append(req)
        return req.uid

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            self._prefills[bucket] = jax.jit(
                make_prefill(self.cfg, self.max_len)
            )
        return self._prefills[bucket]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.pending:
                break
            req = self.pending.pop(0)
            n = len(req.prompt)
            bucket = min(_bucket(n), self.max_len)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt
            # Long-prompt prefill rides the context-parallel ring when the
            # engine has a mesh (trace-time dispatch in core.api.attend).
            with maybe_set_mesh(self.mesh):
                logits, cache1 = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(toks)
                )
            # NOTE: right-padding shifts the "last" logit for padded prompts;
            # re-read the true last-position logits from position n-1 by
            # decoding from position n with the prompt's last token instead.
            self.cache = {
                key: self._insert_slot(self.cache[key], cache1[key], slot,
                                       self._slot_axis(key))
                for key in self.cache
            }
            if "length" in self.cache:
                # Bucketed prefill right-pads the prompt; only the true n
                # tokens are live — every decode step's KV walk (and the
                # kernel grid) is bounded by this, not by max_len.
                self.cache["length"] = self.cache["length"].at[slot].set(n)
            self.pos = self.pos.at[slot].set(n - 1)
            self.tokens = self.tokens.at[slot, 0].set(req.prompt[-1])
            self.active[slot] = req

    @staticmethod
    def _slot_axis(key: str) -> int:
        """Batch/slot axis per cache layout (serve.kv_cache docstring)."""
        if key in ("cross_len", "length"):
            return 0
        if key.startswith("groups_"):
            return 2  # (G, per_group, B, ...)
        return 1  # (L_or_G, B, ...)

    @staticmethod
    def _insert_slot(full: jnp.ndarray, one: jnp.ndarray, slot: int,
                     axis: int) -> jnp.ndarray:
        # pad seq dims that differ (prefill bucket < max_len)
        for ax2 in range(full.ndim):
            if ax2 != axis and one.shape[ax2] != full.shape[ax2]:
                widths = [(0, 0)] * full.ndim
                widths[ax2] = (0, full.shape[ax2] - one.shape[ax2])
                one = jnp.pad(one, widths)
        idx = [slice(None)] * full.ndim
        idx[axis] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one.astype(full.dtype))

    def step(self) -> list[Request]:
        """Admit pending, decode one token for all active slots; returns
        newly finished requests."""
        self._admit()
        if not self.active:
            return []
        # advance positions: decode writes at pos+1 (pos = last filled index).
        # Idle slots stay pinned at 0 so their garbage decode keeps walking
        # one KV block instead of growing back toward max_len (serve_step
        # stores length = max(length, pos+1)).
        occupied = np.zeros((self.max_slots,), bool)
        for s in self.active:
            occupied[s] = True
        step_pos = jnp.where(jnp.asarray(occupied), self.pos + 1, 0)
        self._rng, sub = jax.random.split(self._rng)
        logits, self.cache = self._decode(
            self.params, self.tokens, self.cache, step_pos
        )
        next_tokens = sample(logits, rng=sub, temperature=self.temperature)
        self.pos = step_pos
        self.tokens = next_tokens[:, None]

        done_now = []
        toks = np.asarray(next_tokens)
        # Ring caches (GQA, length-tracked) slide past max_len: the ring
        # write evicts the oldest token and the kernels see the live window
        # min(length, max_len).  Other cache layouts (MLA/SSM/hybrid/encdec)
        # have no ring invariant, so their sequences finish before wrap.
        sliding = "length" in self.cache
        for slot, req in list(self.active.items()):
            t = int(toks[slot])
            req.generated.append(t)
            limit = len(req.generated) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and t == req.eos_id
            full = (not sliding) and int(self.pos[slot]) >= self.max_len - 2
            if limit or hit_eos or full:
                req.done = True
                done_now.append(req)
                self.finished.append(req)
                del self.active[slot]
                # Reset the freed slot so its (garbage) decode walks one KV
                # block, not the dead sequence's full live window.
                self.pos = self.pos.at[slot].set(0)
                if "length" in self.cache:
                    self.cache["length"] = self.cache["length"].at[slot].set(0)
        return done_now

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.active and not self.pending:
                break
        return self.finished
