"""Serving engines: the slot engine (contiguous ring caches) and the paged
engine (block-pool KV + continuous-batching scheduler).

``ServeEngine`` is slot-based: the decode cache holds ``max_slots``
sequences with a contiguous ``max_len`` slab each — admission is
slot-bound.  ``PagedServeEngine`` replaces the slabs with a shared block
pool and delegates every step to ``serve.scheduler`` (token-budget
admission, chunked prefill, preempt-to-host) — admission is memory-bound,
so mixed-length workloads pack more concurrent decode lanes into the same
HBM (DESIGN.md §Paged serving, benchmarks/serving.py).

Slot engine: requests are
prefilled one at a time (bucketed prompt padding bounds recompiles) and their
caches inserted into free slots; every ``step()`` advances *all* active slots
by one token in a single jitted decode.  Finished sequences free their slot
immediately — the vLLM-style continuous batching pattern at step granularity.

Long generations: for ring-layout caches (GQA ``length``-tracked) decoding
continues *past* ``max_len`` with sliding-window eviction — the ring write
(``pos mod S``) overwrites the oldest token and the kernels attend over the
live window ``min(length, max_len)``, so a slot serves arbitrarily long
outputs at bounded memory.  Families without the ring invariant (MLA / SSM /
hybrid / enc-dec) still finish before wrap.

Construction also warms the block-size autotuner (``repro.tune``) for every
prefill bucket and the decode split — under ``REPRO_TUNE=measure`` the
timing sweeps run once here, never inside a serving step.
"""
from __future__ import annotations

import itertools
from collections import Counter
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.clock import resolve_clock
from repro.obs.trace import get_recorder
from repro.serve import kv_cache, lifecycle
from repro.serve.degrade import DegradationController, DegradeConfig
from repro.serve.faults import NULL_INJECTOR
from repro.serve.lifecycle import IncompleteRun
from repro.serve.sampler import sample
from repro.serve.serve_step import make_decode_step, make_prefill
from repro.tune.autotune import warm_engine
from repro.utils.jax_compat import maybe_set_mesh


def _validate_request(prompt, limit: int, max_new_tokens: int,
                      what: str = "max_len") -> None:
    """Shared submission-time validation for both engines: a prompt longer
    than the cache would otherwise shape-error (or silently corrupt KV)
    deep inside admission, and a non-positive ``max_new_tokens`` would
    decode forever (the ≥-limit stop can never trip)."""
    if len(prompt) > limit:
        raise ValueError(
            f"prompt length {len(prompt)} exceeds the engine's "
            f"{what}={limit}; truncate the prompt or build the engine "
            "with a larger cache"
        )
    if not prompt:
        raise ValueError("prompt must hold at least one token")
    if max_new_tokens <= 0:
        raise ValueError(
            f"max_new_tokens must be ≥ 1, got {max_new_tokens}"
        )


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False  # completed *successfully* (status == "done")
    # Lifecycle status (serve.lifecycle): every request terminates in
    # exactly one terminal status; non-terminals are observability.
    status: str = lifecycle.QUEUED
    # Deadlines in clock units (seconds for the default wall clock; ticks
    # for an injected tick clock), relative to submission.  None → none.
    deadline_ttft: float | None = None
    deadline_e2e: float | None = None
    # Grouping fraction G* the prefill actually ran at (1 = exact; > 1 =
    # degraded under overload — serve.degrade attributes the accuracy cost).
    degrade_group: int = 1


class ServeEngine:
    def __init__(self, cfg, params, *, max_slots: int = 8, max_len: int = 512,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
                 seed: int = 0, mesh=None, clock=None, max_waiting=None,
                 degrade: DegradeConfig | None = None, faults=None,
                 trace=None):
        """``mesh``: optional device mesh.  When it carries the axis named
        by ``cfg.attention.context_axis``, long-prompt prefill (sequence ≥
        ring size × 128) runs ring sequence-parallel attention
        (distributed.ring_attention) — prompt length then scales with ring
        size instead of one device's HBM.  Decode stays single-device: a
        one-token query never fills a ring shard."""
        if cfg.family == "encdec":
            raise NotImplementedError(
                "engine drives decoder-only archs; use serve_step directly "
                "for enc-dec"
            )
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.mesh = mesh
        self.clock = resolve_clock(clock)
        self.max_waiting = max_waiting
        if isinstance(degrade, DegradeConfig):
            degrade = DegradationController(degrade)
        self.degrade = degrade
        self.faults = faults or NULL_INJECTOR
        self.trace = trace if trace is not None else get_recorder()
        self._tns = self.trace.ns()  # async-span id namespace (obs.trace)
        self._last_degrade_level = 0
        self.counters: Counter = Counter()
        self._clock_offset = 0.0  # advanced only by the slow_step fault
        self._step_tries: dict[int, int] = {}  # uid → faulting-step retries
        self._uid = itertools.count()
        self._rng = jax.random.PRNGKey(seed)

        # Resolve every block-size key this engine's steps will hit (prefill
        # buckets + decode split) before the first request arrives; under
        # REPRO_TUNE=measure the sweeps run and persist here, once.  The
        # mesh context keys long-prompt buckets per ring shard.
        with maybe_set_mesh(mesh):
            self.tuned_blocks = warm_engine(cfg, max_len)

        self.cache = kv_cache.init_cache(cfg, max_slots, max_len)
        self.pos = jnp.zeros((max_slots,), jnp.int32)
        self.tokens = jnp.zeros((max_slots, 1), jnp.int32)
        self.active: dict[int, Request] = {}  # slot -> request
        self.pending: list[Request] = []
        self.finished: list[Request] = []

        self._decode = jax.jit(make_decode_step(cfg))
        self._prefills: dict[int, object] = {}
        # Wall-clock per request (submit / first token) so the serving
        # benchmark compares TTFT against the paged engine's scheduler-
        # tracked metrics on equal terms.  In-flight timings are folded
        # into _metric_records (and dropped from these dicts) when a
        # request finishes, so they track active requests, not history.
        self._t_submit: dict[int, float] = {}
        self._t_first: dict[int, float] = {}
        self._metric_records: dict[int, dict] = {}

    # ------------------------------------------------------------------
    def _now(self) -> float:
        return self.clock() + self._clock_offset

    def add_request(self, prompt: list[int], *, max_new_tokens: int = 32,
                    eos_id: int | None = None, deadline_ttft=None,
                    deadline_e2e=None) -> int:
        # Regression guard: a prompt longer than the cache used to
        # shape-error inside _admit (`toks[0, :n] = prompt` against the
        # clamped max_len bucket); fail cleanly at submission instead.
        _validate_request(prompt, self.max_len, max_new_tokens)
        req = Request(next(self._uid), list(prompt), max_new_tokens, eos_id,
                      deadline_ttft=deadline_ttft, deadline_e2e=deadline_e2e)
        now = self._now()
        self.trace.begin("request", f"{self._tns}:{req.uid}", uid=req.uid,
                         prompt_len=len(req.prompt), max_new=max_new_tokens)
        if (self.max_waiting is not None
                and len(self.pending) >= self.max_waiting):
            # Load shedding, reject-newest: accepted requests keep their
            # latency bound; the verdict is immediate (req.status).
            self.counters["shed"] += 1
            self.trace.instant("shed", uid=req.uid)
            self._terminal(req, lifecycle.REJECTED, now, t_submit=now)
            return req.uid
        self.pending.append(req)
        self._t_submit[req.uid] = now
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Terminate ``uid`` immediately, freeing its slot if it holds one.
        False for unknown / already-terminal uids."""
        for req in self.pending:
            if req.uid == uid:
                self.pending.remove(req)
                self.counters["cancelled"] += 1
                self._terminal(req, lifecycle.CANCELLED, self._now())
                return True
        for slot, req in list(self.active.items()):
            if req.uid == uid:
                self._release_slot(slot)
                self.counters["cancelled"] += 1
                self._terminal(req, lifecycle.CANCELLED, self._now())
                return True
        return False

    def _terminal(self, req: Request, status: str, now: float, *,
                  t_submit: float | None = None) -> None:
        """Move a request to a terminal status and record its metrics row."""
        req.status = status
        if t_submit is not None:
            self._t_submit.setdefault(req.uid, t_submit)
        self._finish_metrics(req, now)
        # End-event args ARE the metrics row: the trace reconstructs the
        # terminal status / timings bit-consistently with metrics().
        self.trace.end("request", f"{self._tns}:{req.uid}",
                       **self._metric_records[req.uid])
        self.finished.append(req)

    def _release_slot(self, slot: int) -> None:
        """Free a slot mid-flight: pin its garbage decode to one KV block
        (same reset as natural completion)."""
        del self.active[slot]
        self.pos = self.pos.at[slot].set(0)
        if "length" in self.cache:
            self.cache["length"] = self.cache["length"].at[slot].set(0)

    def _expire_pass(self, done_now: list) -> None:
        """Deadline sweep: TTFT deadlines apply while a request waits for
        admission (its first token lands on the first step after); e2e
        deadlines apply everywhere."""
        now = self._now()
        for req in list(self.pending):
            waited = now - self._t_submit.get(req.uid, now)
            if ((req.deadline_ttft is not None and waited > req.deadline_ttft)
                    or (req.deadline_e2e is not None
                        and waited > req.deadline_e2e)):
                self.pending.remove(req)
                self.counters["expired"] += 1
                self._terminal(req, lifecycle.EXPIRED, now)
                done_now.append(req)
        for slot, req in list(self.active.items()):
            waited = now - self._t_submit.get(req.uid, now)
            if req.deadline_e2e is not None and waited > req.deadline_e2e:
                self._release_slot(slot)
                self.counters["expired"] += 1
                self._terminal(req, lifecycle.EXPIRED, now)
                done_now.append(req)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def _prefill_fn(self, bucket: int, group: int = 1):
        """Jitted prefill keyed by (bucket, G*): G* = 1 is the engine's
        exact path; G* > 1 runs the backbone under the degraded attention
        config (serve.degrade) while the cache layout stays the engine's
        own (make_prefill backbone_cfg)."""
        key = (bucket, group)
        if key not in self._prefills:
            bcfg = None
            if group > 1:
                bcfg = self.cfg.replace(
                    attention=self.cfg.attention.degraded(group)
                )
            self._prefills[key] = jax.jit(
                make_prefill(self.cfg, self.max_len, backbone_cfg=bcfg)
            )
        return self._prefills[key]

    def _admit(self, done_now: list) -> None:
        group = 1
        if self.degrade is not None:
            # Pressure signal = backlog depth; one observe per step (admit
            # runs once per step) keeps the hysteresis tick-domain.
            level = self.degrade.observe(len(self.pending))
            group = self.degrade.cfg.group_for(level)
            if level != self._last_degrade_level:
                self.trace.instant("degrade_level", level=level, group=group)
                self._last_degrade_level = level
        for slot in self._free_slots():
            if not self.pending:
                break
            req = self.pending.pop(0)
            if self.faults.fires("stuck_step", req.uid) is not None:
                # Bounded retry: the prefill "raised"; requeue at the front
                # and retry next step, then quarantine just this request.
                tries = self._step_tries.get(req.uid, 0) + 1
                self._step_tries[req.uid] = tries
                self.counters["step_retries"] += 1
                if tries > 2:
                    self._step_tries.pop(req.uid, None)
                    self.counters["failed_fault"] += 1
                    self._terminal(req, lifecycle.FAILED, self._now())
                    done_now.append(req)
                else:
                    self.pending.insert(0, req)
                    break
                continue
            self._step_tries.pop(req.uid, None)
            n = len(req.prompt)
            bucket = min(_bucket(n), self.max_len)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt
            # Long-prompt prefill rides the context-parallel ring when the
            # engine has a mesh (trace-time dispatch in core.api.attend).
            with self.trace.span("prefill", uid=req.uid, bucket=bucket,
                                 group=group), maybe_set_mesh(self.mesh):
                logits, cache1 = self._prefill_fn(bucket, group)(
                    self.params, jnp.asarray(toks)
                )
            # Numeric health guard: a non-finite last-position row means
            # this prompt's forward blew up — quarantine the request BEFORE
            # its cache touches the slot; the other slots never notice.
            row = np.asarray(logits[0, -1], np.float32)
            if (self.faults.fires("nan_logits", req.uid) is not None
                    or not np.isfinite(row).all()):
                self.counters["failed_numeric"] += 1
                self._terminal(req, lifecycle.FAILED, self._now())
                done_now.append(req)
                continue
            req.degrade_group = group
            if group > 1:
                self.counters["degraded_prefills"] += 1
            req.status = lifecycle.RUNNING
            # NOTE: right-padding shifts the "last" logit for padded prompts;
            # re-read the true last-position logits from position n-1 by
            # decoding from position n with the prompt's last token instead.
            self.cache = {
                key: self._insert_slot(self.cache[key], cache1[key], slot,
                                       self._slot_axis(key))
                for key in self.cache
            }
            if "length" in self.cache:
                # Bucketed prefill right-pads the prompt; only the true n
                # tokens are live — every decode step's KV walk (and the
                # kernel grid) is bounded by this, not by max_len.
                self.cache["length"] = self.cache["length"].at[slot].set(n)
            self.pos = self.pos.at[slot].set(n - 1)
            self.tokens = self.tokens.at[slot, 0].set(req.prompt[-1])
            self.active[slot] = req

    @staticmethod
    def _slot_axis(key: str) -> int:
        """Batch/slot axis per cache layout (serve.kv_cache docstring)."""
        if key in ("cross_len", "length"):
            return 0
        if key.startswith("groups_"):
            return 2  # (G, per_group, B, ...)
        return 1  # (L_or_G, B, ...)

    @staticmethod
    def _insert_slot(full: jnp.ndarray, one: jnp.ndarray, slot: int,
                     axis: int) -> jnp.ndarray:
        # pad seq dims that differ (prefill bucket < max_len)
        for ax2 in range(full.ndim):
            if ax2 != axis and one.shape[ax2] != full.shape[ax2]:
                widths = [(0, 0)] * full.ndim
                widths[ax2] = (0, full.shape[ax2] - one.shape[ax2])
                one = jnp.pad(one, widths)
        idx = [slice(None)] * full.ndim
        idx[axis] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one.astype(full.dtype))

    def step(self) -> list[Request]:
        """Admit pending, decode one token for all active slots; returns
        newly *terminal* requests (done, expired, or failed this step)."""
        done_now: list[Request] = []
        spec = self.faults.fires("slow_step")
        if spec is not None:
            # A straggling step ages every in-flight deadline (no wall-
            # clock sleep needed — the offset rides the injectable clock).
            self._clock_offset += spec.delay
        self._expire_pass(done_now)
        self._admit(done_now)
        if not self.active:
            return done_now
        # advance positions: decode writes at pos+1 (pos = last filled index).
        # Idle slots stay pinned at 0 so their garbage decode keeps walking
        # one KV block instead of growing back toward max_len (serve_step
        # stores length = max(length, pos+1)).
        occupied = np.zeros((self.max_slots,), bool)
        for s in self.active:
            occupied[s] = True
        step_pos = jnp.where(jnp.asarray(occupied), self.pos + 1, 0)
        for slot, req in list(self.active.items()):
            if self.faults.fires("stuck_step", req.uid) is not None:
                # The whole batched decode "raised": retry the step next
                # call, spending retry budget only on the culprit.
                tries = self._step_tries.get(req.uid, 0) + 1
                self._step_tries[req.uid] = tries
                self.counters["step_retries"] += 1
                if tries > 2:
                    self._step_tries.pop(req.uid, None)
                    self._release_slot(slot)
                    self.counters["failed_fault"] += 1
                    self._terminal(req, lifecycle.FAILED, self._now())
                    done_now.append(req)
                return done_now
        self._rng, sub = jax.random.split(self._rng)
        with self.trace.span("decode", n_active=len(self.active)):
            logits, self.cache = self._decode(
                self.params, self.tokens, self.cache, step_pos
            )
        # Per-slot numeric health guard: one device-side reduce + a tiny
        # host transfer; a non-finite row quarantines exactly that slot.
        nan_slots = {
            slot for slot, req in self.active.items()
            if self.faults.fires("nan_logits", req.uid) is not None
        }
        if nan_slots:
            logits = logits.at[np.array(sorted(nan_slots)), -1].set(jnp.nan)
        row_ok = np.asarray(jnp.isfinite(logits[:, -1]).all(axis=-1))
        next_tokens = sample(
            logits, rng=sub, temperature=self.temperature,
            top_k=self.top_k, top_p=self.top_p,
        )
        self.pos = step_pos
        self.tokens = next_tokens[:, None]

        toks = np.asarray(next_tokens)
        now = self._now()
        # Ring caches (GQA, length-tracked) slide past max_len: the ring
        # write evicts the oldest token and the kernels see the live window
        # min(length, max_len).  Other cache layouts (MLA/SSM/hybrid/encdec)
        # have no ring invariant, so their sequences finish before wrap.
        sliding = "length" in self.cache
        for slot, req in list(self.active.items()):
            if not row_ok[slot]:
                # Quarantine: the offending slot alone dies; every other
                # slot's cache row and token are untouched.
                self._release_slot(slot)
                self.counters["failed_numeric"] += 1
                self._terminal(req, lifecycle.FAILED, now)
                done_now.append(req)
                continue
            self._step_tries.pop(req.uid, None)
            t = int(toks[slot])
            req.generated.append(t)
            if len(req.generated) == 1:
                self._t_first[req.uid] = now
                self.trace.instant("first_token", uid=req.uid)
            limit = len(req.generated) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and t == req.eos_id
            full = (not sliding) and int(self.pos[slot]) >= self.max_len - 2
            if limit or hit_eos or full:
                req.done = True
                # Reset the freed slot so its (garbage) decode walks one KV
                # block, not the dead sequence's full live window.
                self._release_slot(slot)
                self._terminal(req, lifecycle.DONE, now)
                done_now.append(req)
        return done_now

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.active and not self.pending:
                return self.finished
        # Steps exhausted with work in flight: a silent return here let a
        # hung engine masquerade as success (requests vanished without a
        # terminal status).
        raise IncompleteRun(
            sorted([r.uid for r in self.active.values()]
                   + [r.uid for r in self.pending]),
            max_steps,
        )

    def _finish_metrics(self, req: Request, now: float) -> None:
        t0 = self._t_submit.pop(req.uid, None)
        t1 = self._t_first.pop(req.uid, None)
        n = len(req.generated)
        self._metric_records[req.uid] = {
            "uid": req.uid,
            "ttft_s": None if t0 is None or t1 is None else t1 - t0,
            "tpot_s": None if t1 is None else (now - t1) / max(n - 1, 1),
            "n_generated": n,
            "n_preemptions": 0,
            "status": req.status,
            "degrade_group": req.degrade_group,
        }

    def has_work(self) -> bool:
        return bool(self.active or self.pending)

    def queue_depth(self) -> int:
        """Requests waiting for admission (cluster-router health signal)."""
        return len(self.pending)

    def degrade_level(self) -> int:
        """Current degradation-controller level (0 = exact / no controller)."""
        return 0 if self.degrade is None else self.degrade.level

    def counters_snapshot(self) -> dict:
        """Robustness counters, frozen to ``lifecycle.COUNTER_KEYS`` (zero-
        filled) — the exact key set the paged engine and scheduler report,
        so the cluster router's health model can diff snapshots blindly."""
        return lifecycle.counters_view(self.counters)

    @property
    def max_prompt_len(self) -> int:
        """Longest prompt ``add_request`` accepts — the replica *capability*
        the cluster router steers on (serve.cluster.EngineReplica)."""
        return self.max_len

    def metrics(self) -> list[dict]:
        """Per-request TTFT / TPOT (same shape as PagedServeEngine.metrics,
        so benchmarks/serving.py compares the engines on equal terms).
        Records live exactly as long as ``finished`` does."""
        return [
            self._metric_records[req.uid]
            for req in self.finished
            if req.uid in self._metric_records
        ]


# ---------------------------------------------------------------------------
# Paged engine: block-pool KV + continuous-batching scheduler
# ---------------------------------------------------------------------------


class PagedServeEngine:
    """Serving engine over the paged KV subsystem (serve.paged +
    serve.scheduler + kernels/paged_decode.py).

    Replaces the per-slot contiguous ``max_len`` slab with a shared block
    pool: HBM is committed per *live token* (rounded to ``block_size``),
    not per worst-case sequence, so at equal memory budget a mixed-length
    workload runs far more concurrent decode lanes.  Every ``step()``
    delegates to the continuous-batching :class:`~repro.serve.scheduler.
    Scheduler`: token-budget admission each tick, chunked prefill riding
    the paged decode kernel (banded multi-token windows — exact attention,
    unlike the slot engine's approximate distr prefill when
    ``impl='distr'``), FCFS with whole-request preemption to host when the
    pool runs dry.

    Scope: GQA dense/moe families (the pools mirror the ring k/v cache
    layout; fused-K̂ pools under ``attention.distr_decode``).  A request's
    *prompt* is bounded by ``max_len`` (the block-table width); decode
    slides past it — once the table is full the write position wraps
    (``pos mod capacity``) and new tokens recycle the request's head
    blocks in place, the paged analog of the slot engine's ring-cache
    eviction, so ``max_new_tokens`` is never capacity-bound.

    ``mesh``: optional device mesh.  When it carries the axis named by
    ``cfg.attention.context_axis``, whole-prompt prefill of long prompts
    runs ring sequence-parallel attention across the mesh and scatters the
    resulting per-layer K/V into this engine's (single-device) block pool
    in ONE scheduler tick (``prefill_mesh_run``) — prefill compute scales
    with ring size, decode-side KV residency stays paged and local.

    Construction resolves the pool block size through the autotuner
    (``repro.tune`` kernel key ``paged_decode``) — under
    ``REPRO_TUNE=measure`` the sweep runs once here, never in a tick.
    With a mesh it also pre-resolves the ring-prefill attention buckets
    (keyed per ring shard) so no serving tick blocks on a timing run.
    """

    #: Decode slides past capacity by recycling head blocks (the scheduler
    #: consults this before force-finishing a request at the table bound).
    window_decode = True

    def __init__(self, cfg, params, *, max_batch: int = 8, max_len: int = 512,
                 block_size: int | None = None, num_blocks: int | None = None,
                 prefill_chunk: int = 32, token_budget: int = 0,
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
                 seed: int = 0, cache_dtype=jnp.bfloat16, clock=None,
                 max_waiting=None, degrade: DegradeConfig | None = None,
                 faults=None, mesh=None, trace=None):
        from repro.serve import paged
        from repro.serve.scheduler import Scheduler, SchedulerConfig
        from repro.serve.serve_step import make_paged_step
        from repro.tune.autotune import warm_paged_engine

        if cfg.family not in ("dense", "moe") or cfg.use_mla:
            raise NotImplementedError(
                "paged serving covers GQA dense/moe; use ServeEngine for "
                f"family={cfg.family!r} use_mla={cfg.use_mla}"
            )
        if getattr(cfg, "frontend", None):
            raise NotImplementedError(
                "chunked prefill drives token prompts; patch/frame "
                "frontends keep the slot engine"
            )
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.mesh = mesh
        self._uid = itertools.count()
        self._rng = jax.random.PRNGKey(seed)

        # Pool block size doubles as allocator granularity: resolve it
        # (tuned under REPRO_TUNE) before the pools are shaped by it.  An
        # explicit block_size skips the decode warm-up — a measure-mode
        # sweep whose result would be discarded is pure construction-time
        # waste.  A mesh engine additionally warms the ring-prefill
        # attention buckets under the mesh (per-shard tuner keys).
        want_decode = block_size is None
        if want_decode or mesh is not None:
            with maybe_set_mesh(mesh):
                self.tuned_blocks = warm_paged_engine(
                    cfg, max_len, decode=want_decode,
                    mesh_prefill_buckets=mesh is not None,
                )
        else:
            self.tuned_blocks = {}
        if block_size is None:
            block_size = self.tuned_blocks.get("paged_decode", 128)
        self.block_size = min(block_size, max_len)
        self.max_blocks = -(-max_len // self.block_size)
        self.capacity_tokens = self.max_blocks * self.block_size
        if num_blocks is None:
            # Memory-pressure-free default: every lane can hold max_len.
            num_blocks = 1 + max_batch * self.max_blocks
        if num_blocks - 1 < self.max_blocks:
            raise ValueError(
                f"pool of {num_blocks} blocks (1 reserved) cannot hold one "
                f"full request ({self.max_blocks} blocks of "
                f"{self.block_size}); preemption could not guarantee "
                "progress"
            )
        self.cache = paged.PagedKVCache(
            cfg, num_blocks, self.block_size, dtype=cache_dtype
        )
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.faults = faults or NULL_INJECTOR
        self.trace = trace if trace is not None else get_recorder()
        self.scheduler = Scheduler(
            SchedulerConfig(
                max_batch=max_batch, prefill_chunk=self.prefill_chunk,
                token_budget=token_budget, max_waiting=max_waiting,
            ),
            degrade=degrade, faults=self.faults, trace=self.trace,
            **({"clock": clock} if clock is not None else {}),
        )
        self._decode = jax.jit(make_paged_step(cfg, 1))
        self._chunk = jax.jit(make_paged_step(cfg, self.prefill_chunk))
        self._degraded: dict[tuple[int, int], object] = {}
        self._mesh_prefills: dict = {}
        self.finished: list[Request] = []

    # -- public API (mirrors ServeEngine) --------------------------------

    def add_request(self, prompt: list[int], *, max_new_tokens: int = 32,
                    eos_id: int | None = None, deadline_ttft=None,
                    deadline_e2e=None) -> int:
        # The first decode token writes at position len(prompt): a request
        # must leave at least one block-table slot for it (a clamped write
        # at capacity would land inside the LAST live block).  Only the
        # PROMPT is capacity-bound: max_new_tokens may cross capacity
        # freely — decode slides by recycling head blocks (window_decode).
        _validate_request(
            prompt, min(self.max_len, self.capacity_tokens - 1),
            max_new_tokens, what="max_len (capacity − 1)",
        )
        req = Request(next(self._uid), list(prompt), max_new_tokens, eos_id,
                      deadline_ttft=deadline_ttft, deadline_e2e=deadline_e2e)
        if self.scheduler.submit(req) is None:
            self.finished.append(req)  # shed at the gate (status rejected)
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Terminate ``uid`` now; its blocks / lane / host copy free in this
        call, not at the next tick.  False for unknown / terminal uids."""
        if self.scheduler.cancel(uid, self):
            e = next(x for x in reversed(self.scheduler.done)
                     if x.uid == uid)
            self.finished.append(e.req)
            return True
        return False

    def step(self) -> list[Request]:
        """One scheduler tick: admission + chunked prefill + batched decode
        (serve.scheduler.Scheduler.tick)."""
        done = self.scheduler.tick(self)
        self.finished.extend(done)
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.scheduler.has_work():
                return self.finished
        # A silent return here let a hung scheduler masquerade as success.
        raise IncompleteRun(
            sorted([e.uid for e in self.scheduler.waiting]
                   + [e.uid for e in self.scheduler.running.values()]),
            max_steps,
        )

    def metrics(self) -> list[dict]:
        """Per-request TTFT / TPOT / preemption counts / terminal status /
        degradation level (scheduler-tracked)."""
        return self.scheduler.metrics()

    def counters_snapshot(self) -> dict:
        """Robustness counters, frozen to ``lifecycle.COUNTER_KEYS`` (the
        slot engine reports the identical key set)."""
        return self.scheduler.counters_snapshot()

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def queue_depth(self) -> int:
        """Requests waiting for admission (cluster-router health signal)."""
        return len(self.scheduler.waiting)

    def degrade_level(self) -> int:
        """Current degradation-controller level (0 = exact / no controller)."""
        d = self.scheduler.degrade
        return 0 if d is None else d.level

    @property
    def max_prompt_len(self) -> int:
        """Longest prompt ``add_request`` accepts — the replica *capability*
        the cluster router steers on (serve.cluster.EngineReplica).  A
        mesh-backed engine is built with a large ``max_len`` (ring prefill
        makes it affordable); this property is how it advertises that."""
        return min(self.max_len, self.capacity_tokens - 1)

    # -- scheduler primitives --------------------------------------------

    def free_lane(self) -> int:
        for lane in range(self.max_batch):
            if lane not in self.scheduler.running:
                return lane
        raise RuntimeError("no free lane (scheduler admitted past max_batch)")

    def alloc(self, entry, n_tokens: int) -> bool:
        from repro.serve.paged import PoolExhausted

        if self.faults.fires("pool_exhausted", entry.uid) is not None:
            # Injected allocator failure presents exactly like the real
            # one: False — the scheduler waits / preempts / watchdogs.
            return False
        try:
            self.cache.allocate_to(entry.uid, min(n_tokens, self.capacity_tokens))
            return True
        except PoolExhausted:
            return False

    def can_admit(self, entry) -> bool:
        """Admission watermark: the whole prompt plus one decode-growth
        block must fit in free blocks before the first chunk runs."""
        need = self.cache.blocks_for(
            min(len(entry.req.prompt) + 1, self.capacity_tokens)
        )
        return self.cache.pool.num_free >= need

    def evict(self, entry) -> None:
        self.cache.evict_to_host(entry.uid, entry.length,
                                 pad_to=self.max_blocks)

    def restore(self, entry) -> bool:
        from repro.serve.paged import PoolExhausted

        # A raise is a restore FAULT (host↔device copy failure — bounded
        # retry with backoff); a False return is a capacity wait (free
        # blocks will appear) and costs no retry budget.
        self.faults.raise_if("restore_failure", entry.uid)
        try:
            self.cache.restore(entry.uid)
            return True
        except PoolExhausted:
            return False

    def release(self, entry) -> None:
        self.cache.free(entry.uid)

    def holds_blocks(self, entry) -> bool:
        return bool(self.cache.tables.get(entry.uid))

    def sample_one(self, logits_row: jnp.ndarray) -> int:
        self._rng, sub = jax.random.split(self._rng)
        tok = sample(
            logits_row[None], rng=sub, temperature=self.temperature,
            top_k=self.top_k, top_p=self.top_p,
        )
        return int(tok[0])

    def prefill_chunk_run(self, entry, chunk: int) -> jnp.ndarray:
        """One chunked-prefill window for ``entry`` (B = 1 jit bucket);
        returns the last *live* row's logits (exact last-position
        distribution once the prompt completes)."""
        # Raised BEFORE any pool mutation: a retried chunk re-runs cleanly.
        self.faults.raise_if("stuck_step", entry.uid)
        start = entry.prompt_done
        toks = np.zeros((1, self.prefill_chunk), np.int32)
        toks[0, :chunk] = entry.req.prompt[start : start + chunk]
        bt = self.cache.table_array([entry.uid], self.max_blocks)
        logits, self.cache.pools = self._chunk(
            self.params, jnp.asarray(toks), self.cache.pools, bt,
            jnp.asarray([start], jnp.int32), jnp.asarray([chunk], jnp.int32),
        )
        row = logits[0, chunk - 1]
        if self.faults.fires("nan_logits", entry.uid) is not None:
            row = jnp.full_like(row, jnp.nan)
        return row

    def _degraded_prefill_fn(self, bucket: int, group: int):
        from repro.serve.serve_step import make_degraded_paged_prefill

        key = (bucket, group)
        if key not in self._degraded:
            self._degraded[key] = jax.jit(
                make_degraded_paged_prefill(self.cfg, bucket, group)
            )
        return self._degraded[key]

    def prefill_full_run(self, entry, group: int) -> jnp.ndarray:
        """Whole-prompt *degraded* prefill (serve.degrade): one forward
        under DistrAttention grouping 1/``group`` replaces every exact
        chunk, scattering the prompt's K/V into the already-allocated
        blocks; returns the last live row's logits."""
        self.faults.raise_if("stuck_step", entry.uid)
        n = len(entry.req.prompt)
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = entry.req.prompt
        bt = self.cache.table_array([entry.uid], self.max_blocks)
        row, self.cache.pools = self._degraded_prefill_fn(bucket, group)(
            self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32),
            self.cache.pools, bt,
        )
        if self.faults.fires("nan_logits", entry.uid) is not None:
            row = jnp.full_like(row, jnp.nan)
        return row

    def mesh_prefill_ready(self, n: int) -> bool:
        """Scheduler consult: admit an ``n``-token prompt as ONE whole-
        prompt ring-prefill tick instead of chunked prefill?  Requires a
        mesh, and a prompt longer than one chunk — a one-chunk prompt
        already admits in a single tick, with no collective to amortise."""
        return self.mesh is not None and n > self.prefill_chunk

    def _mesh_prefill_fn(self, bucket: int, dead=frozenset()):
        from repro.serve.serve_step import make_mesh_paged_prefill

        # Keyed by the dead-shard set too: dead_shard_fault rewires the
        # ring at TRACE time, so a degraded ring needs its own jit entry.
        key = (bucket, tuple(sorted(dead)))
        if key not in self._mesh_prefills:
            self._mesh_prefills[key] = jax.jit(
                make_mesh_paged_prefill(self.cfg, bucket)
            )
        return self._mesh_prefills[key]

    def prefill_mesh_run(self, entry) -> jnp.ndarray:
        """Whole-prompt *exact* prefill across the context-parallel ring
        (serve_step.make_mesh_paged_prefill): one forward under the
        engine's mesh replaces every chunk, scattering the prompt's
        per-layer K/V into the already-allocated blocks of THIS device's
        pool; returns the last live row's logits.  Faults fire before any
        pool mutation, so a failed collective never poisons the blocks."""
        self.faults.raise_if("stuck_step", entry.uid)
        self.faults.raise_if("mesh_prefill", entry.uid)
        from repro.distributed.ring_attention import dead_shard_fault

        n = len(entry.req.prompt)
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = entry.req.prompt
        bt = self.cache.table_array([entry.uid], self.max_blocks)
        dead = self.faults.dead_shards()
        with maybe_set_mesh(self.mesh), dead_shard_fault(dead):
            row, self.cache.pools = self._mesh_prefill_fn(bucket, dead)(
                self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32),
                self.cache.pools, bt,
            )
        if self.faults.fires("nan_logits", entry.uid) is not None:
            row = jnp.full_like(row, jnp.nan)
        return row

    def decode_tick(self, running: dict) -> tuple[np.ndarray, np.ndarray]:
        """One batched decode over all running lanes; returns
        ``(tokens, ok)`` — (max_batch,) sampled tokens (garbage on idle
        lanes — the scheduler only reads occupied ones) and the numeric
        health mask (False = that lane's logits went non-finite; the
        scheduler quarantines exactly that request)."""
        for e in running.values():
            # Raised BEFORE the model call (no pool mutated): the retried
            # tick re-runs cleanly and only the culprit spends budget.
            self.faults.raise_if("stuck_step", e.uid)
        occupied = np.zeros((self.max_batch,), bool)
        pos = np.zeros((self.max_batch,), np.int32)
        toks = np.zeros((self.max_batch, 1), np.int32)
        uids = [-1] * self.max_batch
        for lane, e in running.items():
            occupied[lane] = True
            pos[lane] = e.length
            toks[lane, 0] = e.next_token
            uids[lane] = e.uid
        bt = self.cache.table_array(uids, self.max_blocks)
        count = jnp.asarray(occupied.astype(np.int32))
        logits, self.cache.pools = self._decode(
            self.params, jnp.asarray(toks), self.cache.pools, bt,
            jnp.asarray(pos), count,
        )
        nan_lanes = [lane for lane, e in running.items()
                     if self.faults.fires("nan_logits", e.uid) is not None]
        if nan_lanes:
            logits = logits.at[np.array(sorted(nan_lanes)), -1].set(jnp.nan)
        # Numeric health guard: one device-side reduce, one tiny transfer.
        # Only occupied lanes count (idle lanes decode garbage by design).
        ok = np.asarray(jnp.isfinite(logits[:, -1]).all(axis=-1)) | ~occupied
        self._rng, sub = jax.random.split(self._rng)
        next_tokens = sample(
            logits[:, -1], rng=sub, temperature=self.temperature,
            top_k=self.top_k, top_p=self.top_p,
        )
        return np.asarray(next_tokens), ok
