"""KV-cache layouts per architecture family + the beyond-paper fused-K̂
DistrAttention decode cache.

Layouts (L = layers, B = slots, S = max_len):
  dense/moe (GQA): k, v            (L, B, Hkv, S, dh) + length (B,)
  mla:             ckv             (L, B, S, kv_lora), krope (L, B, S, rope_d)
  ssm:             conv            (L, B, k-1, conv_dim), ssm (L, B, H, S, P)
  hybrid:          groups_* (G, per-group stacks) + shared_k/v per group site
  encdec:          k, v + cross_k, cross_v (L, B, Hkv, enc_len, dh)

Ring layout (GQA serve path, DESIGN.md §Decode): the S axis is a ring —
writes land at ``pos mod S`` (``models.attention.cache_insert``) and the
per-slot ``length`` tracks the *total* tokens ever written, so the live
window is the most recent ``min(length, S)`` tokens.  Invariants:

  * length ≤ S ⇒ slots ``0..length-1`` are live, tail ``length..S-1`` dead —
    the decode kernel's grid visits only ``ceil(length/block_k)`` KV blocks
    and masks the part-filled tail block (kernels/decode.py);
  * length > S ⇒ every slot is live (the ring has wrapped; oldest tokens
    were overwritten);
  * RoPE positions stay absolute — only the storage slot wraps.

Fused decode cache (``AttentionConfig.distr_decode``): for GQA archs the K
cache additionally stores K̂ = fuse(K, perm_static) with a *static* per-layer
permutation — at decode the score stage reads d/G* columns per token instead
of d, cutting K-cache read bytes by (1-1/G*)·½ of KV traffic in the
memory-bound decode regime (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grouping, lsh


def _hybrid_layout(cfg):
    n_groups = cfg.n_layers // cfg.attn_every
    n_tail = cfg.n_layers - n_groups * cfg.attn_every
    return n_groups, n_tail


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def cache_struct(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree for the cache (used by init & dry-run)."""
    f = jax.ShapeDtypeStruct
    dh = cfg.head_dim_
    l, hkv = cfg.n_layers, cfg.n_kv_heads

    if cfg.family == "encdec":
        return {
            "k": f((l, batch, hkv, max_len, dh), dtype),
            "v": f((l, batch, hkv, max_len, dh), dtype),
            "cross_k": f((l, batch, hkv, cfg.cross_len, dh), dtype),
            "cross_v": f((l, batch, hkv, cfg.cross_len, dh), dtype),
            "cross_len": f((batch,), jnp.int32),
        }
    if cfg.family == "ssm":
        return {
            "conv": f((l, batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
            "ssm": f((l, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                     jnp.float32),
        }
    if cfg.family == "hybrid":
        g, t = _hybrid_layout(cfg)
        cache = {
            "groups_conv": f((g, cfg.attn_every, batch, cfg.ssm_conv - 1,
                              conv_dim(cfg)), dtype),
            "groups_ssm": f((g, cfg.attn_every, batch, cfg.ssm_heads,
                             cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "shared_k": f((g, batch, hkv, max_len, dh), dtype),
            "shared_v": f((g, batch, hkv, max_len, dh), dtype),
        }
        if t:
            cache["tail_conv"] = f((t, batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype)
            cache["tail_ssm"] = f((t, batch, cfg.ssm_heads, cfg.ssm_state,
                                   cfg.ssm_head_dim), jnp.float32)
        return cache
    if cfg.use_mla:
        return {
            "ckv": f((l, batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": f((l, batch, max_len, cfg.qk_rope_dim), dtype),
        }
    cache = {
        "k": f((l, batch, hkv, max_len, dh), dtype),
        "v": f((l, batch, hkv, max_len, dh), dtype),
        # Total tokens written per slot (ring: live window = min(length, S)).
        # The decode kernels bound their KV walk by it instead of max_len.
        "length": f((batch,), jnp.int32),
    }
    if cfg.attention.distr_decode:
        g = cfg.attention.distr.group_size
        # bf16 K̂: the bandwidth win is the point (KV read bytes drop by
        # (1-1/G*)/2 of the K side; see benchmarks/distr_decode.py).
        cache["k_fused"] = f((l, batch, hkv, max_len, dh // g), dtype)
    return cache


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_struct(cfg, batch, max_len, dtype)
    )


def cache_pspecs(cfg, mesh, *, batch: int = 0, max_len: int = 0) -> dict:
    """PartitionSpecs for the cache tree: batch → DP axes; the long/seq or
    head dim → "model" per cfg.attn_shard (flash-decoding style for seq).

    Axis assignments that don't divide the actual cache dims (e.g. batch=1
    for long_500k) are dropped — pass batch/max_len to enable the check.
    """
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a != "model")
    seq_sharded = cfg.attn_shard == "seq"

    def spec_for(key: str, ndim: int) -> P:
        if key in ("k", "v", "cross_k", "cross_v", "k_fused"):
            # (L, B, Hkv, S, dh)
            return P(None, dp, None, "model", None) if seq_sharded else \
                P(None, dp, "model", None, None)
        if key in ("ckv", "krope"):  # (L, B, S, C)
            return P(None, dp, "model", None)
        if key == "ssm":  # (L, B, H, S, P)
            return P(None, dp, "model", None, None)
        if key == "conv":  # (L, B, k-1, conv_dim)
            return P(None, dp, None, "model")
        if key in ("groups_ssm",):  # (G, per, B, H, S, P)
            return P(None, None, dp, "model", None, None)
        if key in ("groups_conv",):  # (G, per, B, k-1, conv_dim)
            return P(None, None, dp, None, "model")
        if key in ("tail_ssm",):
            return P(None, dp, "model", None, None)
        if key in ("tail_conv",):
            return P(None, dp, None, "model")
        if key in ("shared_k", "shared_v"):  # (G, B, Hkv, S, dh)
            return P(None, dp, "model", None, None)
        return P(*([None] * ndim))

    struct = cache_struct(cfg, max(batch, 1), max(max_len, 2))
    axis_size = {a: int(mesh.shape[a]) for a in mesh.axis_names}

    def prune(spec: P, shape: tuple) -> P:
        entries = []
        for i, s in enumerate(spec):
            if s is None:
                entries.append(None)
                continue
            parts = s if isinstance(s, tuple) else (s,)
            need = 1
            for a in parts:
                need *= axis_size.get(a, 1)
            if batch and shape[i] % need:
                entries.append(None)
            else:
                entries.append(s)
        return P(*entries)

    return {
        k: prune(spec_for(k, len(v.shape)), v.shape) for k, v in struct.items()
    }


# ---------------------------------------------------------------------------
# Fused-K̂ decode cache (beyond-paper DistrAttention extension)
# ---------------------------------------------------------------------------


def static_perms(cfg, n_layers: int | None = None) -> jnp.ndarray:
    """Static per-(layer, kv-head) grouping permutations (L, Hkv, dh).

    Derived from the fixed LSH projection seed; in production these would be
    calibrated from prefill Q statistics — here they are seeded random, which
    preserves the bandwidth story (the accuracy story is benchmarked in
    benchmarks/distr_decode.py).
    """
    l = n_layers if n_layers is not None else cfg.n_layers
    dh = cfg.head_dim_
    key = jax.random.PRNGKey(cfg.attention.distr.proj_seed + 13)
    perms = []
    for i in range(l):
        key, sub = jax.random.split(key)
        perms.append(
            jnp.stack([
                jax.random.permutation(jax.random.fold_in(sub, h), dh)
                for h in range(cfg.n_kv_heads)
            ])
        )
    return jnp.stack(perms).astype(jnp.int32)  # (L, Hkv, dh)


def fuse_new_k(k_new: jnp.ndarray, perm: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Fuse one decode step's K rows.  k_new: (B, Hkv, 1, dh); perm: (Hkv, dh)."""
    return grouping.fuse_columns(k_new.astype(jnp.float32), perm[None], group_size)


def sample_q(q: jnp.ndarray, perm: jnp.ndarray, group_size: int,
             q_per_kv: int) -> jnp.ndarray:
    """Sample Q columns under the per-kv-head static permutation.

    q: (B, Hq, 1, dh); perm: (Hkv, dh) → (B, Hq, 1, dh/g).  Thin alias of
    ``core.grouping.sample_q_heads`` (the single implementation shared with
    the decode-kernel wrapper and the reference dispatch).
    """
    del q_per_kv  # implied by q/perm head counts
    return grouping.sample_q_heads(q, perm, group_size)
