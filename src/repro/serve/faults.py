"""Serve-tier re-export shim over the shared fault-injection machinery.

The :class:`FaultInjector`/:class:`FaultSpec` machinery started life here
(PR 6) and was promoted to :mod:`repro.faults` when training grew its own
fault points — the serving tier's catalog is ``repro.faults.SERVE_POINTS``
and the full documentation lives on the shared module.  Every existing
``repro.serve.faults`` import keeps working through this shim; new code
should import from :mod:`repro.faults` directly.
"""
from __future__ import annotations

from repro.faults import (  # noqa: F401
    NULL_INJECTOR,
    POINTS,
    SERVE_POINTS,
    TRAIN_POINTS,
    FaultInjector,
    FaultSpec,
    InjectedFault,
)

__all__ = [
    "NULL_INJECTOR",
    "POINTS",
    "SERVE_POINTS",
    "TRAIN_POINTS",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
]
