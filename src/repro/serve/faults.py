"""Fault injection for the serving tier: named failure points with
deterministic triggers.

Production failure modes don't show up in happy-path tests, so both serve
engines expose a small set of **named fault points** that an injected
:class:`FaultInjector` can fire deterministically — the chaos suite
(tests/test_chaos.py) drives each one and asserts every request still
terminates with an explicit lifecycle status (serve.lifecycle) and no pool
block leaks.

Fault-point catalog (DESIGN.md §Robustness):

  pool_exhausted    block-pool allocation fails even though blocks are free
                    (models fragmentation / a buggy allocator under load);
                    fired inside ``PagedServeEngine.alloc``.
  nan_logits        a request's logits row is poisoned with NaN (models a
                    numerical blow-up in the model step); fired wherever
                    logits are produced (decode tick, prefill chunk, slot
                    decode) — exercises the numeric health guards.
  stuck_step        a model step raises instead of returning (models a hung
                    or crashed device call surfacing as an error); the
                    scheduler retries the culprit a bounded number of times
                    then fails it.  Raised as :class:`InjectedFault`.
  restore_failure   ``restore`` of a preempted request's KV raises (models
                    a host↔device copy failure); retried with exponential
                    backoff, bounded, then the request fails.
  slow_step         the scheduler's clock jumps forward by ``delay``
                    seconds (models a straggling step) — exercises the
                    deadline-expiry path without wall-clock sleeps.
  dead_ring_shard   a ring context-parallel KV shard never arrives at its
                    consumers (models a dead host mid-ring); implemented as
                    ``distributed.ring_attention.dead_shard_fault`` — the
                    ring skips the shard's hops and serves a degraded but
                    finite result.
  replica_crash     an entire engine replica's process dies (models OOM
                    kill / host loss in the multi-replica tier); consulted
                    by ``serve.cluster.ClusterRouter`` once per tick per
                    replica with ``uid`` = the REPLICA id — the replica
                    stops heartbeating, the router detects the death after
                    ``heartbeat_misses`` ticks and redelivers its in-flight
                    requests to survivors.

Triggers are *counted*: a :class:`FaultSpec` fires on hits
``after ≤ hit < after + times`` of its point (per matching uid), so a
fault can be transient (``times=2``) or persistent (``times=-1``) and every
run is reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field

POINTS = (
    "pool_exhausted",
    "nan_logits",
    "stuck_step",
    "restore_failure",
    "slow_step",
    "dead_ring_shard",
    "replica_crash",
)


class InjectedFault(Exception):
    """An injected failure surfacing through an engine primitive.  Carries
    the fault point and the culprit uid so the scheduler can retry / fail
    exactly the affected request and keep the batch alive."""

    def __init__(self, point: str, uid: int | None = None):
        self.point = point
        self.uid = uid
        super().__init__(f"injected fault {point!r} (uid={uid})")


@dataclass
class FaultSpec:
    """One deterministic trigger: fire ``point`` for hits ``after ≤ hit <
    after + times`` (``times=-1`` → forever), optionally restricted to one
    request (``uid``).  ``delay`` is the clock jump for ``slow_step``;
    ``shards`` the dead set for ``dead_ring_shard``."""

    point: str
    uid: int | None = None
    after: int = 0
    times: int = 1
    delay: float = 0.0
    shards: tuple[int, ...] = ()
    _hits: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; catalog: {POINTS}"
            )

    def _matches(self, uid: int | None) -> bool:
        return self.uid is None or uid == self.uid

    def _hit(self) -> bool:
        """Count one hit; True when this hit is inside the firing window."""
        h = self._hits
        self._hits += 1
        if h < self.after:
            return False
        return self.times < 0 or h < self.after + self.times


class FaultInjector:
    """A set of :class:`FaultSpec` triggers consulted at engine fault
    points.  ``fires(point, uid)`` counts one hit on every matching spec
    and returns the first spec whose window covers it (None otherwise) —
    pure host-side bookkeeping, deterministic across runs."""

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...] = ()):
        self.specs = list(specs)

    def fires(self, point: str, uid: int | None = None) -> FaultSpec | None:
        fired = None
        for s in self.specs:
            if s.point == point and s._matches(uid):
                if s._hit() and fired is None:
                    fired = s
        return fired

    def raise_if(self, point: str, uid: int | None = None) -> None:
        if self.fires(point, uid) is not None:
            raise InjectedFault(point, uid)

    def dead_shards(self) -> frozenset[int]:
        """Union of shard ids across active ``dead_ring_shard`` specs (for
        wiring into ``distributed.ring_attention.dead_shard_fault``)."""
        out: set[int] = set()
        for s in self.specs:
            if s.point == "dead_ring_shard":
                out.update(s.shards)
        return frozenset(out)


#: Engines default to this — zero per-tick overhead when nothing is injected.
NULL_INJECTOR = FaultInjector(())
