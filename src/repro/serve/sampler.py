"""Token sampling: greedy / temperature / top-k / top-p, pure-functional.

``temperature <= 0`` is exact greedy regardless of the truncation knobs —
the engines' greedy-parity tests rely on that (a top-k/top-p setting must
never change deterministic decoding).  top-k and top-p compose: logits are
truncated to the top-k set first, then to the smallest nucleus whose
probability mass reaches ``top_p``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MASKED = -1e30


def sample(
    logits: jnp.ndarray,  # (B, 1, V) or (B, V)
    *,
    rng: jax.Array | None = None,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jnp.ndarray:
    """→ (B,) int32 next tokens.  temperature 0 = greedy (knobs ignored);
    ``top_k > 0`` keeps the k highest logits; ``0 < top_p < 1`` keeps the
    smallest set of tokens whose softmax mass ≥ top_p (nucleus sampling,
    applied after the top-k cut).  The highest-probability token always
    survives both cuts, so sampling can never mask everything.
    """
    if logits.ndim == 3:
        logits = logits[:, -1, :]
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, _MASKED, logits)
    if 0.0 < top_p < 1.0:
        # Nucleus: sort descending, keep the prefix whose cumulative
        # probability (inclusive) first reaches top_p — the top token's
        # cumulative is its own mass, so it is always kept.
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < top_p  # exclusive mass before this token
        # Threshold = smallest kept logit per row.
        thresh = jnp.min(
            jnp.where(keep, sorted_logits, jnp.inf), axis=-1, keepdims=True
        )
        logits = jnp.where(logits < thresh, _MASKED, logits)
    assert rng is not None, "temperature sampling needs an rng"
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
