"""Token sampling: greedy / temperature / top-k, pure-functional."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jnp.ndarray,  # (B, 1, V) or (B, V)
    *,
    rng: jax.Array | None = None,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jnp.ndarray:
    """→ (B,) int32 next tokens.  temperature 0 = greedy."""
    if logits.ndim == 3:
        logits = logits[:, -1, :]
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    assert rng is not None, "temperature sampling needs an rng"
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
