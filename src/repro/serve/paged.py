"""Paged KV-cache: a ref-counted block-pool allocator + the pooled arrays.

The slot engine reserves a contiguous ``max_len`` KV slab per slot, so HBM
is committed at admission for the *worst-case* sequence and short requests
strand most of it.  The paged subsystem (vLLM's PagedAttention model) cuts
KV into fixed-size **blocks** drawn from one shared pool:

  pools:        k, v  (L, P, Hkv, block_size, dh)   [+ k_fused (·, dh/G*)]
  block table:  per request, logical block j → physical pool block ids[j]
  invariant:    block 0 is a reserved GARBAGE block — never allocated, the
                write target for dead/padded lanes so their stores can't
                corrupt live KV.

``BlockPool`` is pure host-side bookkeeping (free list + ref counts);
``PagedKVCache`` owns the device arrays and the per-request tables and
provides the engine-facing operations:

  * ``allocate_to(uid, n_tokens)`` — grow a table to cover ``n_tokens``
    (admission / chunked prefill / decode growth), failing cleanly with
    ``PoolExhausted`` so the scheduler can preempt;
  * ``free(uid)`` — return a finished request's blocks (ref-counted:
    prefix-shared blocks survive until their last holder frees);
  * ``evict_to_host(uid)`` / ``restore(uid)`` — whole-request preemption:
    the request's live KV is copied to host numpy, its blocks freed, and
    later re-allocated + copied back — continuations are bit-identical;
  * ``share_prefix(src_uid, dst_uid, n_tokens)`` — optional shared-prefix
    reuse: the *full* blocks covering a common prompt prefix are ref-
    bumped into the new table instead of recomputed (shared blocks are
    never written again — only whole blocks are shared, and the dst's own
    tokens land in fresh blocks).

Per-layer pool slices ride the decode scan exactly like the contiguous
cache's ``(L, B, ...)`` stacks; block tables are shared across layers.
The kernel side is kernels/paged_decode.py; policy is serve/scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_decode import GARBAGE_BLOCK


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied; the scheduler reacts
    by preempting (whole-request eviction to host), never by crashing."""


class BlockPool:
    """Ref-counted fixed-size block allocator (host-side free list).

    Block ids are indices into the pooled device arrays.  Block
    ``GARBAGE_BLOCK`` (0) is reserved and never handed out.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("pool needs ≥ 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))  # pop() → low ids first
        self._refs = np.zeros((num_blocks,), np.int32)
        self._refs[GARBAGE_BLOCK] = 1  # permanently held

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """n fresh blocks (refcount 1) or ``PoolExhausted`` — all-or-nothing,
        so a partial grab never deadlocks two growing requests."""
        if n > len(self._free):
            raise PoolExhausted(f"need {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        return out

    def incref(self, block: int) -> None:
        if self._refs[block] <= 0:
            raise ValueError(f"incref of free block {block}")
        self._refs[block] += 1

    def free(self, block: int) -> None:
        if block == GARBAGE_BLOCK:
            return
        if self._refs[block] <= 0:
            raise ValueError(f"double free of block {block}")
        self._refs[block] -= 1
        if self._refs[block] == 0:
            self._free.append(block)

    def refcount(self, block: int) -> int:
        return int(self._refs[block])


# ---------------------------------------------------------------------------
# Pooled device arrays + per-request tables
# ---------------------------------------------------------------------------


def pool_struct(cfg, num_blocks: int, block_size: int, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree for the paged pools.  GQA families only: the
    paged layout replaces the (L, B, Hkv, S, dh) ring slabs; MLA/SSM/hybrid/
    enc-dec keep the slot engine (serve.kv_cache)."""
    if cfg.family not in ("dense", "moe") or cfg.use_mla:
        raise NotImplementedError(
            f"paged KV covers GQA dense/moe caches; family={cfg.family!r} "
            f"use_mla={cfg.use_mla} keeps the slot engine"
        )
    f = jax.ShapeDtypeStruct
    l, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    pools = {"v": f((l, num_blocks, hkv, block_size, dh), dtype)}
    # Mirror serve_step.make_paged_step's dispatch exactly: the fused path
    # only engages for dense — a moe config with distr_decode set still
    # runs (and pools) the raw-K path, like the slot engine's decode scan.
    if cfg.attention.distr_decode and cfg.family == "dense":
        # Fused-K̂ paged serving never reads OR writes raw K (chunked
        # prefill rides the fused decode kernel too), so unlike the slot
        # cache the raw K pool is dropped entirely — an extra
        # (1 − 1/G*)·½ of the *allocation*, not just the read stream.
        g = cfg.attention.distr.group_size
        pools["k_fused"] = f((l, num_blocks, hkv, block_size, dh // g), dtype)
    else:
        pools["k"] = f((l, num_blocks, hkv, block_size, dh), dtype)
    return pools


def init_pools(cfg, num_blocks: int, block_size: int, dtype=jnp.bfloat16) -> dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        pool_struct(cfg, num_blocks, block_size, dtype),
    )


@dataclass
class _Evicted:
    """Host copy of a preempted request's live KV (per pool key: numpy
    (L, width, Hkv, bs, dh*) gathered blocks in logical order, possibly
    garbage-padded to a fixed width — see evict_to_host)."""
    length: int
    blocks: dict = field(default_factory=dict)
    n_blocks: int = 0  # real (unpadded) table entries


class PagedKVCache:
    """Device pools + per-request block tables over a :class:`BlockPool`."""

    def __init__(self, cfg, num_blocks: int, block_size: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.pool = BlockPool(num_blocks, block_size)
        self.block_size = block_size
        self.pools = init_pools(cfg, num_blocks, block_size, dtype)
        self.tables: dict[int, list[int]] = {}  # uid → physical block ids
        self.evicted: dict[int, _Evicted] = {}
        # Shared (ref > 1 at share time) leading blocks are read-only for
        # their sharers; count per uid so eviction gathers only owned KV.
        self._shared_prefix: dict[int, int] = {}

    # -- allocation -----------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def allocate_to(self, uid: int, n_tokens: int) -> None:
        """Grow ``uid``'s table to cover ``n_tokens`` positions.  Raises
        ``PoolExhausted`` (table unchanged) when the pool can't satisfy it."""
        table = self.tables.setdefault(uid, [])
        need = self.blocks_for(n_tokens) - len(table)
        if need > 0:
            table.extend(self.pool.alloc(need))

    def free(self, uid: int) -> None:
        for b in self.tables.pop(uid, []):
            self.pool.free(b)
        self._shared_prefix.pop(uid, None)
        self.evicted.pop(uid, None)

    def table_array(self, uids, max_blocks: int) -> jnp.ndarray:
        """(len(uids), max_blocks) int32 padded block-table rows; absent /
        short tables pad with the garbage block."""
        out = np.full((len(uids), max_blocks), GARBAGE_BLOCK, np.int32)
        for i, uid in enumerate(uids):
            t = self.tables.get(uid, [])
            out[i, : len(t)] = t
        return jnp.asarray(out)

    # -- shared-prefix reuse -------------------------------------------

    def share_prefix(self, src_uid: int, dst_uid: int, n_tokens: int) -> int:
        """Seed ``dst``'s table with ``src``'s full blocks covering the first
        ``n_tokens`` positions (rounded *down* to whole blocks — partial
        blocks are still written by src's decode and are never shared).
        Returns the number of tokens actually covered; dst must start its
        prefill at that offset."""
        if self.tables.get(dst_uid):
            raise ValueError(f"dst {dst_uid} already has blocks")
        src = self.tables.get(src_uid, [])
        n_blocks = min(n_tokens // self.block_size, len(src))
        for b in src[:n_blocks]:
            self.pool.incref(b)
        self.tables[dst_uid] = list(src[:n_blocks])
        if n_blocks:
            self._shared_prefix[dst_uid] = n_blocks
        return n_blocks * self.block_size

    # -- preemption ----------------------------------------------------

    def evict_to_host(self, uid: int, length: int, *,
                      pad_to: int | None = None) -> None:
        """Copy ``uid``'s live blocks to host numpy and free them.  Every
        table entry is gathered (shared-prefix blocks included — restore
        simply writes them back as owned blocks).  ``pad_to`` pads the
        gather to a fixed table width with the garbage block so every
        evict/restore traces the SAME shapes — without it, each distinct
        block count jit-compiles a fresh gather/scatter pair (a visible
        first-preemption stall in serving)."""
        table = self.tables.get(uid)
        if not table:
            raise ValueError(f"uid {uid} holds no blocks")
        width = max(pad_to or 0, len(table))
        padded = table + [GARBAGE_BLOCK] * (width - len(table))
        idx = jnp.asarray(padded, jnp.int32)
        ev = _Evicted(length=length)
        ev.n_blocks = len(table)
        for key, pool in self.pools.items():
            # (L, width, Hkv, bs, dh*) in logical block order
            ev.blocks[key] = np.asarray(jnp.take(pool, idx, axis=1))
        self.evicted[uid] = ev
        for b in table:
            self.pool.free(b)
        del self.tables[uid]
        self._shared_prefix.pop(uid, None)

    def restore(self, uid: int) -> int:
        """Re-allocate and copy back an evicted request's KV; returns its
        live length.  Raises ``PoolExhausted`` with nothing allocated if the
        pool can't hold it yet.  Rows padded at eviction scatter back into
        the garbage block (content never read), keeping the write shape
        fixed too."""
        ev = self.evicted[uid]
        width = next(iter(ev.blocks.values())).shape[1]
        blocks = self.pool.alloc(ev.n_blocks)  # all-or-nothing
        padded = blocks + [GARBAGE_BLOCK] * (width - len(blocks))
        idx = jnp.asarray(padded, jnp.int32)
        for key in self.pools:
            self.pools[key] = self.pools[key].at[:, idx].set(
                jnp.asarray(ev.blocks[key], self.pools[key].dtype)
            )
        self.tables[uid] = blocks
        del self.evicted[uid]
        return ev.length
