"""Serving substrate: KV caches, prefill/decode steps, sampler, engine."""
from repro.serve import engine, kv_cache, sampler, serve_step

__all__ = ["engine", "kv_cache", "sampler", "serve_step"]
