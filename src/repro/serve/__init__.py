"""Serving substrate: KV caches (contiguous ring + paged block pool),
prefill/decode steps, sampler, engines, continuous-batching scheduler —
plus the robustness layer: request lifecycle statuses, deadline/shedding
policy, the graceful-degradation controller, and fault injection
(DESIGN.md §Robustness)."""
from repro.serve import (
    degrade,
    engine,
    faults,
    kv_cache,
    lifecycle,
    paged,
    sampler,
    scheduler,
    serve_step,
)

__all__ = [
    "degrade",
    "engine",
    "faults",
    "kv_cache",
    "lifecycle",
    "paged",
    "sampler",
    "scheduler",
    "serve_step",
]
