"""Serving substrate: KV caches (contiguous ring + paged block pool),
prefill/decode steps, sampler, engines, continuous-batching scheduler —
plus the robustness layer (request lifecycle statuses, deadline/shedding
policy, the graceful-degradation controller, fault injection; DESIGN.md
§Robustness) and the multi-replica cluster tier (health-aware router
with failover and draining; DESIGN.md §Cluster tier)."""
from repro.serve import (
    cluster,
    degrade,
    engine,
    faults,
    kv_cache,
    lifecycle,
    paged,
    sampler,
    scheduler,
    serve_step,
)

__all__ = [
    "cluster",
    "degrade",
    "engine",
    "faults",
    "kv_cache",
    "lifecycle",
    "paged",
    "sampler",
    "scheduler",
    "serve_step",
]
