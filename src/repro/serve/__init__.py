"""Serving substrate: KV caches (contiguous ring + paged block pool),
prefill/decode steps, sampler, engines, continuous-batching scheduler."""
from repro.serve import engine, kv_cache, paged, sampler, scheduler, serve_step

__all__ = ["engine", "kv_cache", "paged", "sampler", "scheduler", "serve_step"]
