"""Request lifecycle: the status state machine shared by both serve engines.

Every request submitted to an engine terminates in exactly one of the
terminal statuses below — the robustness contract the chaos suite
(tests/test_chaos.py) asserts under every injected fault.  Transitions
(DESIGN.md §Robustness):

    queued ──────► prefill ──► running ──► done
      │  ╲            │    ╲      │  ╲        (eos / max_new_tokens / full)
      │   ╲           │     ╲     │   └─► preempted ──► prefill/running
      │    ╲          │      ╲    │            (restore; bit-identical resume)
      │     ╲         ▼       ▼   ▼
      │      ╲     expired  failed ◄── numeric guard / watchdog /
      │       ╲    (deadline)          bounded-retry exhaustion
      │        └─► cancelled           (any non-terminal state)
      └──► rejected (bounded-queue load shedding at submit)

``done`` is the only *successful* terminal; ``Request.done`` (bool) keeps
meaning exactly that.  Non-terminal statuses are advisory (the scheduler
updates them for observability); terminal statuses are authoritative and
never overwritten.
"""
from __future__ import annotations

# -- non-terminal -----------------------------------------------------------
QUEUED = "queued"  # submitted, waiting for admission
PREFILL = "prefill"  # prompt (partially) prefilled, not yet decoding
RUNNING = "running"  # decoding on a lane / slot
PREEMPTED = "preempted"  # KV evicted to host, awaiting restore

# -- terminal ---------------------------------------------------------------
DONE = "done"  # completed normally (eos / max_new_tokens / capacity)
REJECTED = "rejected"  # load-shed at submission (bounded waiting queue)
EXPIRED = "expired"  # missed its TTFT or end-to-end deadline
CANCELLED = "cancelled"  # explicit cancel(uid)
FAILED = "failed"  # numeric guard / watchdog / retry exhaustion

TERMINAL = frozenset({DONE, REJECTED, EXPIRED, CANCELLED, FAILED})


def is_terminal(status: str) -> bool:
    return status in TERMINAL


# -- frozen observability schema --------------------------------------------
# The cluster router's health model (serve.cluster) reads these dicts from
# every replica; silent key drift between the engines would blind it.  Both
# engines and the scheduler snapshot against THIS key set (zero-filled), and
# tests/test_cluster.py freezes it with a regression test.  Adding a counter
# means adding it here, on purpose.

#: Robustness counters common to ServeEngine, PagedServeEngine, Scheduler.
COUNTER_KEYS = (
    "shed",  # load-shed at submission (bounded waiting queue)
    "expired",  # missed a TTFT / e2e deadline
    "cancelled",  # explicit cancel(uid)
    "failed_numeric",  # non-finite logits quarantined
    "failed_fault",  # step/restore retry budget exhausted
    "step_retries",  # faulting model steps retried in place
    "restore_retries",  # faulting restores retried with backoff
    "watchdog_fails",  # global-stall watchdog fired
    "degraded_prefills",  # prompts served under coarser grouping
    "mesh_prefills",  # whole-prompt ring prefills (mesh one-tick admission)
)

#: Per-request metrics() row keys shared by both engines and the scheduler.
METRIC_KEYS = (
    "uid", "ttft_s", "tpot_s", "n_generated", "n_preemptions", "status",
    "degrade_group",
)


def counters_view(counters) -> dict:
    """Freeze a Counter/dict into the canonical zero-filled schema."""
    return {k: int(counters.get(k, 0)) for k in COUNTER_KEYS}


class IncompleteRun(RuntimeError):
    """``run_to_completion(max_steps)`` exhausted its step budget with
    requests still in flight.  Raised instead of returning silently so a
    hung or livelocked engine can never masquerade as success; ``uids``
    lists the in-flight requests by uid."""

    def __init__(self, uids: list[int], max_steps: int):
        self.uids = list(uids)
        self.max_steps = max_steps
        super().__init__(
            f"run_to_completion exhausted {max_steps} steps with "
            f"{len(self.uids)} request(s) still in flight (uids "
            f"{self.uids}); raise max_steps or investigate a stall"
        )
