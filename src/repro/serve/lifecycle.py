"""Request lifecycle: the status state machine shared by both serve engines.

Every request submitted to an engine terminates in exactly one of the
terminal statuses below — the robustness contract the chaos suite
(tests/test_chaos.py) asserts under every injected fault.  Transitions
(DESIGN.md §Robustness):

    queued ──────► prefill ──► running ──► done
      │  ╲            │    ╲      │  ╲        (eos / max_new_tokens / full)
      │   ╲           │     ╲     │   └─► preempted ──► prefill/running
      │    ╲          │      ╲    │            (restore; bit-identical resume)
      │     ╲         ▼       ▼   ▼
      │      ╲     expired  failed ◄── numeric guard / watchdog /
      │       ╲    (deadline)          bounded-retry exhaustion
      │        └─► cancelled           (any non-terminal state)
      └──► rejected (bounded-queue load shedding at submit)

``done`` is the only *successful* terminal; ``Request.done`` (bool) keeps
meaning exactly that.  Non-terminal statuses are advisory (the scheduler
updates them for observability); terminal statuses are authoritative and
never overwritten.
"""
from __future__ import annotations

# -- non-terminal -----------------------------------------------------------
QUEUED = "queued"  # submitted, waiting for admission
PREFILL = "prefill"  # prompt (partially) prefilled, not yet decoding
RUNNING = "running"  # decoding on a lane / slot
PREEMPTED = "preempted"  # KV evicted to host, awaiting restore

# -- terminal ---------------------------------------------------------------
DONE = "done"  # completed normally (eos / max_new_tokens / capacity)
REJECTED = "rejected"  # load-shed at submission (bounded waiting queue)
EXPIRED = "expired"  # missed its TTFT or end-to-end deadline
CANCELLED = "cancelled"  # explicit cancel(uid)
FAILED = "failed"  # numeric guard / watchdog / retry exhaustion

TERMINAL = frozenset({DONE, REJECTED, EXPIRED, CANCELLED, FAILED})


def is_terminal(status: str) -> bool:
    return status in TERMINAL


class IncompleteRun(RuntimeError):
    """``run_to_completion(max_steps)`` exhausted its step budget with
    requests still in flight.  Raised instead of returning silently so a
    hung or livelocked engine can never masquerade as success; ``uids``
    lists the in-flight requests by uid."""

    def __init__(self, uids: list[int], max_steps: int):
        self.uids = list(uids)
        self.max_steps = max_steps
        super().__init__(
            f"run_to_completion exhausted {max_steps} steps with "
            f"{len(self.uids)} request(s) still in flight (uids "
            f"{self.uids}); raise max_steps or investigate a stall"
        )
