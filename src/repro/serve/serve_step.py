"""Serving steps: prefill (build cache from a full forward) and one-token
decode, per architecture family.  Both are pure functions of (params, cache)
so they jit cleanly under the production mesh — the decode shapes of the
dry-run lower ``decode_step`` exactly as defined here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers, lm, transformer
from repro.models.attention import _split_heads
from repro.serve import kv_cache


def _compute_dtype(cfg):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _pad_seq_to(x: jnp.ndarray, max_len: int, axis: int) -> jnp.ndarray:
    pad = max_len - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def make_prefill(cfg, max_len: int, backbone_cfg=None):
    """→ prefill(params, tokens, patches=None, frames=None) → (logits, cache).

    logits: (B, 1, V) for the last position; cache: ready for decode at
    position = prompt length.

    ``backbone_cfg`` (default ``cfg``) drives the forward pass alone — the
    graceful-degradation path (serve.degrade) passes
    ``cfg.attention.degraded(G*)`` here so prefill attention runs
    DistrAttention at a coarser grouping while the *cache layout* (dtypes,
    fused-K̂ width, ring convention) stays exactly the engine's own:
    approximation enters only through the degraded hidden states, decode is
    untouched.
    """
    bcfg = cfg if backbone_cfg is None else backbone_cfg

    def prefill(params, tokens, patches=None, frames=None):
        hidden, _aux, parts, n_prefix = lm.backbone(
            params, bcfg, tokens, patches=patches, frames=frames,
            collect_cache=True,
        )
        logits = lm.logits_fn(params, cfg, hidden[:, -1:])
        cache: dict = {}
        dtype = _compute_dtype(cfg)

        if cfg.family in ("dense", "moe") and not cfg.use_mla:
            k, v = parts["kv"]  # (L, B, Hkv, S, dh)
            cache["k"] = _pad_seq_to(k.astype(dtype), max_len, 3)
            cache["v"] = _pad_seq_to(v.astype(dtype), max_len, 3)
            # Per-slot live length: the whole prompt is live after prefill.
            # The engine overrides this for right-padded prompts.
            cache["length"] = jnp.full(
                (tokens.shape[0],), k.shape[3], jnp.int32
            )
            if cfg.attention.distr_decode:
                from repro.core import grouping

                g = cfg.attention.distr.group_size
                perms = kv_cache.static_perms(cfg)  # (L, Hkv, dh)
                # (L, 1, Hkv, dh) broadcasts over batch & seq inside fuse.
                cache["k_fused"] = grouping.fuse_columns(
                    cache["k"].astype(jnp.float32), perms[:, None], g
                )
        elif cfg.use_mla:
            ckv, krope = parts["kv"]  # (L,B,S,C), (L,B,1,S,R)
            cache["ckv"] = _pad_seq_to(ckv.astype(dtype), max_len, 2)
            cache["krope"] = _pad_seq_to(krope[:, :, 0].astype(dtype), max_len, 2)
        elif cfg.family == "ssm":
            conv, ssm = parts["ssm"]
            cache["conv"] = conv.astype(dtype)
            cache["ssm"] = ssm
        elif cfg.family == "hybrid":
            conv_g, ssm_g = parts["ssm_groups"]
            sk, sv = parts["shared_kv"]
            cache["groups_conv"] = conv_g.astype(dtype)
            cache["groups_ssm"] = ssm_g
            cache["shared_k"] = _pad_seq_to(sk.astype(dtype), max_len, 3)
            cache["shared_v"] = _pad_seq_to(sv.astype(dtype), max_len, 3)
            if parts.get("ssm_tail") is not None:
                conv_t, ssm_t = parts["ssm_tail"]
                cache["tail_conv"] = conv_t.astype(dtype)
                cache["tail_ssm"] = ssm_t
        elif cfg.family == "encdec":
            k, v = parts["kv"]
            cache["k"] = _pad_seq_to(k.astype(dtype), max_len, 3)
            cache["v"] = _pad_seq_to(v.astype(dtype), max_len, 3)
            enc_out = parts["enc_out"]

            def cross_kv(block_params):
                ck = _split_heads(
                    layers.linear_apply(block_params["cross_attn"]["wk"], enc_out),
                    cfg.n_kv_heads,
                )
                cv = _split_heads(
                    layers.linear_apply(block_params["cross_attn"]["wv"], enc_out),
                    cfg.n_kv_heads,
                )
                return ck.astype(dtype), cv.astype(dtype)

            ck, cv = jax.vmap(cross_kv)(params["blocks"])
            cache["cross_k"] = _pad_seq_to(ck, cfg.cross_len, 3)[:, :, :, : cfg.cross_len]
            cache["cross_v"] = _pad_seq_to(cv, cfg.cross_len, 3)[:, :, :, : cfg.cross_len]
            cache["cross_len"] = jnp.full(
                (tokens.shape[0],), min(enc_out.shape[1], cfg.cross_len), jnp.int32
            )
        return logits, cache

    return prefill


def _make_paged_full_prefill(cfg, backbone_cfg):
    """Shared whole-prompt paged prefill body: one backbone forward under
    ``backbone_cfg``, last-live-row logits, and a scatter of every layer's
    K/V into the request's pool blocks through the block table
    (``models.attention.paged_insert``; padded rows divert to the garbage
    block).  The fused K̂ — when the engine decodes fused — is always
    written at the engine's ORIGINAL group size from its static per-layer
    permutations, whatever attention ``backbone_cfg`` ran: the cache
    layout belongs to the engine, the forward pass to the caller."""
    if cfg.family not in ("dense", "moe") or cfg.use_mla:
        raise NotImplementedError(
            f"paged serving covers GQA dense/moe; family={cfg.family!r} "
            f"use_mla={cfg.use_mla} keeps the slot engine"
        )
    from repro.models.attention import paged_insert

    fused = cfg.attention.distr_decode and cfg.family == "dense"

    def prefill(params, tokens, n, pools, block_tables):
        hidden, _aux, parts, _ = lm.backbone(
            params, backbone_cfg, tokens, collect_cache=True
        )
        # Exact last-live-position logits: causal attention means padded
        # rows past n-1 never feed row n-1 (the LSH permutations of the
        # row's block may see padding — an approximation the degraded path
        # already accepts).
        h_last = jnp.take(hidden, n - 1, axis=1)  # (1, 1, d)
        logits = lm.logits_fn(params, cfg, h_last)[0, 0]
        k, v = parts["kv"]  # (L, 1, Hkv, bucket, dh)
        pos0 = jnp.zeros((1,), jnp.int32)
        insert = jax.vmap(paged_insert, in_axes=(0, 0, None, None, None))
        new_pools = dict(pools)
        new_pools["v"] = insert(pools["v"], v, block_tables, pos0, n)
        if fused:
            from repro.core import grouping

            g = cfg.attention.distr.group_size
            perms = kv_cache.static_perms(cfg)  # (L, Hkv, dh)
            k_f = grouping.fuse_columns(
                k.astype(jnp.float32), perms[:, None], g
            )
            new_pools["k_fused"] = insert(
                pools["k_fused"], k_f, block_tables, pos0, n
            )
        else:
            new_pools["k"] = insert(pools["k"], k, block_tables, pos0, n)
        return logits, new_pools

    return prefill


def make_degraded_paged_prefill(cfg, bucket: int, group_size: int):
    """→ prefill(params, tokens (1, bucket), n (1,), pools, block_tables)
    → (last-live-row logits (V,), pools).

    The graceful-degradation prefill (serve.degrade): under sustained
    overload the scheduler trades chunked *exact* prefill for one
    whole-prompt forward whose attention runs DistrAttention at grouping
    fraction 1/``group_size`` (``core.api.AttentionConfig.degraded`` — the
    paper's accuracy↔speed dial), then scatters the resulting K/V into the
    request's pool blocks through the block table.  One step replaces
    ``ceil(n / prefill_chunk)`` chunk steps — TTFT under pressure drops to
    a single tick — at an attributable accuracy cost recorded per request
    (``Request.degrade_group``).

    The KV written is the backbone's own K/V (same convention as the exact
    paths); approximation enters only through the degraded attention's
    effect on the hidden states, so decode continues on the standard paged
    kernels untouched.
    """
    del bucket  # shapes ride on ``tokens``; the engine keys its jit cache
    dcfg = cfg.replace(attention=cfg.attention.degraded(group_size))
    return _make_paged_full_prefill(cfg, dcfg)


def make_mesh_paged_prefill(cfg, bucket: int):
    """→ prefill(params, tokens (1, bucket), n (1,), pools, block_tables)
    → (last-live-row logits (V,), pools).

    The mesh-capable whole-prompt prefill (paged × ring composition): the
    returned function is *traced under the engine's context mesh* —
    ``PagedServeEngine(mesh=)`` wraps the jitted call in ``maybe_set_mesh``
    — so the backbone's attention dispatches through ``core.api.attend`` to
    the ring (``distributed.ring_attention``) whenever the padded bucket
    spans at least ``ring_size × MIN_RING_SHARD`` tokens.  One long prompt
    prefills across the whole ring in a single step; GSPMD gathers each
    layer's K/V back to global arrays at the shard_map boundary, and the
    scatter lands them in ONE device's block pool through the block table —
    the prefill is distributed, the decode-side KV residency is not.

    The forward runs the engine's own *exact* attention config (no
    degradation); the fused K̂ is written at the original group size — the
    same invariant as the degraded prefill — so decode continues on the
    standard paged kernels untouched.
    """
    del bucket  # shapes ride on ``tokens``; the engine keys its jit cache
    return _make_paged_full_prefill(cfg, cfg)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def make_paged_step(cfg, width: int):
    """→ paged_step(params, tokens (B, width), pools, block_tables, pos,
    count) → (logits (B, width, V), pools).

    One jitted tick of the *paged* serve path (serve.paged pools +
    kernels/paged_decode.py): ``width = 1`` is the batched decode tick,
    ``width = chunk`` is one chunked-prefill window — both are the same
    banded windowed-decode computation, so chunked prefill runs on the
    decode kernel instead of a separate full-attention prefill graph.

    tokens: (B, width) int32 (right-padded); pos: (B,) absolute start
    positions; count: (B,) live tokens per row (padding writes are
    redirected to the garbage block and padded logits are ignored by the
    caller).  GQA dense/moe families only — the other families keep the
    slot engine's contiguous caches.
    """
    if cfg.family not in ("dense", "moe") or cfg.use_mla:
        raise NotImplementedError(
            f"paged serving covers GQA dense/moe; family={cfg.family!r} "
            f"use_mla={cfg.use_mla} keeps the slot engine"
        )
    fused = cfg.attention.distr_decode and cfg.family == "dense"

    def paged_step(params, tokens, pools, block_tables, pos, count):
        compute = _compute_dtype(cfg)
        x = layers.embedding_apply(params["embed"], tokens, compute)
        if cfg.pos == "learned":
            positions = pos[:, None] + jnp.arange(width)[None, :]
            x = x + layers.embedding_apply(
                params["pos_embed"], positions, compute
            )
        new_pools = dict(pools)

        if fused:
            perms = kv_cache.static_perms(cfg)  # (L, Hkv, dh)

            def body_f(h, inputs):
                lp, v_l, kf_l, perm_l = inputs
                h, (_, pv, pkf) = transformer.block_paged_decode_apply(
                    lp, h, cfg, "dense",
                    pool_k=None, pool_v=v_l, block_tables=block_tables,
                    pos=pos, count=count, pool_k_fused=kf_l, perm=perm_l,
                )
                return h, (pv, pkf)

            x, (vs, kfs) = jax.lax.scan(
                body_f, x,
                (params["blocks"], pools["v"], pools["k_fused"], perms),
            )
            new_pools.update(v=vs, k_fused=kfs)
        else:

            def make_body(layer_type):
                def body(h, inputs):
                    lp, k_l, v_l = inputs
                    h, (pk, pv, _) = transformer.block_paged_decode_apply(
                        lp, h, cfg, layer_type,
                        pool_k=k_l, pool_v=v_l, block_tables=block_tables,
                        pos=pos, count=count,
                    )
                    return h, (pk, pv)

                return body

            if cfg.family == "moe" and cfg.first_dense_layers:
                fd = cfg.first_dense_layers
                x, (kd, vd) = jax.lax.scan(
                    make_body("dense"), x,
                    (params["dense_blocks"], pools["k"][:fd], pools["v"][:fd]),
                )
                x, (km, vm) = jax.lax.scan(
                    make_body("moe"), x,
                    (params["blocks"], pools["k"][fd:], pools["v"][fd:]),
                )
                new_pools["k"] = jnp.concatenate([kd, km], axis=0)
                new_pools["v"] = jnp.concatenate([vd, vm], axis=0)
            else:
                layer_type = "moe" if cfg.family == "moe" else "dense"
                x, (ks, vs) = jax.lax.scan(
                    make_body(layer_type), x,
                    (params["blocks"], pools["k"], pools["v"]),
                )
                new_pools.update(k=ks, v=vs)

        x = transformer.norm_apply(params["final_norm"], x, cfg)
        logits = lm.logits_fn(params, cfg, x)
        return logits, new_pools

    return paged_step


def make_decode_step(cfg):
    """→ decode_step(params, tokens (B,1), cache, pos (B,)) → (logits, cache)."""

    def decode_step(params, tokens, cache, pos):
        compute = _compute_dtype(cfg)
        b = tokens.shape[0]
        x = layers.embedding_apply(params["embed"], tokens, compute)
        if cfg.pos == "learned":
            x = x + layers.embedding_apply(
                params["pos_embed"], pos[:, None], compute
            )

        if cfg.family in ("dense", "moe") and not cfg.use_mla:
            new_cache = dict(cache)
            max_len = cache["k"].shape[3]
            # Length-aware decode: the total token count (incl. the token
            # being decoded) bounds every layer's KV walk — the kernels
            # stream ceil(length/block_k) blocks, not max_len.
            total = jnp.maximum(cache["length"], pos + 1)
            length = jnp.minimum(total, max_len)
            new_cache["length"] = total
            if cfg.family == "moe" and cfg.first_dense_layers:
                fd = cfg.first_dense_layers

                def body_d(h, inputs):
                    lp, k_l, v_l = inputs
                    h, nc = transformer.block_decode_apply(
                        lp, h, cfg, "dense",
                        cache={"k": k_l, "v": v_l}, cache_index=pos,
                        length=length,
                    )
                    return h, (nc["k"], nc["v"])

                x, (kd, vd) = jax.lax.scan(
                    body_d, x,
                    (params["dense_blocks"], cache["k"][:fd], cache["v"][:fd]),
                )
                layer_type = "moe"

                def body_m(h, inputs):
                    lp, k_l, v_l = inputs
                    h, nc = transformer.block_decode_apply(
                        lp, h, cfg, layer_type,
                        cache={"k": k_l, "v": v_l}, cache_index=pos,
                        length=length,
                    )
                    return h, (nc["k"], nc["v"])

                x, (km, vm) = jax.lax.scan(
                    body_m, x, (params["blocks"], cache["k"][fd:], cache["v"][fd:])
                )
                new_cache["k"] = jnp.concatenate([kd, km], axis=0)
                new_cache["v"] = jnp.concatenate([vd, vm], axis=0)
            elif cfg.attention.distr_decode and cfg.family == "dense":
                # Beyond-paper fused-K̂ decode: the score stage reads the
                # d/G*-wide fused cache (see models.attention).
                from repro.models.attention import attention_decode_fused
                from repro.models.transformer import norm_apply

                perms = kv_cache.static_perms(cfg)  # (L, Hkv, dh)

                # The raw K cache is NOT streamed through the decode scan:
                # the score stage reads only K̂ (+V).  Raw K stays as-is in
                # the cache dict (stale for decode; re-fused at prefill) —
                # this is where the (1-1/G*)·½ KV-read saving comes from.
                def body_f(h, inputs):
                    lp, v_l, kf_l, perm_l = inputs
                    hn = norm_apply(lp["norm1"], h, cfg)
                    o, (_, v2, kf2) = attention_decode_fused(
                        lp["attn"], hn, cfg,
                        cache_k=None, cache_v=v_l, cache_k_fused=kf_l,
                        perm=perm_l, cache_index=pos, length=length,
                    )
                    h = h + o
                    h2 = norm_apply(lp["norm2"], h, cfg)
                    h = h + layers.mlp_apply(lp["ffn"], h2, act=cfg.act)
                    return h, (v2, kf2)

                x, (vs, kfs) = jax.lax.scan(
                    body_f, x,
                    (params["blocks"], cache["v"], cache["k_fused"], perms),
                )
                new_cache.update(v=vs, k_fused=kfs)
            else:
                layer_type = "moe" if cfg.family == "moe" else "dense"

                def body(h, inputs):
                    lp, k_l, v_l = inputs
                    h, nc = transformer.block_decode_apply(
                        lp, h, cfg, layer_type,
                        cache={"k": k_l, "v": v_l}, cache_index=pos,
                        length=length,
                    )
                    return h, (nc["k"], nc["v"])

                x, (ks, vs) = jax.lax.scan(
                    body, x, (params["blocks"], cache["k"], cache["v"])
                )
                new_cache["k"], new_cache["v"] = ks, vs
        elif cfg.use_mla:
            new_cache = dict(cache)
            fd = cfg.first_dense_layers

            # dense prefix
            def body_mla_dense(h, inputs):
                lp, ckv_l, kr_l = inputs
                h, nc = transformer.block_decode_apply(
                    lp, h, cfg, "dense",
                    cache={"ckv": ckv_l, "krope": kr_l}, cache_index=pos,
                )
                return h, (nc["ckv"], nc["krope"])

            parts_ckv, parts_kr = [], []
            if fd:
                x, (c1, r1) = jax.lax.scan(
                    body_mla_dense, x,
                    (params["dense_blocks"], cache["ckv"][:fd], cache["krope"][:fd]),
                )
                parts_ckv.append(c1)
                parts_kr.append(r1)

            def body_mla_moe(h, inputs):
                lp, ckv_l, kr_l = inputs
                h, nc = transformer.block_decode_apply(
                    lp, h, cfg, "moe",
                    cache={"ckv": ckv_l, "krope": kr_l}, cache_index=pos,
                )
                return h, (nc["ckv"], nc["krope"])

            x, (c2, r2) = jax.lax.scan(
                body_mla_moe, x,
                (params["blocks"], cache["ckv"][fd:], cache["krope"][fd:]),
            )
            parts_ckv.append(c2)
            parts_kr.append(r2)
            new_cache["ckv"] = (
                jnp.concatenate(parts_ckv, axis=0) if fd else parts_ckv[0]
            )
            new_cache["krope"] = (
                jnp.concatenate(parts_kr, axis=0) if fd else parts_kr[0]
            )
        elif cfg.family == "ssm":

            def body_ssm(h, inputs):
                lp, conv_l, ssm_l = inputs
                h, nc = transformer.block_decode_apply(
                    lp, h, cfg, "mamba",
                    cache={"conv": conv_l, "ssm": ssm_l}, cache_index=pos,
                )
                return h, (nc["conv"], nc["ssm"])

            x, (convs, ssms) = jax.lax.scan(
                body_ssm, x, (params["blocks"], cache["conv"], cache["ssm"])
            )
            new_cache = {"conv": convs, "ssm": ssms}
        elif cfg.family == "hybrid":
            x0 = x
            nsb = cfg.n_shared_attn_blocks

            def mamba_body(h, inputs):
                lp, conv_l, ssm_l = inputs
                h, nc = transformer.block_decode_apply(
                    lp, h, cfg, "mamba",
                    cache={"conv": conv_l, "ssm": ssm_l}, cache_index=pos,
                )
                return h, (nc["conv"], nc["ssm"])

            shared_fns = [
                functools.partial(
                    transformer.shared_block_decode_apply, sp, cfg=cfg
                )
                for sp in params["shared"]
            ]

            def group_body(h, inputs):
                gp, conv_g, ssm_g, sk, sv, gi = inputs
                h, (conv_n, ssm_n) = jax.lax.scan(
                    mamba_body, h, (gp, conv_g, ssm_g)
                )
                h, kv_n = jax.lax.switch(
                    gi % nsb,
                    [
                        lambda hh, fn=fn: fn(
                            hh, x0, cache={"k": sk, "v": sv}, cache_index=pos
                        )
                        for fn in shared_fns
                    ],
                    h,
                )
                return h, (conv_n, ssm_n, kv_n["k"], kv_n["v"])

            n_groups, n_tail = kv_cache._hybrid_layout(cfg)
            x, (conv_g, ssm_g, sks, svs) = jax.lax.scan(
                group_body, x,
                (
                    params["groups"], cache["groups_conv"], cache["groups_ssm"],
                    cache["shared_k"], cache["shared_v"], jnp.arange(n_groups),
                ),
            )
            new_cache = dict(cache)
            new_cache.update(
                groups_conv=conv_g, groups_ssm=ssm_g, shared_k=sks, shared_v=svs
            )
            if n_tail:
                x, (conv_t, ssm_t) = jax.lax.scan(
                    mamba_body, x,
                    (params["tail"], cache["tail_conv"], cache["tail_ssm"]),
                )
                new_cache.update(tail_conv=conv_t, tail_ssm=ssm_t)
        elif cfg.family == "encdec":
            cross_len = cache["cross_len"]

            def body_ed(h, inputs):
                lp, k_l, v_l, ck_l, cv_l = inputs
                h, nc = transformer.block_decode_apply(
                    lp, h, cfg, "dense",
                    cache={"k": k_l, "v": v_l, "cross_k": ck_l, "cross_v": cv_l},
                    cache_index=pos, cross_len=cross_len,
                )
                return h, (nc["k"], nc["v"])

            x, (ks, vs) = jax.lax.scan(
                body_ed, x,
                (params["blocks"], cache["k"], cache["v"],
                 cache["cross_k"], cache["cross_v"]),
            )
            new_cache = dict(cache)
            new_cache.update(k=ks, v=vs)
        else:
            raise ValueError(cfg.family)

        x = transformer.norm_apply(params["final_norm"], x, cfg)
        logits = lm.logits_fn(params, cfg, x)
        return logits, new_cache

    return decode_step
