"""repro — DistrAttention (Jin et al., 2025) as a production JAX/TPU framework.

Layers: core (the paper's algorithm) · kernels (Pallas TPU) · models ·
configs · distributed · train · serve · launch · roofline.
"""

__version__ = "1.0.0"
