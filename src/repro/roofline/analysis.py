"""Roofline extraction from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh) cell, all **per-chip** (the SPMD
partitioned module reports per-device shapes/FLOPs):

  compute    = flops_per_dev / PEAK_FLOPS
  memory     = hbm_bytes_per_dev / HBM_BW
  collective = collective_operand_bytes_per_dev / ICI_BW

``collective_bytes`` parses the post-partitioning HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
and sums operand sizes (per-device, per spec).  MODEL_FLOPS = 6·N_active·D
(2·N_active·D for inference) measures how much compiled compute is "useful".
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


# ---------------------------------------------------------------------------
# HLO cost walker.
#
# XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
# undercounts scanned-layer models by ~n_layers (verified empirically).  The
# dry-run therefore walks the optimized HLO text itself: per-computation
# flops / HBM-byte / collective totals, propagated through the call graph
# with ``known_trip_count`` multipliers on while ops.  All shapes in the
# SPMD-partitioned module are per-device, so every total is per-chip.
#
# Bytes model (documented bias): output bytes of every materialising op plus
# operand bytes of dot/fusion/collective/scatter/gather — i.e. each tensor is
# written once and read where consumed by a heavy op.  Fusion internals are
# excluded (XLA fused them precisely so they don't touch HBM).
# ---------------------------------------------------------------------------

# `<name> = <type> <op>(...)`; <type> may be a tuple with /*index=N*/
# comments, so match lazily up to the first `word(` — ops never appear
# inside type strings.
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
_BYTES_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "broadcast", "reshape", "convert", "copy",
    "transpose",
}


def _parse_shapes(s: str) -> int:
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(s))


def _split_args(s: str) -> list[str]:
    """Split an HLO operand list on top-level commas only — operand types
    like ``f32[128,128]{1,0}`` carry commas inside brackets/braces."""
    args, buf, depth = [], "", 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            args.append(buf)
            buf = ""
        else:
            buf += ch
    if buf.strip():
        args.append(buf)
    return [a.strip() for a in args if a.strip()]


class _Comp:
    __slots__ = ("flops", "bytes", "coll", "calls", "dus_root_bytes", "root_op")

    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {op: 0.0 for op in COLLECTIVE_OPS}
        self.calls: list[tuple[str, object]] = []  # (callee, mult | ("fusion", out_bytes))
        # If this computation's ROOT is a dynamic-update-slice, fusions
        # calling it are in-place: traffic = the update slice, not the buffer.
        self.dus_root_bytes: float | None = None
        self.root_op: str | None = None


def _split_computations(txt: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for raw in txt.splitlines():
        if raw and not raw[0].isspace() and "{" in raw and ("->" in raw or raw.startswith("ENTRY")):
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)", raw.strip())
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
            continue
        if raw.strip() == "}":
            current = None
            continue
        if current is not None and raw.strip():
            comps[current].append(raw.strip())
    return comps, entry


def _analyze_computation(lines: list[str]) -> _Comp:
    shapes: dict[str, str] = {}
    c = _Comp()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        shapes[name] = shape_str
        out_bytes = _parse_shapes(shape_str)
        if line.startswith("ROOT"):
            c.root_op = op
        # operand list: text after the op's '(' up to the matching ')'
        tail = line[m.end():]
        depth = 1
        arglist = []
        buf = ""
        for ch in tail:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    arglist.append(buf)
                    break
            if depth >= 1:
                buf += ch
        # Operands may be bare names (`%x`) or typed (`f32[8,8]{1,0} %x`)
        # depending on the XLA version; resolve each to (name, shape_str).
        raw_args = _split_args(arglist[0]) if arglist else []
        args = []
        arg_shapes = []
        for a in raw_args:
            name = a.split()[-1].lstrip("%")
            args.append(name)
            arg_shapes.append(a if _SHAPE_RE.search(a) else shapes.get(name, ""))

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
            opnd = sum(_parse_shapes(a) for a in arg_shapes)
            c.coll[base_op] += opnd if opnd else out_bytes
            c.bytes += out_bytes
            continue
        if op.endswith("-done"):
            continue

        if op in ("dot", "dot_general", "convolution"):
            out_elems = out_bytes / max(
                _DTYPE_BYTES.get(_SHAPE_RE.search(shape_str).group(1), 4), 1
            ) if _SHAPE_RE.search(shape_str) else 0
            contract = 1
            mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            lhs_shape = arg_shapes[0] if arg_shapes else ""
            lhs_dims = _SHAPE_RE.search(lhs_shape)
            if mdims and lhs_dims and lhs_dims.group(2):
                dims = [int(x) for x in lhs_dims.group(2).split(",")]
                for di in mdims.group(1).split(","):
                    if di != "":
                        contract *= dims[int(di)]
            c.flops += 2.0 * out_elems * contract
            c.bytes += out_bytes + sum(_parse_shapes(a) for a in arg_shapes)
            continue

        if op == "fusion":
            mc = re.search(r"calls=%?([\w\.\-]+)", line)
            if mc:
                # Write bytes resolved at the call site in hlo_cost (root-
                # aware: in-place DUS-root fusions count the slice only).
                c.calls.append((mc.group(1), ("fusion", out_bytes)))
            else:
                c.bytes += out_bytes
            continue
        if op == "while":
            trip = 1.0
            mt = re.search(r'known_trip_count[^0-9]*(\d+)', line)
            if mt:
                trip = float(mt.group(1))
            for attr in ("body", "condition"):
                mb = re.search(attr + r"=%?([\w\.\-]+)", line)
                if mb:
                    c.calls.append((mb.group(1), trip))
            continue
        if op in ("call", "async-start"):
            mb = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if mb:
                c.calls.append((mb.group(1), 1.0))
            continue
        if op == "conditional":
            mb = re.search(r"branch_computations=\{([^}]*)\}", line)
            branches = []
            if mb:
                branches = [b.strip().lstrip("%") for b in mb.group(1).split(",")]
            else:
                for attr in ("true_computation", "false_computation"):
                    ma = re.search(attr + r"=%?([\w\.\-]+)", line)
                    if ma:
                        branches.append(ma.group(1))
            # exactly one branch runs; charge the mean
            for bname in branches:
                c.calls.append((bname, 1.0 / max(len(branches), 1)))
            continue

        if op == "dynamic-update-slice":
            # In-place aliased by XLA: traffic = the update slice, not the
            # full buffer (which would overcount scan stacking by ×trips).
            upd = (
                2 * _parse_shapes(arg_shapes[1]) if len(arg_shapes) >= 2 else 0
            )
            c.bytes += upd
            if line.startswith("ROOT"):
                c.dus_root_bytes = float(upd)
            continue
        if op == "dynamic-slice":
            c.bytes += 2 * out_bytes  # read slice + write result
            continue
        if op == "scatter":
            # In-place on TPU (operand aliased to output): traffic = the
            # touched rows (read-modify-write of updates), not the buffer —
            # KV-cache inserts would otherwise count the full cache/layer.
            upd = _parse_shapes(arg_shapes[-1]) if arg_shapes else 0
            c.bytes += 3 * (upd or out_bytes // 16)
            continue
        if op == "gather":
            c.bytes += 2 * out_bytes  # read gathered rows + write result
            continue
        if op in ("sort", "reduce", "reduce-window", "select-and-scatter",
                  "custom-call"):
            c.bytes += out_bytes + sum(_parse_shapes(a) for a in arg_shapes)
            continue
        if op in ("pad", "concatenate", "slice"):
            c.bytes += out_bytes
            continue
        if op not in _BYTES_SKIP_OPS:
            c.bytes += out_bytes
    return c


def hlo_cost(txt: str) -> dict:
    """Per-device {flops, bytes, coll{op: bytes}} with trip-count scaling."""
    comps, entry = _split_computations(txt)
    analyzed = {name: _analyze_computation(lines) for name, lines in comps.items()}
    memo: dict[str, tuple[float, float, dict]] = {}

    # fusion computations: flops recurse, bytes do NOT (fused = no HBM)
    def total(name: str, as_fusion: bool) -> tuple[float, float, dict]:
        key = name + ("#f" if as_fusion else "")
        if key in memo:
            return memo[key]
        comp = analyzed.get(name)
        if comp is None:
            return 0.0, 0.0, {}
        fl = comp.flops
        by = 0.0 if as_fusion else comp.bytes
        co = dict(comp.coll)
        memo[key] = (fl, by, co)  # provisional (cycle guard)
        for callee, mult in comp.calls:
            is_fusion_call = isinstance(mult, tuple) and mult[0] == "fusion"
            m = 1.0 if is_fusion_call else float(mult)
            cf, cb, cc = total(callee, is_fusion_call)
            fl += m * cf
            by += m * cb
            if is_fusion_call:
                callee_comp = analyzed.get(callee)
                if callee_comp is not None and callee_comp.dus_root_bytes is not None:
                    by += callee_comp.dus_root_bytes
                elif callee_comp is not None and callee_comp.root_op in (
                    "convert", "copy", "bitcast"
                ):
                    # pure dtype-cast/copy fusion: a CPU float-normalisation
                    # artifact (bf16 loop carries widened to f32) — free on
                    # the TPU target, so excluded from the HBM model.
                    pass
                else:
                    by += mult[1]
            for k, v in cc.items():
                co[k] = co.get(k, 0.0) + m * v
        memo[key] = (fl, by, co)
        return memo[key]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "coll": {}}
    fl, by, co = total(entry, False)
    return {"flops": fl, "bytes": by, "coll": co}


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device collective operand bytes by kind (trip-count aware)."""
    co = hlo_cost(hlo_text)["coll"]
    return {op: int(co.get(op, 0)) for op in COLLECTIVE_OPS}


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_by_op: dict

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_by_op": self.coll_by_op,
        }


def roofline(compiled) -> RooflineTerms:
    cost = hlo_cost(compiled.as_text())
    flops = float(cost["flops"])
    bytes_ = float(cost["bytes"])
    coll = {k: float(v) for k, v in cost["coll"].items()}
    coll_total = float(sum(coll.values()))
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=coll_total / ICI_BW,
        flops_per_dev=flops,
        hbm_bytes_per_dev=bytes_,
        coll_bytes_per_dev=coll_total,
        coll_by_op=coll,
    )


# ---------------------------------------------------------------------------
# Flash-decoding analytic cost model (kernels/decode.py)
# ---------------------------------------------------------------------------


def decode_attention_cost(
    b: int,
    hq: int,
    hkv: int,
    length: int,
    max_len: int,
    d: int,
    *,
    group_size: int = 1,
    block_k: int = 128,
    q_len: int = 1,
) -> dict:
    """FLOPs / bytes model of one split-K decode step (per layer).

    The length-aware grid streams only ``ceil(length/block_k)`` KV blocks
    per slot — per-token KV traffic scales with the *live* length, not the
    allocated ``max_len`` (whose cost is reported as ``dense_kv_bytes`` for
    comparison: the pre-kernel serve path attended over the whole padded
    cache).  The fused-K̂ variant (``group_size > 1``) reads the ``d/G*``-
    wide fused cache in the score stage and full V in the value stage: the
    paper's (1 − 1/G*)·½ KV-read saving on top of the live-length win.
    Split partials (o, m, l per split, f32) are the flash-decoding merge
    overhead — counted as one write + one read each over *all*
    ``max_len/block_k`` splits: jit shapes are static, so dead splits still
    zero-write their partials and the XLA merge streams every split (only
    the KV stream itself is length-bounded).
    """
    block_k = min(block_k, max_len)
    live = min(max(length, 1), max_len)
    nk_live = -(-live // block_k) * block_k  # KV blocks actually streamed
    splits_total = -(-max_len // block_k)  # partial buffers are full-size
    d_score = d // group_size
    w = 2  # bf16 cache / activations
    rows = b * hq * q_len

    kv_bytes = w * b * hkv * nk_live * (d_score + d)  # K (or K̂) + V streams
    # Pre-kernel baseline: the masked-scan path streams the same caches
    # (K̂ + V when fused, K + V otherwise) but over all max_len slots.
    dense_kv_bytes = w * b * hkv * max_len * (d_score + d)
    q_bytes = w * rows * d_score
    o_bytes = w * rows * d
    partial_bytes = 2 * 4 * b * hq * q_len * splits_total * (d + 2)

    qk_flops = 2 * rows * nk_live * d_score
    pv_flops = 2 * rows * nk_live * d
    softmax_flops = 4 * rows * nk_live
    merge_flops = 4 * rows * splits_total * (d + 2)

    return {
        "kv_bytes": kv_bytes,
        "dense_kv_bytes": dense_kv_bytes,
        "hbm_bytes": kv_bytes + q_bytes + o_bytes + partial_bytes,
        "mxu_flops": qk_flops + pv_flops,
        "total_flops": qk_flops + pv_flops + softmax_flops + merge_flops,
        "splits_live": nk_live // block_k,
    }


def paged_decode_attention_cost(
    b: int,
    hq: int,
    hkv: int,
    length: int,
    max_blocks: int,
    block_size: int,
    d: int,
    *,
    group_size: int = 1,
    q_len: int = 1,
) -> dict:
    """FLOPs / bytes model of one block-table split-K decode step (per
    layer; kernels/paged_decode.py).

    The clamped index maps stream ``ceil(length/block_size)`` pool blocks
    per request — same live-length scaling as the contiguous decode kernel
    — plus the block table itself (scalar prefetch: 4 bytes per table
    entry).  ``slab_kv_bytes`` reports what the *slot engine* commits for
    the same request: a full ``max_blocks·block_size`` contiguous slab —
    the allocation the pool shares across requests; the difference (times
    the request count) is the HBM the paged engine turns into extra batch
    lanes at equal budget (benchmarks/serving.py).  The fused-K̂ variant
    (``group_size > 1``) streams the ``d/G*``-wide fused pool in the score
    stage, full V in the value stage.  Split partials (o, m, l, f32) span
    all ``max_blocks`` table entries — jit shapes are static, dead splits
    still zero-write — so the merge term scales with the table width.
    """
    capacity = max_blocks * block_size
    live = min(max(length, 1), capacity)
    live_blocks = -(-live // block_size)
    nk_live = live_blocks * block_size
    d_score = d // group_size
    w = 2  # bf16 pools / activations
    rows = b * hq * q_len

    kv_bytes = w * b * hkv * nk_live * (d_score + d)  # K̂/K + V block streams
    slab_kv_bytes = w * b * hkv * capacity * (d_score + d)
    table_bytes = 4 * b * max_blocks
    q_bytes = w * rows * d_score
    o_bytes = w * rows * d
    partial_bytes = 2 * 4 * b * hq * q_len * max_blocks * (d + 2)

    qk_flops = 2 * rows * nk_live * d_score
    pv_flops = 2 * rows * nk_live * d
    softmax_flops = 4 * rows * nk_live
    merge_flops = 4 * rows * max_blocks * (d + 2)

    return {
        "kv_bytes": kv_bytes,
        "slab_kv_bytes": slab_kv_bytes,
        "table_bytes": table_bytes,
        "hbm_bytes": kv_bytes + table_bytes + q_bytes + o_bytes + partial_bytes,
        "mxu_flops": qk_flops + pv_flops,
        "total_flops": qk_flops + pv_flops + softmax_flops + merge_flops,
        "blocks_live": live_blocks,
    }


def mesh_prefill_handoff_cost(
    hq: int,
    hkv: int,
    n: int,
    p: int,
    d: int,
    *,
    group_size: int = 1,
    w: int = 2,
) -> dict:
    """FLOPs / bytes model of one mesh-prefill→paged-decode handoff (per
    layer; serve_step.make_mesh_paged_prefill under PagedServeEngine(mesh=)).

    Three phases, all modeled per device on a ``p``-way context ring:

      * **Ring attention** over the ``n``-token prompt: each device holds a
        ``ceil(n/p)``-row query shard and streams every KV shard over
        ``p − 1`` collective-permute hops (causal sweeps skip future hops,
        so the rotate volume is halved on average).  A causal query row
        attends ``n/2`` keys on average — per-device MXU work is the
        single-device prefill's divided by ``p``.
      * **Gather**: the per-shard K/V re-assembles to global arrays at the
        shard_map boundary (all-gather: each device contributes its shard
        to ``p − 1`` peers).
      * **Handoff scatter**: the pool-owning device writes the prompt's
        K/V (fused K̂ at width ``d/group_size`` replaces raw K when the
        engine decodes fused) through the block table — read the gathered
        rows, write the pool blocks.

    Seconds follow from the module constants: ``mxu_flops/PEAK_FLOPS``,
    ``(ici_rotate_bytes + ici_gather_bytes)/ICI_BW``,
    ``(hbm_stream_bytes + pool_scatter_bytes)/HBM_BW`` — the roofline rows
    benchmarks/mesh_serving.py reports next to the measured TTFT.
    """
    shard = -(-n // max(p, 1))
    d_score = d // group_size
    rows = hq * shard
    attended = n / 2.0  # causal average

    qk_flops = 2.0 * rows * attended * d
    pv_flops = 2.0 * rows * attended * d
    softmax_flops = 4.0 * rows * attended

    # Per hop one KV shard (K + V) rides collective-permute; causal rings
    # run half the hops on average.
    ici_rotate_bytes = (p - 1) / 2.0 * w * hkv * shard * 2 * d
    ici_gather_bytes = (p - 1) * w * hkv * shard * 2 * d
    hbm_stream_bytes = w * shard * (2 * hq * d + 2 * hkv * d)  # q,o + k,v
    # Scatter on the pool device: read the n gathered rows, write K̂/K + V.
    pool_scatter_bytes = 2 * w * hkv * n * (d_score + d)

    return {
        "shard_len": shard,
        "mxu_flops": qk_flops + pv_flops,
        "total_flops": qk_flops + pv_flops + softmax_flops,
        "ici_rotate_bytes": ici_rotate_bytes,
        "ici_gather_bytes": ici_gather_bytes,
        "hbm_stream_bytes": hbm_stream_bytes,
        "pool_scatter_bytes": pool_scatter_bytes,
        "hbm_bytes": hbm_stream_bytes + pool_scatter_bytes,
    }


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D convention)
# ---------------------------------------------------------------------------


def active_params(cfg, param_shapes) -> tuple[int, int]:
    """(total_params, active_params): MoE counts routed experts × k/E.

    Embedding tables are excluded from the 6ND matmul count (lookup ≠ matmul)
    but the tied/untied LM head IS counted.
    """
    import jax

    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "embed" in keys and "table" in keys:
            if cfg.tie_embeddings:
                active += n  # doubles as LM head
            continue
        if "pos_embed" in keys:
            continue
        if "experts" in keys:
            active += n * cfg.moe_top_k / max(cfg.n_experts, 1)
            continue
        active += n
    return int(total), int(active)


def model_flops(cfg, shape, active: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens
