"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline import analysis

__all__ = ["analysis"]
