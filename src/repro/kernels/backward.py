"""FA-2-style backward Pallas TPU kernels for flash / DistrAttention.

Design (DESIGN.md §Backward): the forward saves only the per-row logsumexp
``L = m + log l``; the backward recomputes each score block from (Q, K) —
IO-aware recomputation instead of materialising the N×N probability matrix
(Dao, 2023).  Three kernel families:

* ``delta``  — D = rowsum(dO ∘ O), one cheap VPU pass.  Like the LSE it is
  stored per-row f32 ``(BHq, N)`` in HBM (no ×128 lane replication); the
  matmul kernels re-broadcast it to a (block_q, 1) column on load.
* ``dq``     — grid (B·Hq, N/l, Nk/m), KV innermost, dQ accumulated in VMEM
  scratch across KV blocks:  dQ = Σ_j dS_j K_j · scale.
* ``dkv``    — grid (B·Hq, Nk/m, N/l), Q innermost, dK/dV accumulated across
  Q blocks:  dV = Σ_i P_iᵀ dO_i,  dK = Σ_i dS_iᵀ Q_i · scale.  Outputs are
  per *query* head; the ops.py wrapper sums the ``q_per_kv`` group (GQA).

The distr variants re-fuse K̂ in-kernel under the saved per-Q-block
permutation (same gather + segment-sum as the forward) and route dK̂ back
through the segment-sum transpose: each fused column's gradient is replicated
to its ``G*`` members and scattered to original column order via the inverse
permutation — a lane *gather* by ``inv_perm``, TPU-friendly, no scatter op.
The LSH permutation itself is non-differentiable (straight-through): the
paper's grouping is a fixed discrete choice per block, so no gradient flows
into the hash.  Q̂ gradients leave the kernel in sampled space; the wrapper
transposes the sampling gather back to full-d dQ.

Everywhere ``p = where(mask, exp(s - L), 0)``: masking P directly (rather
than relying on s = -inf) keeps padded rows/columns exactly zero-gradient
even when a row's L is itself -inf (fully-masked query padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.distr_attention import fuse_k_columns
from repro.kernels.flash_attention import NEG_INF
from repro.kernels.tpu_compat import CompilerParams


# ---------------------------------------------------------------------------
# D = rowsum(dO ∘ O) precompute
# ---------------------------------------------------------------------------


def _delta_kernel(o_ref, do_ref, d_ref):
    o = o_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    # Per-row f32 write (not lane-replicated): the matmul kernels
    # re-broadcast on load.
    d_ref[...] = (o * do).sum(axis=1)


def delta_kernel_call(
    o: jnp.ndarray,
    do: jnp.ndarray,
    *,
    block_q: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """D = rowsum(dO ∘ O).  o, do: (BHq, N, d) → (BHq, N) f32 per-row."""
    bhq, n, d = o.shape
    grid = (bhq, n // block_q)
    return pl.pallas_call(
        _delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, block_q, d), lambda bh, i: (bh, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q), lambda bh, i: (bh, i)),
        out_shape=jax.ShapeDtypeStruct((bhq, n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="attention_bwd_delta",
    )(o, do)


# ---------------------------------------------------------------------------
# Shared block math
# ---------------------------------------------------------------------------


def _block_mask(iq, ik, shape, *, causal, block_q, block_k, kv_len):
    col = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    mask = col < kv_len
    if causal:
        row = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
        mask = jnp.logical_and(mask, col <= row)
    return mask


def _p_and_ds(s, mask, lse, delta, do, v):
    """P from the saved LSE, then dS = P ∘ (dOVᵀ − D).  All f32."""
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)  # (block_q, block_k)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    return p, ds


# ---------------------------------------------------------------------------
# Exact flash backward
# ---------------------------------------------------------------------------


def _flash_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr,
    *, scale, causal, block_q, block_k, kv_len,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        # Per-row residuals: re-broadcast the (block_q,) row stats to the
        # (block_q, 1) column layout the block math wants.
        lse = lse_ref[...][:, None]
        delta = delta_ref[...][:, None]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(
            iq, ik, s.shape, causal=causal, block_q=block_q, block_k=block_k,
            kv_len=kv_len,
        )
        _, ds = _p_and_ds(s, mask, lse, delta, do, v)
        dq_scr[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[...] = dq_scr[...].astype(dq_ref.dtype)


def flash_dq_kernel_call(
    q, k, v, do, lse, delta, *,
    q_per_kv: int, scale: float, causal: bool,
    block_q: int, block_k: int, kv_len: int, interpret: bool = True,
) -> jnp.ndarray:
    """dQ for the exact kernel.  All seq dims padded; returns (BHq, N, d) f32."""
    bhq, n, d = q.shape
    bhkv, nk_len, _ = k.shape
    assert bhq == bhkv * q_per_kv

    grid = (bhq, n // block_q, nk_len // block_k)
    q_index = lambda bh, i, j: (bh, i, 0)
    kv_index = lambda bh, i, j: (bh // q_per_kv, j, 0)

    kernel = functools.partial(
        _flash_dq_kernel,
        scale=scale, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((bhq, n, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_bwd_dq",
    )(q, k, v, do, lse, delta)


def _flash_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_scr, dv_scr,
    *, scale, causal, block_q, block_k, kv_len,
):
    ik = pl.program_id(1)  # KV block: outer/parallel here
    iq = pl.program_id(2)  # Q block: innermost, accumulated over
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        # Per-row residuals: re-broadcast the (block_q,) row stats to the
        # (block_q, 1) column layout the block math wants.
        lse = lse_ref[...][:, None]
        delta = delta_ref[...][:, None]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(
            iq, ik, s.shape, causal=causal, block_q=block_q, block_k=block_k,
            kv_len=kv_len,
        )
        p, ds = _p_and_ds(s, mask, lse, delta, do, v)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dk_scr[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].astype(dv_ref.dtype)


def flash_dkv_kernel_call(
    q, k, v, do, lse, delta, *,
    q_per_kv: int, scale: float, causal: bool,
    block_q: int, block_k: int, kv_len: int, interpret: bool = True,
):
    """dK, dV per *query* head: (BHq, Nk, d) f32 each; caller sums the GQA
    group (wrapper-side accumulation keeps the kernel grid race-free)."""
    bhq, n, d = q.shape
    bhkv, nk_len, _ = k.shape
    assert bhq == bhkv * q_per_kv

    grid = (bhq, nk_len // block_k, n // block_q)
    q_index = lambda bh, j, i: (bh, i, 0)
    kv_index = lambda bh, j, i: (bh // q_per_kv, j, 0)
    dkv_index = lambda bh, j, i: (bh, j, 0)

    kernel = functools.partial(
        _flash_dkv_kernel,
        scale=scale, causal=causal, block_q=block_q, block_k=block_k,
        kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_q), lambda bh, j, i: (bh, i)),
            pl.BlockSpec((None, block_q), lambda bh, j, i: (bh, i)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_k, d), dkv_index),
            pl.BlockSpec((None, block_k, d), dkv_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhq, nk_len, d), jnp.float32),
            jax.ShapeDtypeStruct((bhq, nk_len, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_bwd_dkv",
    )(q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# DistrAttention backward
# ---------------------------------------------------------------------------


def _distr_dq_kernel(
    q_hat_ref, k_ref, v_ref, perm_ref, do_ref, lse_ref, delta_ref,
    dq_hat_ref, dq_scr,
    *, causal, group_size, block_q, block_k, kv_len,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q_hat = q_hat_ref[...].astype(jnp.float32)  # (block_q, dg) pre-scaled
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        perm = perm_ref[0]
        # Per-row residuals: re-broadcast the (block_q,) row stats to the
        # (block_q, 1) column layout the block math wants.
        lse = lse_ref[...][:, None]
        delta = delta_ref[...][:, None]

        k_hat = fuse_k_columns(k, perm, group_size)  # (block_k, dg)
        s = jax.lax.dot_general(
            q_hat, k_hat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        mask = _block_mask(
            iq, ik, s.shape, causal=causal, block_q=block_q, block_k=block_k,
            kv_len=kv_len,
        )
        _, ds = _p_and_ds(s, mask, lse, delta, do, v)
        # q_hat is pre-scaled, so no scale factor here: the ops.py wrapper
        # folds 1/sqrt(d) into the q̂ chain rule when scattering back to dQ.
        dq_scr[...] += jax.lax.dot_general(
            ds, k_hat, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_hat_ref[...] = dq_scr[...].astype(dq_hat_ref.dtype)


def distr_dq_kernel_call(
    q_hat, k, v, perm, do, lse, delta, *,
    q_per_kv: int, causal: bool, group_size: int,
    block_q: int, block_k: int, kv_len: int, interpret: bool = True,
) -> jnp.ndarray:
    """dQ̂ (gradient w.r.t. the pre-scaled sampled queries): (BHq, N, d/G*)."""
    bhq, n, dg = q_hat.shape
    bhkv, nk_len, d = k.shape
    assert bhq == bhkv * q_per_kv
    assert dg * group_size == d

    grid = (bhq, n // block_q, nk_len // block_k)

    kernel = functools.partial(
        _distr_dq_kernel,
        causal=causal, group_size=group_size, block_q=block_q,
        block_k=block_k, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dg), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, i, j: (bh // q_per_kv, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, i, j: (bh // q_per_kv, j, 0)),
            pl.BlockSpec((None, 1, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dg), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, n, dg), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, dg), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="distr_attention_bwd_dq",
    )(q_hat, k, v, perm, do, lse, delta)


def _distr_dkv_kernel(
    q_hat_ref, k_ref, v_ref, perm_ref, inv_perm_ref, do_ref, lse_ref,
    delta_ref, dk_ref, dv_ref, dk_scr, dv_scr,
    *, causal, group_size, block_q, block_k, kv_len,
):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q_hat = q_hat_ref[...].astype(jnp.float32)  # (block_q, dg)
        k = k_ref[...].astype(jnp.float32)  # (block_k, d)
        v = v_ref[...].astype(jnp.float32)
        do = do_ref[...].astype(jnp.float32)
        perm = perm_ref[0]  # (d,) this Q block's permutation
        inv_perm = inv_perm_ref[0]  # (d,) its inverse
        # Per-row residuals: re-broadcast the (block_q,) row stats to the
        # (block_q, 1) column layout the block math wants.
        lse = lse_ref[...][:, None]
        delta = delta_ref[...][:, None]

        k_hat = fuse_k_columns(k, perm, group_size)  # re-fused under this Q block
        s = jax.lax.dot_general(
            q_hat, k_hat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        mask = _block_mask(
            iq, ik, s.shape, causal=causal, block_q=block_q, block_k=block_k,
            kv_len=kv_len,
        )
        p, ds = _p_and_ds(s, mask, lse, delta, do, v)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dk_hat = jax.lax.dot_general(
            ds, q_hat, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (block_k, dg)
        # Segment-sum transpose: every member of a fused group receives the
        # group's gradient; undo the permutation with a gather by inv_perm
        # (dk[:, c] = dk_rep[:, inv_perm[c]] since perm[inv_perm[c]] = c).
        d = k.shape[1]
        dk_rep = jnp.broadcast_to(
            dk_hat[:, :, None], (dk_hat.shape[0], dk_hat.shape[1], group_size)
        ).reshape(dk_hat.shape[0], d)
        dk_scr[...] += jnp.take(dk_rep, inv_perm, axis=1)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[...] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].astype(dv_ref.dtype)


def distr_dkv_kernel_call(
    q_hat, k, v, perm, inv_perm, do, lse, delta, *,
    q_per_kv: int, causal: bool, group_size: int,
    block_q: int, block_k: int, kv_len: int, interpret: bool = True,
):
    """dK, dV per *query* head (dK already scattered back through each head's
    permutation): (BHq, Nk, d) f32 each; caller sums the GQA group."""
    bhq, n, dg = q_hat.shape
    bhkv, nk_len, d = k.shape
    assert bhq == bhkv * q_per_kv
    assert dg * group_size == d

    grid = (bhq, nk_len // block_k, n // block_q)
    q_index = lambda bh, j, i: (bh, i, 0)
    kv_index = lambda bh, j, i: (bh // q_per_kv, j, 0)
    dkv_index = lambda bh, j, i: (bh, j, 0)

    kernel = functools.partial(
        _distr_dkv_kernel,
        causal=causal, group_size=group_size, block_q=block_q,
        block_k=block_k, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dg), q_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, 1, d), q_index),
            pl.BlockSpec((None, 1, d), q_index),
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_q), lambda bh, j, i: (bh, i)),
            pl.BlockSpec((None, block_q), lambda bh, j, i: (bh, i)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_k, d), dkv_index),
            pl.BlockSpec((None, block_k, d), dkv_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhq, nk_len, d), jnp.float32),
            jax.ShapeDtypeStruct((bhq, nk_len, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="distr_attention_bwd_dkv",
    )(q_hat, k, v, perm, inv_perm, do, lse, delta)
