"""Block-table (paged) split-K flash-decoding Pallas kernel.

The contiguous decode kernel (kernels/decode.py) assumes each slot owns a
``(Hkv, max_len, d)`` slab — the allocation model the paged serving
subsystem replaces.  Here KV lives in a shared *block pool*
``(P, Hkv, block_size, d)`` and each request describes its sequence as a
**block table**: logical block ``j`` of request ``b`` holds tokens
``[j·bs, (j+1)·bs)`` and lives in physical pool block ``bt[b, j]``
(serve/paged.py owns allocation; DESIGN.md §Paged serving).

The split-K structure carries over unchanged — one grid step per logical
block, unnormalised partials, the same cross-split LSE merge
(``kernels.decode.merge_splits``) — only the *addressing* differs:

* **Scalar-prefetched block table.**  ``PrefetchScalarGridSpec`` makes the
  per-request live lengths *and* the block table available to the K/V
  BlockSpec index maps, so grid step ``(b, h, j)`` DMAs physical block
  ``bt[b, j]`` straight out of the pool — no gather materialises a
  contiguous copy of the request's KV.

* **Clamped index maps.**  Dead logical blocks (``j·bs ≥ length``) clamp to
  the request's last live table entry: the pipeline sees a repeated block
  index and skips the DMA, so dead pool blocks are never streamed and
  per-token KV traffic tracks ``ceil(length/bs)`` blocks — the paged analog
  of the ring cache's length-aware grid.

* **One kernel, two cache widths.**  Exactly like the contiguous kernel,
  the score width is whatever ``q``/``k_pool`` carry: the flash variant
  streams the raw K pool (width ``d``), the fused-K̂ distr variant streams
  the ``d/G*``-wide fused pool with column-sampled queries (static per-layer
  permutation, applied by the ops wrapper).  V is always full width.

* **GQA head-packing + small-q_len banding** are shared verbatim with
  kernels/decode.py: rows pack ``q_per_kv × q_len`` queries per KV head,
  and packed row ``r`` (query token ``i = r mod q_len``) attends to cache
  positions ``< length − (q_len − 1 − i)`` — which is also what makes
  *chunked prefill* ride this kernel (a width-``c`` chunk is a ``q_len=c``
  banded decode).

Validated against gathered-contiguous oracles in tests/test_paged.py
(interpret mode on CPU; compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF
from repro.kernels.tpu_compat import CompilerParams

GARBAGE_BLOCK = 0  # pool block 0 is never allocated: dead-lane writes land here


def _paged_decode_kernel(
    lens_ref,  # scalar prefetch: (B,) int32 live lengths
    bt_ref,  # scalar prefetch: (B, max_blocks) int32 block table
    q_ref,  # (1, 1, rows, d_score)
    k_ref,  # (1, 1, block_size, d_score)   physical block via index map
    v_ref,  # (1, 1, block_size, d)
    o_ref,  # (1, 1, 1, rows, d)      unnormalised partial
    m_ref,  # (1, 1, 1, rows)         per-split row max
    l_ref,  # (1, 1, 1, rows)         per-split row sum
    *,
    scale: float,
    block_size: int,
    q_len: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = lens_ref[b]

    # Dead logical block: this request's live KV ends before block j.  The
    # index map already re-pointed the DMA at the last live physical block;
    # skip the math and emit identity stats for the merge.
    live = j * block_size < length

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (rows, d_score)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_size, d_score)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_size, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (rows, block_size)

        col = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # Packed row r is query token i = r % q_len; it sees the cache up to
        # length − (q_len − 1 − i) tokens (q_len = 1 ⇒ plain `col < length`).
        row_tok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_len
        row_len = length - (q_len - 1 - row_tok)
        mask = col < row_len
        s = jnp.where(mask, s, NEG_INF)

        m = s.max(axis=1)  # (rows,)
        p = jnp.where(mask, jnp.exp(s - m[:, None]), 0.0)
        o_ref[0, 0, 0] = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[0, 0, 0] = m
        l_ref[0, 0, 0] = p.sum(axis=1)

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[0, 0, 0] = jnp.zeros_like(o_ref[0, 0, 0])
        m_ref[0, 0, 0] = jnp.full_like(m_ref[0, 0, 0], NEG_INF)
        l_ref[0, 0, 0] = jnp.zeros_like(l_ref[0, 0, 0])


def paged_decode_kernel_call(
    q: jnp.ndarray,  # (B, Hkv, rows, d_score) — GQA-packed (+ padded) queries
    k_pool: jnp.ndarray,  # (P, Hkv, block_size, d_score) — raw K or fused K̂ pool
    v_pool: jnp.ndarray,  # (P, Hkv, block_size, d)
    block_tables: jnp.ndarray,  # (B, max_blocks) int32 physical block ids
    lengths: jnp.ndarray,  # (B,) int32 live token counts
    *,
    scale: float,
    q_len: int,
    interpret: bool = True,
):
    """Raw pallas_call → unnormalised split partials ``(o, m, l)``.

    o: (B, Hkv, max_blocks, rows, d) f32; m, l: (B, Hkv, max_blocks, rows).
    One split per *logical* block-table entry; the caller performs the
    cross-split LSE merge (``kernels.decode.merge_splits`` — identical
    algebra, the splits just came from non-contiguous physical blocks).
    """
    b, hkv, rows, d_score = q.shape
    block_size, d = k_pool.shape[2], v_pool.shape[3]
    max_blocks = block_tables.shape[1]

    def q_index(bi, h, j, lens, bt):
        return (bi, h, 0, 0)

    def kv_index(bi, h, j, lens, bt):
        # Clamp dead logical blocks to the request's last live table entry:
        # the pipeline sees a repeated physical index and skips the DMA —
        # dead pool blocks are never streamed, so per-token traffic tracks
        # ceil(length / block_size), not the table width.
        last_live = jnp.maximum(pl.cdiv(lens[bi], block_size) - 1, 0)
        return (bt[bi, jnp.minimum(j, last_live)], h, 0, 0)

    def out_index(bi, h, j, lens, bt):
        return (bi, h, j, 0, 0)

    def stat_index(bi, h, j, lens, bt):
        return (bi, h, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d_score), q_index),
            pl.BlockSpec((1, 1, block_size, d_score), kv_index),
            pl.BlockSpec((1, 1, block_size, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, rows, d), out_index),
            pl.BlockSpec((1, 1, 1, rows), stat_index),
            pl.BlockSpec((1, 1, 1, rows), stat_index),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_size=block_size, q_len=q_len
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, max_blocks, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, max_blocks, rows), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, max_blocks, rows), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
        name="paged_decode_splitk",
    )(lengths, block_tables, q, k_pool, v_pool)
