"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They intentionally reuse ``repro.core`` (itself validated against the naive
softmax oracle) so kernel semantics and framework semantics cannot drift.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.distr_attention import DistrConfig, distr_attention
from repro.core.flash_reference import reference_attention


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for kernels/flash_attention.py (exact attention)."""
    return reference_attention(q, k, v, causal=causal, scale=scale)


def distr_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: DistrConfig,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for kernels/distr_attention.py.

    The pure-JAX blockwise DistrAttention computes a full-row softmax per Q
    block; the kernel computes the same quantity with an online softmax — the
    results agree to float tolerance when both use the same permutations
    (guaranteed by the shared ``core.lsh`` stage and proj_seed).
    """
    return distr_attention(q, k, v, cfg, causal=causal, scale=scale)


def decode_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray | None = None,
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for kernels/decode.py (q_len == 1 decode).

    q: (B, Hq, 1, d); k, v: (B, Hkv, S, d); lengths: (B,) live token counts
    (None ⇒ all S live).  The fused-K̂ variant shares this oracle: pass the
    fused cache as ``k`` and pre-sampled queries as ``q`` with the full-d
    scale (the kernel computes exactly this masked softmax either way).
    """
    kv_mask = (
        jnp.arange(k.shape[2])[None, :] < lengths[:, None]
        if lengths is not None
        else None
    )
    return reference_attention(
        q, k.astype(q.dtype), v.astype(q.dtype),
        causal=False, scale=scale, kv_mask=kv_mask,
    )


def ssd_ref(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    chunk: int = 64,
) -> jnp.ndarray:
    """Oracle for kernels/ssd.py (Mamba-2 state-space duality, naive scan).

    x: (B, N, H, P) inputs;  a: (B, N, H) log-decay (a = -softplus(...));
    b, c: (B, N, G, S) input/output projections (G state groups).
    Returns y: (B, N, H, P).  Sequential over N — slow but unambiguous.
    """
    bsz, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    heads_per_group = h // g
    y = jnp.zeros_like(x, dtype=jnp.float32)
    state = jnp.zeros((bsz, h, s, p), jnp.float32)  # (B, H, S, P)
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    outs = []
    for t in range(n):
        decay = jnp.exp(af[:, t])[:, :, None, None]  # (B, H, 1, 1)
        bt = jnp.repeat(bf[:, t], heads_per_group, axis=1)  # (B, H, S)
        ct = jnp.repeat(cf[:, t], heads_per_group, axis=1)
        state = state * decay + bt[..., None] * xf[:, t][:, :, None, :]
        outs.append(jnp.einsum("bhs,bhsp->bhp", ct, state))
    y = jnp.stack(outs, axis=1)  # (B, N, H, P)
    return y.astype(x.dtype)
