"""Jit'd public wrappers around the Pallas kernels.

Handles everything the raw kernels keep out of their grids: GQA flattening,
sequence padding, LSH permutation precompute, scale folding, and the
analytic cost models used by benchmarks and the §Perf roofline corrections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import grouping, lsh
from repro.core.distr_attention import DistrConfig, compute_block_permutations
from repro.kernels.distr_attention import distr_attention_kernel_call
from repro.kernels.flash_attention import flash_attention_kernel_call
from repro.kernels.ssd import ssd_kernel_call


def _pad_seq(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[2]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x, n


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Exact FA-2 Pallas kernel.  q: (B,Hq,N,d); k,v: (B,Hkv,Nk,d)."""
    b, hq, n, d = q.shape
    hkv, nk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    q_per_kv = hq // hkv

    q, n_orig = _pad_seq(q, block_q)
    k, kv_len = _pad_seq(k, block_k)
    v, _ = _pad_seq(v, block_k)

    out = flash_attention_kernel_call(
        q.reshape(b * hq, q.shape[2], d),
        k.reshape(b * hkv, k.shape[2], d),
        v.reshape(b * hkv, v.shape[2], d),
        q_per_kv=q_per_kv,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        interpret=interpret,
    )
    return out.reshape(b, hq, -1, d)[:, :, :n_orig, :]


@functools.partial(jax.jit, static_argnames=("cfg", "causal", "scale", "interpret"))
def distr_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: DistrConfig = DistrConfig(),
    *,
    causal: bool = False,
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """DistrAttention Pallas kernel (paper §3.3 + FA-2 integration).

    Stage 1 (outside kernel, XLA): LSH permutations per Q block + Q sampling.
    Stage 2 (kernel): per-KV-block fusion + reduced-d flash attention.
    """
    b, hq, n, d = q.shape
    hkv, nk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    q_per_kv = hq // hkv
    g = cfg.group_size

    q, n_orig = _pad_seq(q, cfg.block_q)
    k, kv_len = _pad_seq(k, cfg.block_k)
    v, _ = _pad_seq(v, cfg.block_k)
    n_pad = q.shape[2]
    nq_blocks = n_pad // cfg.block_q

    proj = lsh.make_projection(jax.random.PRNGKey(cfg.proj_seed), cfg.block_q)
    if cfg.shared_kv_perm:
        q_mean = q.reshape(b, hkv, q_per_kv, n_pad, d).mean(axis=2)
        perms = compute_block_permutations(q_mean, cfg, proj)  # (b, hkv, nq, d)
        perms = jnp.broadcast_to(
            perms[:, :, None], (b, hkv, q_per_kv, nq_blocks, d)
        ).reshape(b, hq, nq_blocks, d)
    else:
        perms = compute_block_permutations(q, cfg, proj)  # (b, hq, nq, d)

    q_blocks = q.reshape(b, hq, nq_blocks, cfg.block_q, d)
    if cfg.estimator == "sample":
        q_hat = grouping.sample_columns(q_blocks, perms, g)
    elif cfg.estimator == "mean":
        q_hat = grouping.mean_columns(q_blocks, perms, g)
    else:
        raise ValueError(f"unknown estimator {cfg.estimator!r}")
    q_hat = (q_hat * scale).reshape(b * hq, n_pad, d // g).astype(q.dtype)

    out = distr_attention_kernel_call(
        q_hat,
        k.reshape(b * hkv, k.shape[2], d),
        v.reshape(b * hkv, v.shape[2], d),
        perms.reshape(b * hq, nq_blocks, d),
        q_per_kv=q_per_kv,
        causal=causal,
        group_size=g,
        block_q=cfg.block_q,
        block_k=cfg.block_k,
        kv_len=kv_len,
        interpret=interpret,
    )
    return out.reshape(b, hq, -1, d)[:, :, :n_orig, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Mamba-2 SSD.  x: (B,N,H,P); a: (B,N,H); b,c: (B,N,G,S)."""
    bsz, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    heads_per_group = h // g
    pad = (-n) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_pad = x.shape[1]

    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, n_pad, p)
    ar = a.transpose(0, 2, 1).reshape(bsz * h, n_pad, 1)
    br = b.transpose(0, 2, 1, 3).reshape(bsz * g, n_pad, s)
    cr = c.transpose(0, 2, 1, 3).reshape(bsz * g, n_pad, s)

    y = ssd_kernel_call(
        xr, ar, br, cr, heads_per_group=heads_per_group, chunk=chunk,
        interpret=interpret,
    )
    y = y.reshape(bsz, h, n_pad, p).transpose(0, 2, 1, 3)
    return y[:, :n, :, :]


# ---------------------------------------------------------------------------
# Analytic cost models (benchmarks + roofline corrections).
# ---------------------------------------------------------------------------


def attention_cost(
    b: int,
    hq: int,
    n: int,
    nk: int,
    d: int,
    *,
    causal: bool = False,
    group_size: int = 1,
    block_q: int = 128,
) -> dict:
    """FLOPs / bytes model of (Distr)FlashAttention for one forward pass.

    MXU matmul FLOPs, VPU fusion adds, and HBM bytes (bf16 in/out, the
    flash structure never materialises S/P).  ``group_size=1`` = exact FA-2.
    """
    frac = 0.5 * (1 + 1 / max(nk // max(block_q, 1), 1)) if causal else 1.0
    d_eff = d // group_size
    qk_flops = 2 * b * hq * n * nk * d_eff * frac
    pv_flops = 2 * b * hq * n * nk * d * frac
    softmax_flops = 4 * b * hq * n * nk * frac  # exp, max, sum, scale
    # K fusion: for each (q-block, kv element) a d-length permuted add chain.
    fusion_adds = (
        b * hq * (n // max(block_q, 1)) * nk * d * frac if group_size > 1 else 0
    )
    lsh_flops = (
        2 * b * hq * (n // max(block_q, 1)) * lsh.N_PRIME * block_q * d
        if group_size > 1
        else 0
    )
    w = 2  # bf16
    io_bytes = w * (
        b * hq * n * (d + d // group_size if group_size > 1 else d)  # Q (+Q̂)
        + b * hq * (n // max(block_q, 1)) * nk * 0  # K̂ stays in VMEM
        + 2 * b * hq * nk * d  # K, V read (per-head upper bound)
        + b * hq * n * d  # O write
    )
    return {
        "qk_flops": qk_flops,
        "pv_flops": pv_flops,
        "softmax_flops": softmax_flops,
        "fusion_adds": fusion_adds,
        "lsh_flops": lsh_flops,
        "mxu_flops": qk_flops + pv_flops,
        "total_flops": qk_flops + pv_flops + softmax_flops + fusion_adds + lsh_flops,
        "hbm_bytes": io_bytes,
    }


def ssd_cost(b: int, n: int, h: int, p: int, s: int, *, chunk: int = 64) -> dict:
    """FLOPs model of chunked SSD forward."""
    nc = n // chunk
    intra = 2 * b * h * nc * (chunk * chunk * s + chunk * chunk * p)
    inter = 2 * b * h * nc * (chunk * s * p * 2)
    return {"total_flops": intra + inter, "mxu_flops": intra + inter}
