"""Jit'd public wrappers around the Pallas kernels.

Handles everything the raw kernels keep out of their grids: GQA flattening,
sequence padding, LSH permutation precompute, scale folding, and the
analytic cost models used by benchmarks and the §Perf roofline corrections.

Both attention entry points are differentiable end-to-end via
``jax.custom_vjp``: the forward kernels emit the logsumexp row statistics,
and the backward runs the fused FA-2-style kernels in
``repro.kernels.backward`` (dQ, dK/dV, and the D = rowsum(dO ∘ O)
precompute) instead of XLA rematerialisation — so training steps stay on
the kernel path (DESIGN.md §Backward).  The DistrAttention backward treats
the LSH permutation as non-differentiable (straight-through): gradients
flow through the Q-sampling gather and the K̂ segment-sum only.

``interpret=None`` (the default everywhere) auto-detects the backend:
compiled kernels on TPU, interpreter mode elsewhere — no call-site changes
between the CPU container and real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import grouping, lsh
from repro.core.distr_attention import DistrConfig, compute_block_permutations
from repro.kernels import backward as bwd
from repro.kernels import decode as decode_kernels
from repro.kernels import paged_decode as paged_decode_kernels
from repro.kernels.distr_attention import distr_attention_kernel_call
from repro.kernels.flash_attention import flash_attention_kernel_call
from repro.kernels.ssd import ssd_kernel_call
from repro.tune.block_sizes import BlockSizes
from repro.tune.cache import dtype_str as _dtype_str


def default_interpret() -> bool:
    """Compiled Pallas on TPU, interpreter everywhere else (CPU container)."""
    return jax.default_backend() != "tpu"


def _pad_seq(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[2]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x, n


LSE_PAD = 1e30  # padded residual rows: exp(s − LSE_PAD) ≡ 0 kills their grads


def _pad_rows(x: jnp.ndarray, block: int, value: float = 0.0) -> jnp.ndarray:
    """Pad the row axis of per-row residuals (BHq, N) to a block multiple."""
    pad = (-x.shape[1]) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=value)
    return x


def _flatten_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, n, d = x.shape
    return x.reshape(b * h, n, d)


def _gqa_sum(dx_per_q_head: jnp.ndarray, b: int, hkv: int, q_per_kv: int,
             nk_orig: int) -> jnp.ndarray:
    """(B·Hq, Nk_pad, d) per-query-head grads → (B, Hkv, Nk, d)."""
    bhq, nk_pad, d = dx_per_q_head.shape
    out = dx_per_q_head.reshape(b, hkv, q_per_kv, nk_pad, d).sum(axis=2)
    return out[:, :, :nk_orig, :]


# ---------------------------------------------------------------------------
# Exact FA-2 with custom_vjp
# ---------------------------------------------------------------------------


def _flash_fwd_impl(causal, scale, block_q, block_k, interpret, q, k, v,
                    with_residuals):
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv

    qp, n_orig = _pad_seq(q, block_q)
    kp, kv_len = _pad_seq(k, block_k)
    vp, _ = _pad_seq(v, block_k)

    res = flash_attention_kernel_call(
        _flatten_heads(qp), _flatten_heads(kp), _flatten_heads(vp),
        q_per_kv=q_per_kv, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, kv_len=kv_len,
        interpret=interpret, return_residuals=with_residuals,
    )
    out, lse = res if with_residuals else (res, None)
    return out.reshape(b, hq, -1, d)[:, :, :n_orig, :], lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_attention(causal, scale, blocks, interpret, q, k, v):
    # Primal (inference / non-differentiated) path: skip the LSE residual —
    # it is only consumed by the backward kernels.
    out, _ = _flash_fwd_impl(
        causal, scale, blocks.block_q, blocks.block_k, interpret, q, k, v,
        with_residuals=False,
    )
    return out


def _flash_vjp_fwd(causal, scale, blocks, interpret, q, k, v):
    out, lse = _flash_fwd_impl(
        causal, scale, blocks.block_q, blocks.block_k, interpret, q, k, v,
        with_residuals=True,
    )
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, scale, blocks, interpret, res, do):
    # The backward kernels run their own tuned tiles (``blocks.dq()`` for
    # the dQ kernel, ``blocks.dkv()`` for the dK/dV kernel) — carried in the
    # custom_vjp static args, not in the residuals.  The fwd LSE is padded
    # to the *forward* q-block, so residuals are re-sliced to the live
    # length and re-padded per kernel; dead rows get LSE=+big ⇒ P ≡ 0,
    # contributing nothing to dK/dV.
    q, k, v, o, lse = res
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv
    nk = k.shape[2]
    do = do.astype(q.dtype)
    lse_n = lse[:, :n]

    blocks = _resolve_bwd_blocks(blocks, q, k, causal, interpret)
    bq_dq, bk_dq = blocks.dq()
    bq_dkv, bk_dkv = blocks.dkv()

    def q_side(block):
        qp, _ = _pad_seq(q, block)
        dop, _ = _pad_seq(do, block)
        op, _ = _pad_seq(o, block)
        return _flatten_heads(qp), _flatten_heads(dop), _flatten_heads(op)

    def kv_side(block):
        kp, _ = _pad_seq(k, block)
        vp, _ = _pad_seq(v, block)
        return _flatten_heads(kp), _flatten_heads(vp)

    qf1, dof1, of1 = q_side(bq_dq)
    kf1, vf1 = kv_side(bk_dq)
    delta = bwd.delta_kernel_call(of1, dof1, block_q=bq_dq, interpret=interpret)
    delta_n = delta[:, :n]
    dq = bwd.flash_dq_kernel_call(
        qf1, kf1, vf1, dof1,
        _pad_rows(lse_n, bq_dq, LSE_PAD), _pad_rows(delta_n, bq_dq),
        q_per_kv=q_per_kv, scale=scale, causal=causal,
        block_q=bq_dq, block_k=bk_dq, kv_len=nk, interpret=interpret,
    )
    if (bq_dkv, bk_dkv) == (bq_dq, bk_dq):
        qf2, dof2, kf2, vf2 = qf1, dof1, kf1, vf1
    else:
        qf2, dof2, _ = q_side(bq_dkv)
        kf2, vf2 = kv_side(bk_dkv)
    dk_h, dv_h = bwd.flash_dkv_kernel_call(
        qf2, kf2, vf2, dof2,
        _pad_rows(lse_n, bq_dkv, LSE_PAD), _pad_rows(delta_n, bq_dkv),
        q_per_kv=q_per_kv, scale=scale, causal=causal,
        block_q=bq_dkv, block_k=bk_dkv, kv_len=nk, interpret=interpret,
    )
    dq = dq.reshape(b, hq, -1, d)[:, :, :n, :].astype(q.dtype)
    dk = _gqa_sum(dk_h, b, hkv, q_per_kv, nk).astype(k.dtype)
    dv = _gqa_sum(dv_h, b, hkv, q_per_kv, nk).astype(v.dtype)
    return dq, dk, dv


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "blocks", "interpret")
)
def _flash_attention_jit(q, k, v, causal, scale, blocks, interpret):
    return _flash_attention(causal, scale, blocks, interpret, q, k, v)


def _resolve_flash_blocks(q, k, causal, interpret, block_q, block_k):
    """Explicit ints win (a partial pin gets the static default for the
    free dim — never a tuned value measured for a different pair); both
    None resolves the forward pair through the autotuner.  Backward tiles
    stay None here and resolve lazily at backward-trace time."""
    if block_q is not None or block_k is not None:
        return BlockSizes.from_pair(block_q or 128, block_k or 128)
    from repro.tune.autotune import resolve_block_sizes

    return resolve_block_sizes(
        "flash", d=q.shape[-1], n=max(q.shape[2], k.shape[2]),
        dtype=_dtype_str(q), causal=causal, interpret=interpret,
    )


def _resolve_bwd_blocks(blocks, q, k, causal, interpret):
    """Fill the backward dQ/dKV tiles at backward-trace time (measure mode
    only): forward-only dispatch — serving — never pays a backward-kernel
    sweep, and training pays it once, when grad tracing first reaches the
    op.  Explicitly-set backward tiles and off/analytic modes pass through
    (``BlockSizes.dq()/dkv()`` fall back to the fwd pair)."""
    if blocks.block_q_dq is not None or blocks.block_q_dkv is not None:
        return blocks
    from repro.tune.autotune import get_autotuner, tune_mode

    if tune_mode() != "measure":
        return blocks
    kw = dict(
        d=q.shape[-1], n=max(q.shape[2], k.shape[2]), dtype=_dtype_str(q),
        causal=causal, interpret=interpret,
    )
    tuner = get_autotuner()
    dq = tuner.resolve_pair("flash_dq", **kw)
    dkv = tuner.resolve_pair("flash_dkv", **kw)
    return blocks.with_(
        block_q_dq=dq[0], block_k_dq=dq[1],
        block_q_dkv=dkv[0], block_k_dkv=dkv[1],
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    blocks: BlockSizes | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Exact FA-2 Pallas kernel, differentiable.  q: (B,Hq,N,d); k,v:
    (B,Hkv,Nk,d).  ``interpret=None`` auto-detects the backend.

    Block sizes: pass ``blocks`` (a full :class:`BlockSizes`, e.g. from the
    autotuner — carries separate backward dQ/dKV tiles) or the legacy
    ``block_q``/``block_k`` pair; ``None`` means auto (REPRO_TUNE)."""
    scale = float(scale) if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    if interpret is None:
        interpret = default_interpret()
    if blocks is None:
        blocks = _resolve_flash_blocks(q, k, causal, interpret, block_q, block_k)
    return _flash_attention_jit(q, k, v, causal, scale, blocks, interpret)


# ---------------------------------------------------------------------------
# DistrAttention with custom_vjp
# ---------------------------------------------------------------------------


def distr_stage1(cfg, qp, scale, *, hkv: int | None = None):
    """The paper's lightweight pre-kernel stage (§4.8) on a
    ``block_q``-padded q (B, Hq, N_pad, d): per-Q-block LSH permutations +
    Q̂ sampling, with the softmax scale pre-folded.  Returns
    (q_hat (B, Hq, N_pad, d/G*), perms (B, Hq, nq, d)).  ``hkv`` enables
    the shared-KV-perm variant (one permutation per KV group, hashed from
    the group's mean query block).  The one implementation for the
    single-device op *and* the ring (distributed.ring_attention) — the
    grouping decision must never diverge between them."""
    b, hq, n_pad, d = qp.shape
    g = cfg.group_size
    nq_blocks = n_pad // cfg.block_q

    proj = lsh.make_projection(jax.random.PRNGKey(cfg.proj_seed), cfg.block_q)
    if cfg.shared_kv_perm:
        if hkv is None:
            raise ValueError("shared_kv_perm needs the KV head count")
        q_per_kv = hq // hkv
        q_mean = qp.reshape(b, hkv, q_per_kv, n_pad, d).mean(axis=2)
        perms = compute_block_permutations(q_mean, cfg, proj)  # (b, hkv, nq, d)
        perms = jnp.broadcast_to(
            perms[:, :, None], (b, hkv, q_per_kv, nq_blocks, d)
        ).reshape(b, hq, nq_blocks, d)
    else:
        perms = compute_block_permutations(qp, cfg, proj)  # (b, hq, nq, d)
    # Straight-through: the permutation is a fixed discrete grouping choice;
    # no gradient flows into the hash (paper's fixed-grouping semantics).
    perms = jax.lax.stop_gradient(perms)

    q_blocks = qp.reshape(b, hq, nq_blocks, cfg.block_q, d)
    if cfg.estimator == "sample":
        q_hat = grouping.sample_columns(q_blocks, perms, g)
    elif cfg.estimator == "mean":
        q_hat = grouping.mean_columns(q_blocks, perms, g)
    else:
        raise ValueError(f"unknown estimator {cfg.estimator!r}")
    q_hat = (q_hat * scale).reshape(b, hq, n_pad, d // g).astype(qp.dtype)
    return q_hat, perms


def _distr_fwd_impl(cfg, causal, scale, interpret, q, k, v, with_residuals):
    """Returns (out, lse, q_hat_flat, perms) — the kernel-path residuals
    (lse is None on the primal path, which skips emitting it)."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv
    g = cfg.group_size

    qp, n_orig = _pad_seq(q, cfg.block_q)
    kp, kv_len = _pad_seq(k, cfg.block_k)
    vp, _ = _pad_seq(v, cfg.block_k)
    n_pad = qp.shape[2]
    nq_blocks = n_pad // cfg.block_q

    q_hat, perms = distr_stage1(cfg, qp, scale, hkv=hkv)
    q_hat = q_hat.reshape(b * hq, n_pad, d // g)

    res = distr_attention_kernel_call(
        q_hat,
        _flatten_heads(kp),
        _flatten_heads(vp),
        perms.reshape(b * hq, nq_blocks, d),
        q_per_kv=q_per_kv, causal=causal, group_size=g,
        block_q=cfg.block_q, block_k=cfg.block_k, kv_len=kv_len,
        interpret=interpret, return_residuals=with_residuals,
    )
    out, lse = res if with_residuals else (res, None)
    return out.reshape(b, hq, -1, d)[:, :, :n_orig, :], lse, q_hat, perms


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _distr_attention(cfg, causal, scale, interpret, q, k, v):
    out, _, _, _ = _distr_fwd_impl(
        cfg, causal, scale, interpret, q, k, v, with_residuals=False
    )
    return out


def _distr_vjp_fwd(cfg, causal, scale, interpret, q, k, v):
    out, lse, q_hat, perms = _distr_fwd_impl(
        cfg, causal, scale, interpret, q, k, v, with_residuals=True
    )
    return out, (q, k, v, out, lse, q_hat, perms)


def distr_dq_from_dq_hat(estimator, dq_hat, perms, *, block_q, group_size,
                         scale):
    """dQ̂ → dQ: transpose of the Q̂ sampling/mean gather with the
    forward's pre-scale folded in.  dq_hat: (B, Hq, N_pad, d/G*); perms:
    (B, Hq, nq, d) → (B, Hq, N_pad, d) f32.  Shared by the single-device
    ``custom_vjp`` and the ring backward (distributed.ring_attention) so
    the estimator chain rule cannot diverge between them."""
    b, hq, n_pad, dg = dq_hat.shape
    d = perms.shape[-1]
    nq_blocks = n_pad // block_q
    sample_fn = (
        grouping.sample_columns if estimator == "sample"
        else grouping.mean_columns
    )
    blocks_ = (
        dq_hat.astype(jnp.float32).reshape(b, hq, nq_blocks, block_q, dg)
        * scale
    )
    (dq_blocks,) = jax.linear_transpose(
        lambda t: sample_fn(t, perms, group_size),
        jax.ShapeDtypeStruct(
            (b, hq, nq_blocks, block_q, d), jnp.float32
        ),
    )(blocks_)
    return dq_blocks.reshape(b, hq, n_pad, d)


def resolve_distr_bwd_blocks(cfg, *, d, n, dtype, causal, interpret):
    """Backward KV tiles ``(bk_dq, bk_dkv)`` for the distr kernels
    (mirrors ``_resolve_bwd_blocks``).  ``block_q`` is *never* resolved
    here: it is the LSH grouping granularity shared with the forward and
    the saved permutations, and stays pinned (asserted in
    ``Autotuner.resolve_distr_bwd``).  Explicit ``cfg.block_k_bwd`` wins;
    outside measure mode the fwd ``block_k`` carries over.  The one
    resolver for both the single-device custom_vjp (lazy, at
    backward-trace time) and the ring backward (eager, at dispatch, with
    ``n`` = the per-device shard)."""
    if cfg.block_k_bwd is not None:
        return cfg.block_k_bwd, cfg.block_k_bwd
    from repro.tune.autotune import get_autotuner, tune_mode

    if tune_mode() != "measure":
        return cfg.block_k, cfg.block_k
    tuner = get_autotuner()
    kw = dict(
        block_q=cfg.block_q, d=d, n=n, dtype=dtype, group_size=cfg.group_size,
        causal=causal, interpret=interpret, fwd_block_k=cfg.block_k,
    )
    return (
        tuner.resolve_distr_bwd("distr_dq", **kw)[1],
        tuner.resolve_distr_bwd("distr_dkv", **kw)[1],
    )


def _distr_vjp_bwd(cfg, causal, scale, interpret, res, do):
    q, k, v, o, lse, q_hat, perms = res
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv
    g = cfg.group_size
    dg = d // g
    kv_len = k.shape[2]
    bk_dq, bk_dkv = resolve_distr_bwd_blocks(
        cfg, d=d, n=max(n, kv_len), dtype=_dtype_str(q), causal=causal,
        interpret=interpret,
    )

    dop, n_orig = _pad_seq(do.astype(q.dtype), cfg.block_q)
    op, _ = _pad_seq(o, cfg.block_q)
    n_pad = dop.shape[2]
    nq_blocks = n_pad // cfg.block_q

    def kv_side(block):
        kp, _ = _pad_seq(k, block)
        vp, _ = _pad_seq(v, block)
        return _flatten_heads(kp), _flatten_heads(vp)

    kf1, vf1 = kv_side(bk_dq)
    kf2, vf2 = (kf1, vf1) if bk_dkv == bk_dq else kv_side(bk_dkv)
    dof, of = _flatten_heads(dop), _flatten_heads(op)
    perm_f = perms.reshape(b * hq, nq_blocks, d)
    # A permutation's inverse is its argsort; the dkv kernel turns the
    # segment-sum transpose (scatter-add over perm) into a gather by it.
    inv_perm_f = jnp.argsort(perm_f, axis=-1).astype(perm_f.dtype)

    delta = bwd.delta_kernel_call(of, dof, block_q=cfg.block_q, interpret=interpret)
    dq_hat = bwd.distr_dq_kernel_call(
        q_hat, kf1, vf1, perm_f, dof, lse, delta,
        q_per_kv=q_per_kv, causal=causal, group_size=g,
        block_q=cfg.block_q, block_k=bk_dq, kv_len=kv_len,
        interpret=interpret,
    )
    dk_h, dv_h = bwd.distr_dkv_kernel_call(
        q_hat, kf2, vf2, perm_f, inv_perm_f, dof, lse, delta,
        q_per_kv=q_per_kv, causal=causal, group_size=g,
        block_q=cfg.block_q, block_k=bk_dkv, kv_len=kv_len,
        interpret=interpret,
    )

    dq_full = distr_dq_from_dq_hat(
        cfg.estimator, dq_hat.reshape(b, hq, n_pad, dg), perms,
        block_q=cfg.block_q, group_size=g, scale=scale,
    )
    dq = dq_full[:, :, :n_orig, :].astype(q.dtype)
    dk = _gqa_sum(dk_h, b, hkv, q_per_kv, kv_len).astype(k.dtype)
    dv = _gqa_sum(dv_h, b, hkv, q_per_kv, kv_len).astype(v.dtype)
    return dq, dk, dv


_distr_attention.defvjp(_distr_vjp_fwd, _distr_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("cfg", "causal", "scale", "interpret"))
def _distr_attention_jit(q, k, v, cfg, causal, scale, interpret):
    return _distr_attention(cfg, causal, scale, interpret, q, k, v)


def distr_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: DistrConfig = DistrConfig(),
    *,
    causal: bool = False,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """DistrAttention Pallas kernel (paper §3.3 + FA-2 integration),
    differentiable under straight-through permutations.

    Stage 1 (outside kernel, XLA): LSH permutations per Q block + Q sampling.
    Stage 2 (kernel): per-KV-block fusion + reduced-d flash attention.

    ``cfg.block_q``/``block_k`` may be None (auto): resolved here through
    the autotuner under the Pallas "distr" kind.
    """
    scale = float(scale) if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    if interpret is None:
        interpret = default_interpret()
    cfg = cfg.resolved(
        q.shape[-1], max(q.shape[2], k.shape[2]), dtype=_dtype_str(q),
        causal=causal, xla=False, interpret=interpret,
    )
    return _distr_attention_jit(q, k, v, cfg, causal, scale, interpret)


# ---------------------------------------------------------------------------
# Flash-decoding (split-K) — the serve-path hot op
# ---------------------------------------------------------------------------


def _pack_gqa_rows(q: jnp.ndarray, hkv: int) -> tuple[jnp.ndarray, int]:
    """(B, Hq, q_len, d) → (B, Hkv, rows_pad, d): all query heads sharing a
    KV head (× q_len) packed into the kernel's row dimension, padded to the
    sublane width.  Returns (packed, rows_live)."""
    b, hq, q_len, d = q.shape
    rows_live = (hq // hkv) * q_len
    packed = q.reshape(b, hkv, rows_live, d)
    pad = (-rows_live) % decode_kernels.ROW_ALIGN
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return packed, rows_live


def _unpack_gqa_rows(o: jnp.ndarray, rows_live: int, hq: int) -> jnp.ndarray:
    """(B, Hkv, rows_pad, d) → (B, Hq, q_len, d)."""
    b, hkv, _, d = o.shape
    q_len = rows_live * hkv // hq
    return o[:, :, :rows_live, :].reshape(b, hq, q_len, d)


def _decode_impl(q_packed, k_score, v, lengths, *, hq, rows_live, scale,
                 block_k, q_len, interpret):
    nk = k_score.shape[2]
    block_k = min(block_k, nk)
    pad = (-nk) % block_k
    if pad:  # dead tail: clamped index maps keep it out of the KV stream
        k_score = jnp.pad(k_score, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    o, m, l = decode_kernels.decode_kernel_call(
        q_packed, k_score, v, lengths,
        scale=scale, block_k=block_k, q_len=q_len, interpret=interpret,
    )
    return _unpack_gqa_rows(
        decode_kernels.merge_splits(o, m, l), rows_live, hq
    )


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "q_len", "interpret")
)
def _decode_attention_jit(q, k, v, lengths, scale, block_k, q_len, interpret):
    b, hq, _, d = q.shape
    hkv = k.shape[1]
    q_packed, rows_live = _pack_gqa_rows(q, hkv)
    out = _decode_impl(
        q_packed, k, v, lengths, hq=hq, rows_live=rows_live, scale=scale,
        block_k=block_k, q_len=q_len, interpret=interpret,
    )
    return out.astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "scale", "block_k", "q_len", "interpret"),
)
def _decode_attention_fused_jit(q, k_fused, v, perm, lengths, group_size,
                                scale, block_k, q_len, interpret):
    hq, hkv = q.shape[1], k_fused.shape[1]
    # Sample Q columns under the layer's static per-KV-head permutation —
    # decode has no per-Q-block LSH stage (serve.kv_cache.static_perms).
    q_s = grouping.sample_q_heads(q, perm, group_size)
    q_packed, rows_live = _pack_gqa_rows(q_s, hkv)
    out = _decode_impl(
        q_packed, k_fused, v, lengths, hq=hq, rows_live=rows_live,
        scale=scale, block_k=block_k, q_len=q_len, interpret=interpret,
    )
    return out.astype(q.dtype)


def _decode_lengths(lengths, b: int, nk: int) -> jnp.ndarray:
    if lengths is None:
        lengths = jnp.full((b,), nk, jnp.int32)
    return jnp.minimum(jnp.asarray(lengths, jnp.int32), nk)


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    lengths: jnp.ndarray | None = None,
    k_fused: jnp.ndarray | None = None,
    perm: jnp.ndarray | None = None,
    group_size: int = 1,
    scale: float | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Split-K flash-decoding over a KV cache (kernels/decode.py).

    q: (B, Hq, q_len, d) with q_len small (1, or a short speculative
    window); k, v: (B, Hkv, S, d) cache; ``lengths``: (B,) live token
    counts — the kernel grid only streams ``ceil(length/block_k)`` KV
    blocks per slot (None ⇒ all S live).

    Distr fused-K̂ variant: pass ``k_fused`` (B, Hkv, S, d/G*), the layer's
    static ``perm`` (Hkv, d) and ``group_size`` — the score stage streams
    the narrow fused cache (column-sampled Q), the value stage full V; raw
    ``k`` may be None (it stays cold on the serve path).  ``scale`` always
    refers to the full head dim (default 1/√d).  ``interpret=None``
    auto-detects the backend like every other op here.
    """
    d = v.shape[-1]
    q_len = q.shape[2]
    scale = float(scale) if scale is not None else 1.0 / (d ** 0.5)
    if interpret is None:
        interpret = default_interpret()
    if block_k is None:
        # Auto: tuned split length for this cache capacity (REPRO_TUNE).
        from repro.tune.autotune import resolve_decode_block

        nk_cache = (k_fused if k_fused is not None else k).shape[2]
        block_k = resolve_decode_block(
            d=d, n=nk_cache, dtype=_dtype_str(v),
            group_size=group_size if k_fused is not None else 1,
            interpret=interpret,
        )
    if k_fused is not None:
        if perm is None or group_size <= 1:
            raise ValueError("k_fused needs perm and group_size > 1")
        lengths = _decode_lengths(lengths, q.shape[0], k_fused.shape[2])
        return _decode_attention_fused_jit(
            q, k_fused, v, perm, lengths, group_size, scale, block_k, q_len,
            interpret,
        )
    lengths = _decode_lengths(lengths, q.shape[0], k.shape[2])
    return _decode_attention_jit(
        q, k, v, lengths, scale, block_k, q_len, interpret
    )


# ---------------------------------------------------------------------------
# Paged (block-table) flash-decoding — the paged serve-path hot op
# ---------------------------------------------------------------------------


def _paged_decode_impl(q_packed, k_pool, v_pool, block_tables, lengths, *,
                       hq, rows_live, scale, q_len, interpret):
    o, m, l = paged_decode_kernels.paged_decode_kernel_call(
        q_packed, k_pool, v_pool, block_tables, lengths,
        scale=scale, q_len=q_len, interpret=interpret,
    )
    return _unpack_gqa_rows(
        decode_kernels.merge_splits(o, m, l), rows_live, hq
    )


@functools.partial(
    jax.jit, static_argnames=("scale", "q_len", "interpret")
)
def _paged_decode_attention_jit(q, k_pool, v_pool, block_tables, lengths,
                                scale, q_len, interpret):
    hq, hkv = q.shape[1], k_pool.shape[1]
    q_packed, rows_live = _pack_gqa_rows(q, hkv)
    out = _paged_decode_impl(
        q_packed, k_pool, v_pool, block_tables, lengths, hq=hq,
        rows_live=rows_live, scale=scale, q_len=q_len, interpret=interpret,
    )
    return out.astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("group_size", "scale", "q_len", "interpret")
)
def _paged_decode_attention_fused_jit(q, k_fused_pool, v_pool, perm,
                                      block_tables, lengths, group_size,
                                      scale, q_len, interpret):
    hq, hkv = q.shape[1], k_fused_pool.shape[1]
    # Static per-KV-head permutation, same as the contiguous fused decode —
    # paged decode has no per-Q-block LSH stage (serve.kv_cache.static_perms).
    q_s = grouping.sample_q_heads(q, perm, group_size)
    q_packed, rows_live = _pack_gqa_rows(q_s, hkv)
    out = _paged_decode_impl(
        q_packed, k_fused_pool, v_pool, block_tables, lengths, hq=hq,
        rows_live=rows_live, scale=scale, q_len=q_len, interpret=interpret,
    )
    return out.astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray | None,
    v_pool: jnp.ndarray,
    *,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    k_fused_pool: jnp.ndarray | None = None,
    perm: jnp.ndarray | None = None,
    group_size: int = 1,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Block-table split-K flash-decoding over a paged KV pool
    (kernels/paged_decode.py).

    q: (B, Hq, q_len, d) with q_len small (1, or a chunked-prefill window);
    k_pool, v_pool: (P, Hkv, block_size, d) shared block pools;
    ``block_tables``: (B, max_blocks) int32 physical block ids (logical
    block j of request b lives at ``block_tables[b, j]``); ``lengths``:
    (B,) live token counts — the kernel streams ``ceil(length/block_size)``
    pool blocks per request through scalar-prefetched, clamped index maps.

    Distr fused-K̂ variant: pass ``k_fused_pool`` (P, Hkv, block_size,
    d/G*), the layer's static ``perm`` (Hkv, d) and ``group_size`` — the
    score stage streams the narrow fused pool (column-sampled Q), the value
    stage full V; ``k_pool`` may be None (raw K stays cold on the paged
    serve path).  ``scale`` always refers to the full head dim (default
    1/√d).  ``interpret=None`` auto-detects the backend.
    """
    d = v_pool.shape[-1]
    q_len = q.shape[2]
    scale = float(scale) if scale is not None else 1.0 / (d ** 0.5)
    if interpret is None:
        interpret = default_interpret()
    block_size = v_pool.shape[2]
    capacity = block_tables.shape[1] * block_size
    if lengths is None:
        # None ⇒ every table position live (contiguous-op convention).
        lengths = jnp.full((q.shape[0],), capacity, jnp.int32)
    else:
        # Deliberately NOT clamped to capacity: a padded chunked-prefill
        # window may overhang it (lengths = pos + w with the last rows
        # dead), and clamping would shift the LIVE rows' causal band
        # ``col < length − (q_len−1−i)`` downward — silently dropping
        # their most recent context.  The kernel is safe unclamped: the
        # index map's split id never exceeds the table width (jj ≤ j),
        # and live rows' bands always land within capacity.
        lengths = jnp.asarray(lengths, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    if k_fused_pool is not None:
        if perm is None or group_size <= 1:
            raise ValueError("k_fused_pool needs perm and group_size > 1")
        return _paged_decode_attention_fused_jit(
            q, k_fused_pool, v_pool, perm, block_tables, lengths, group_size,
            scale, q_len, interpret,
        )
    return _paged_decode_attention_jit(
        q, k_pool, v_pool, block_tables, lengths, scale, q_len, interpret
    )


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, a, b, c, chunk, interpret):
    bsz, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    heads_per_group = h // g
    pad = (-n) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_pad = x.shape[1]

    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, n_pad, p)
    ar = a.transpose(0, 2, 1).reshape(bsz * h, n_pad, 1)
    br = b.transpose(0, 2, 1, 3).reshape(bsz * g, n_pad, s)
    cr = c.transpose(0, 2, 1, 3).reshape(bsz * g, n_pad, s)

    y = ssd_kernel_call(
        xr, ar, br, cr, heads_per_group=heads_per_group, chunk=chunk,
        interpret=interpret,
    )
    y = y.reshape(bsz, h, n_pad, p).transpose(0, 2, 1, 3)
    return y[:, :n, :, :]


def ssd(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    chunk: int = 64,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Mamba-2 SSD.  x: (B,N,H,P); a: (B,N,H); b,c: (B,N,G,S)."""
    if interpret is None:
        interpret = default_interpret()
    return _ssd_jit(x, a, b, c, chunk, interpret)


# ---------------------------------------------------------------------------
# Analytic cost models (benchmarks + roofline corrections).
# ---------------------------------------------------------------------------


def attention_cost(
    b: int,
    hq: int,
    n: int,
    nk: int,
    d: int,
    *,
    causal: bool = False,
    group_size: int = 1,
    block_q: int = 128,
) -> dict:
    """FLOPs / bytes model of (Distr)FlashAttention, forward AND backward.

    Forward keys model one fused forward pass: MXU matmul FLOPs, VPU fusion
    adds, and HBM bytes (bf16 in/out, the flash structure never materialises
    S/P).  ``bwd_*`` keys model the kernels/backward.py pass: the dQ kernel
    recomputes S and runs dP, dQ; the dK/dV kernel recomputes S and runs dP,
    dV, dK; plus the D = rowsum(dO ∘ O) precompute.  Score-space matmuls
    (S, dQ, dK) contract over d/G*; context-space ones (dP, dV) over the
    full d.  ``group_size=1`` = exact FA-2.
    """
    frac = 0.5 * (1 + 1 / max(nk // max(block_q, 1), 1)) if causal else 1.0
    d_eff = d // group_size
    score_mm = 2 * b * hq * n * nk * d_eff * frac  # one reduced-d matmul
    full_mm = 2 * b * hq * n * nk * d * frac  # one full-d matmul
    qk_flops = score_mm
    pv_flops = full_mm
    softmax_flops = 4 * b * hq * n * nk * frac  # exp, max, sum, scale
    # K fusion: for each (q-block, kv element) a d-length permuted add chain.
    fusion_adds = (
        b * hq * (n // max(block_q, 1)) * nk * d * frac if group_size > 1 else 0
    )
    lsh_flops = (
        2 * b * hq * (n // max(block_q, 1)) * lsh.N_PRIME * block_q * d
        if group_size > 1
        else 0
    )
    w = 2  # bf16
    io_bytes = w * (
        b * hq * n * ((d + d // group_size) if group_size > 1 else d)  # Q (+Q̂)
        # K̂ is (re)built inside the kernel and never leaves VMEM: 0 bytes.
        + 2 * b * hq * nk * d  # K, V read (per-head upper bound)
        + b * hq * n * d  # O write
    )

    # ---- backward (kernels/backward.py structure) ----------------------
    # dq kernel: S recompute (d_eff) + dP (d) + dQ (d_eff)
    # dkv kernel: S recompute (d_eff) + dP (d) + dV (d) + dK (d_eff)
    bwd_mxu_flops = 4 * score_mm + 3 * full_mm
    # P from saved LSE (exp) twice + dS = P∘(dP−D) twice + D precompute.
    bwd_vpu_flops = 6 * b * hq * n * nk * frac + 2 * b * hq * n * d
    # K̂ re-fused in both backward kernels; dK̂ replication adds back to d.
    bwd_fusion_adds = 3 * fusion_adds
    bwd_io_bytes = w * (
        2 * b * hq * n * ((d + d // group_size) if group_size > 1 else d)  # Q(+Q̂) ×2 kernels
        + 4 * b * hq * nk * d  # K, V read in both kernels
        + 4 * b * hq * n * d  # dO read ×2 kernels + O + dO reads (delta)
    ) + 4 * (
        # LSE + D are per-row f32 scalars in HBM: one write each (fwd kernel /
        # delta kernel) + one read each in both backward kernels = 6n.  The
        # implementation matches (kernels store (BHq, N) f32 and re-broadcast
        # in-kernel, DESIGN.md §Backward) — no lane-replication factor.
        6 * b * hq * n
        + b * hq * n * d  # dQ write, f32
        + 2 * b * hq * nk * d  # per-q-head dK, dV writes, f32
    )

    return {
        "qk_flops": qk_flops,
        "pv_flops": pv_flops,
        "softmax_flops": softmax_flops,
        "fusion_adds": fusion_adds,
        "lsh_flops": lsh_flops,
        "mxu_flops": qk_flops + pv_flops,
        "total_flops": qk_flops + pv_flops + softmax_flops + fusion_adds + lsh_flops,
        "hbm_bytes": io_bytes,
        "bwd_mxu_flops": bwd_mxu_flops,
        "bwd_total_flops": bwd_mxu_flops + bwd_vpu_flops + bwd_fusion_adds,
        "bwd_hbm_bytes": bwd_io_bytes,
        "fwd_bwd_mxu_flops": qk_flops + pv_flops + bwd_mxu_flops,
        "fwd_bwd_hbm_bytes": io_bytes + bwd_io_bytes,
    }


def ssd_cost(b: int, n: int, h: int, p: int, s: int, *, chunk: int = 64) -> dict:
    """FLOPs model of chunked SSD forward."""
    nc = n // chunk
    intra = 2 * b * h * nc * (chunk * chunk * s + chunk * chunk * p)
    inter = 2 * b * h * nc * (chunk * s * p * 2)
    return {"total_flops": intra + inter, "mxu_flops": intra + inter}
