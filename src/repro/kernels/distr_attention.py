"""DistrAttention Pallas TPU kernel (paper §3.3 fused into FA-2).

Differences from the exact flash kernel:

* Q arrives pre-sampled (``q_hat``, trailing dim ``d/G*``, pre-scaled): the
  per-Q-block LSH permutation is computed outside the kernel (the paper also
  runs grouping as a separate lightweight stage, §4.8) and Q-sampling is a
  cheap one-off gather there.
* Each KV block is **fused in-kernel** under the current Q-block's
  permutation: gather K's d columns by ``perm`` then segment-sum runs of
  ``G*``.  This must live in the kernel: K̂ depends on (Q block, K block)
  jointly, and materialising it outside would cost O(N²·d/G*) memory.
* The score matmul contracts over ``d/G*`` instead of ``d`` — the paper's
  compute reduction.  V and the PV matmul are untouched (full context).

TPU note (DESIGN.md §2): the column gather runs on the VPU (lane shuffles /
one-hot matmul under Mosaic), freeing MXU cycles; on GPUs the paper uses warp
shuffles.  Validated against ``ref.distr_attention_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF, STATS_LANES
from repro.kernels.tpu_compat import CompilerParams


def fuse_k_columns(k, perm, group_size: int):
    """The paper's fusion: permute K's columns by ``perm``, segment-sum runs
    of ``G*``.  Shared by the forward and backward kernels — the backward's
    recomputed K̂ must be bit-identical to what produced the saved LSE."""
    k_perm = jnp.take(k, perm, axis=1)  # lane gather (VPU)
    d = k.shape[1]
    return k_perm.reshape(k.shape[0], d // group_size, group_size).sum(axis=2)


def _distr_kernel(
    q_hat_ref,
    k_ref,
    v_ref,
    perm_ref,
    o_ref,
    *rest,
    causal: bool,
    group_size: int,
    block_q: int,
    block_k: int,
    kv_len: int,
    with_lse: bool,
):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q_hat = q_hat_ref[...].astype(jnp.float32)  # (block_q, dg) pre-scaled
        k = k_ref[...].astype(jnp.float32)  # (block_k, d)
        v = v_ref[...].astype(jnp.float32)  # (block_k, d)
        perm = perm_ref[0]  # (d,) int32 — this Q block's permutation

        # --- the paper's fusion: permute K columns, sum each run of G*.
        k_hat = fuse_k_columns(k, perm, group_size)

        s = jax.lax.dot_general(
            q_hat, k_hat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (block_q, block_k) — contraction over d/G* only.

        col = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col < kv_len
        if causal:
            row = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = jnp.logical_and(mask, col <= row)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, :1]
        l_prev = l_scr[...][:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)

        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l_final = l_scr[...][:, :1]
        denom = jnp.where(l_final == 0.0, 1.0, l_final)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if with_lse:
            m_final = m_scr[...][:, :1]
            lse = jnp.where(l_final == 0.0, NEG_INF, m_final + jnp.log(denom))
            lse_ref[...] = lse[:, 0]  # per-row f32 (not lane-replicated)


def distr_attention_kernel_call(
    q_hat: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    perm: jnp.ndarray,
    *,
    q_per_kv: int,
    causal: bool,
    group_size: int,
    block_q: int,
    block_k: int,
    kv_len: int,
    interpret: bool = True,
    return_residuals: bool = False,
):
    """Raw pallas_call.

    q_hat: (BHq, N, d/G*) pre-sampled & pre-scaled queries (padded N).
    k, v:  (BHkv, Nk, d) (padded Nk).
    perm:  (BHq, N/block_q, d) int32 per-Q-block permutations.

    Returns ``o`` or ``(o, lse)`` (per-row logsumexp, ``(BHq, N)`` f32) when
    ``return_residuals`` — the residual consumed by kernels/backward.py.
    """
    bhq, n, dg = q_hat.shape
    bhkv, nk_len, d = k.shape
    assert bhq == bhkv * q_per_kv, (bhq, bhkv, q_per_kv)
    assert dg * group_size == d, (dg, group_size, d)

    grid = (bhq, n // block_q, nk_len // block_k)

    kernel = functools.partial(
        _distr_kernel,
        causal=causal,
        group_size=group_size,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        with_lse=return_residuals,
    )
    out_specs = pl.BlockSpec((None, block_q, d), lambda bh, i, j: (bh, i, 0))
    out_shape = jax.ShapeDtypeStruct((bhq, n, d), q_hat.dtype)
    if return_residuals:
        out_specs = [
            out_specs,
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
        ]
        out_shape = [
            out_shape,
            jax.ShapeDtypeStruct((bhq, n), jnp.float32),
        ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dg), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, i, j: (bh // q_per_kv, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, i, j: (bh // q_per_kv, j, 0)),
            pl.BlockSpec((None, 1, d), lambda bh, i, j: (bh, i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="distr_attention_fwd",
    )(q_hat, k, v, perm)
