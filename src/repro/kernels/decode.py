"""Flash-decoding Pallas TPU kernel: split-K partitioning over the KV length.

Decode is the opposite regime from the training/prefill kernels: one (or a
handful of speculative) query rows against a long KV cache.  The forward
kernels' grid — many Q blocks, KV innermost — collapses to a single serial
KV walk per head, leaving the chip idle.  FlashAttention-2's split-K
work-partitioning (Dao, 2023) restores parallelism: the KV length is cut
into independent splits, each split computes an *unnormalised* partial

    o_j = exp(s_j − m_j) · V_j,   m_j = rowmax(s_j),   l_j = rowsum(exp(s_j − m_j))

and a cheap cross-split logsumexp merge combines them:

    m* = max_j m_j,   l* = Σ_j l_j·exp(m_j − m*),
    o  = Σ_j o_j·exp(m_j − m*) / l*.

The merge is O(splits · rows · d) — noise next to the KV stream — and runs
as plain XLA in the ops.py wrapper (kernels/ops.py::decode_attention).

Design points:

* **GQA head-packing.**  The grid is ``(B, Hkv, splits)``; all ``q_per_kv``
  query heads sharing a KV head (× the small ``q_len``) are packed into the
  kernel's row dimension, so one kernel instance amortises the K/V stream
  over the whole GQA group — K/V are read once per *KV* head, the decode
  bandwidth bound.  Rows are padded to the f32 sublane width (8) by the
  wrapper.

* **Length-aware grid.**  Per-slot live lengths arrive via scalar prefetch
  (``PrefetchScalarGridSpec``): the K/V BlockSpec index maps clamp dead
  split indices to the slot's last live split, so the pipeline re-fetches an
  already-resident block instead of streaming dead cache — per-token KV
  traffic scales with ``ceil(length/block_k)``, not ``max_len`` (the ring
  cache invariant, DESIGN.md §Decode).  Dead splits skip compute entirely
  (``@pl.when``) and emit ``m = −inf, l = 0`` so the merge ignores them; the
  tail split masks columns ``≥ length`` within the block.

* **One kernel, two cache layouts.**  The score width is whatever ``q``/``k``
  carry: the plain variant streams the raw K cache (width ``d``); the distr
  fused-K̂ variant streams the ``d/G*``-wide ``k_fused`` cache with
  column-sampled queries (the layer's static permutation is applied by the
  wrapper — decode has no per-Q-block LSH stage).  The value stage always
  reads full-width V.

* **Small-q_len causality.**  For speculative decode (``q_len > 1``) packed
  row ``r`` holds query token ``i = r mod q_len``; it may attend to cache
  positions ``< length − (q_len − 1 − i)`` — the standard "each new token
  sees the cache plus its predecessors" band, degenerate for ``q_len = 1``.

Validated against the pure-JAX decode references in
``tests/test_kernels_decode.py`` (interpret mode on CPU; compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF
from repro.kernels.tpu_compat import CompilerParams

ROW_ALIGN = 8  # f32 sublane width: the wrapper pads packed rows to this


def _decode_kernel(
    lens_ref,  # scalar prefetch: (B,) int32 live lengths
    q_ref,  # (1, 1, rows, d_score)
    k_ref,  # (1, 1, block_k, d_score)
    v_ref,  # (1, 1, block_k, d)
    o_ref,  # (1, 1, 1, rows, d)      unnormalised partial
    m_ref,  # (1, 1, 1, rows)         per-split row max
    l_ref,  # (1, 1, 1, rows)         per-split row sum
    *,
    scale: float,
    block_k: int,
    q_len: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = lens_ref[b]

    # Dead split: this slot's live KV ends before block j.  The index map
    # already re-pointed the DMA at the last live block; skip the math and
    # emit identity stats for the merge.
    live = j * block_k < length

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (rows, d_score)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d_score)
        v = v_ref[0, 0].astype(jnp.float32)  # (block_k, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (rows, block_k)

        col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # Packed row r is query token i = r % q_len; it sees the cache up to
        # length − (q_len − 1 − i) tokens (q_len = 1 ⇒ plain `col < length`).
        row_tok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_len
        row_len = length - (q_len - 1 - row_tok)
        mask = col < row_len
        s = jnp.where(mask, s, NEG_INF)

        m = s.max(axis=1)  # (rows,)
        p = jnp.where(mask, jnp.exp(s - m[:, None]), 0.0)
        o_ref[0, 0, 0] = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[0, 0, 0] = m
        l_ref[0, 0, 0] = p.sum(axis=1)

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[0, 0, 0] = jnp.zeros_like(o_ref[0, 0, 0])
        m_ref[0, 0, 0] = jnp.full_like(m_ref[0, 0, 0], NEG_INF)
        l_ref[0, 0, 0] = jnp.zeros_like(l_ref[0, 0, 0])


def decode_kernel_call(
    q: jnp.ndarray,  # (B, Hkv, rows, d_score) — GQA-packed (+ padded) queries
    k: jnp.ndarray,  # (B, Hkv, Nk, d_score)   — raw K or fused K̂ cache
    v: jnp.ndarray,  # (B, Hkv, Nk, d)
    lengths: jnp.ndarray,  # (B,) int32 live token counts (≤ Nk)
    *,
    scale: float,
    block_k: int,
    q_len: int,
    interpret: bool = True,
):
    """Raw pallas_call → unnormalised split partials ``(o, m, l)``.

    o: (B, Hkv, splits, rows, d) f32;  m, l: (B, Hkv, splits, rows) f32.
    The caller performs the cross-split LSE merge (ops.py) — keeping the
    merge outside lets the splits run fully parallel with no cross-split
    scratch carry.
    """
    b, hkv, rows, d_score = q.shape
    nk, d = k.shape[2], v.shape[3]
    assert nk % block_k == 0, (nk, block_k)
    assert rows % ROW_ALIGN == 0, rows
    splits = nk // block_k

    def q_index(bi, h, j, lens):
        return (bi, h, 0, 0)

    def kv_index(bi, h, j, lens):
        # Clamp dead splits to the slot's last live split: the pipeline sees
        # a repeated block index and skips the DMA — dead KV is never
        # streamed, so per-token traffic tracks the live length.
        last_live = jnp.maximum(pl.cdiv(lens[bi], block_k) - 1, 0)
        return (bi, h, jnp.minimum(j, last_live), 0)

    def out_index(bi, h, j, lens):
        return (bi, h, j, 0, 0)

    def stat_index(bi, h, j, lens):
        return (bi, h, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, splits),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d_score), q_index),
            pl.BlockSpec((1, 1, block_k, d_score), kv_index),
            pl.BlockSpec((1, 1, block_k, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, rows, d), out_index),
            pl.BlockSpec((1, 1, 1, rows), stat_index),
            pl.BlockSpec((1, 1, 1, rows), stat_index),
        ],
    )
    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, q_len=q_len
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, splits, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, splits, rows), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, splits, rows), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
        name="flash_decode_splitk",
    )(lengths, q, k, v)


def merge_splits(o: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    """Cross-split LSE merge (flash-decoding reduction).

    o: (..., splits, rows, d) unnormalised partials; m, l: (..., splits, rows).
    Returns the normalised (..., rows, d) attention output (f32).  Rows whose
    every split is dead (length 0 / padding) come out exactly zero.
    """
    m_star = m.max(axis=-2)  # (..., rows)
    alpha = jnp.exp(m - m_star[..., None, :])  # (..., splits, rows)
    l_star = (l * alpha).sum(axis=-2)  # (..., rows)
    o_sum = (o * alpha[..., None]).sum(axis=-3)  # (..., rows, d)
    denom = jnp.where(l_star == 0.0, 1.0, l_star)
    return o_sum / denom[..., None]
