"""FlashAttention-2 Pallas TPU kernel (exact baseline).

TPU adaptation of the paper's §2.2.2 baseline: grid ``(B·Hq, N/l, Nk/m)``,
``BlockSpec`` VMEM tiles, online softmax with fp32 scratch accumulators.
KV-block iteration is the innermost ("arbitrary") grid dimension so the
``(m, l, acc)`` scratch persists across it — the Pallas equivalent of FA-2's
inner loop held in registers/SMEM.

With ``return_residuals=True`` the kernel additionally emits the per-row
logsumexp ``L = m + log l`` as a plain ``(BHq, N)`` f32 row vector — the
only softmax statistic the FA-2 backward needs; dQ/dK/dV then recompute
the score blocks instead of materialising them (kernels/backward.py).
Only the VMEM scratch keeps the lane-replicated ``(block_q, 128)`` layout
(TPU vector layouts want a lane-width minor dim); the HBM residual is
per-row — 128× less stats traffic than replicating the scratch layout out
(DESIGN.md §Backward).

Validated against ``ref.flash_attention_ref`` under ``interpret=True`` (this
container is CPU-only); on real TPUs the ops.py wrapper auto-selects
compiled mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

NEG_INF = -1e30
# In-kernel softmax-stat *scratch* is lane-replicated: TPU vector layouts
# want the minor dimension to be a multiple of the 128-lane width.  HBM
# residuals (LSE, D) are per-row f32 — re-broadcast on load in the backward
# kernels (one sublane↔lane relayout per block, vs 128× the HBM traffic).
STATS_LANES = 128


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *rest,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
    with_lse: bool,
):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: skip KV blocks strictly above the diagonal band.
    should_run = True
    if causal:
        should_run = iq * block_q + block_q - 1 >= ik * block_k

    @pl.when(should_run)
    def _body():
        q = q_ref[...].astype(jnp.float32)  # (block_q, d)
        k = k_ref[...].astype(jnp.float32)  # (block_k, d)
        v = v_ref[...].astype(jnp.float32)  # (block_k, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        col = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col < kv_len
        if causal:
            row = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = jnp.logical_and(mask, col <= row)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, :1]  # (block_q, 1)
        l_prev = l_scr[...][:, :1]
        m_cur = s.max(axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (block_q, block_k)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)

        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l_final = l_scr[...][:, :1]
        # Fully-masked rows (query padding) have l == 0; emit zeros.
        denom = jnp.where(l_final == 0.0, 1.0, l_final)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if with_lse:
            m_final = m_scr[...][:, :1]
            lse = jnp.where(
                l_final == 0.0, NEG_INF, m_final + jnp.log(denom)
            )
            lse_ref[...] = lse[:, 0]  # per-row f32 (not lane-replicated)


def flash_attention_kernel_call(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_per_kv: int,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
    interpret: bool = True,
    return_residuals: bool = False,
):
    """Raw pallas_call.  q: (BHq, N, d); k, v: (BHkv, Nk, d); N, Nk padded.

    The KV head for flattened q index ``bh`` is resolved inside the BlockSpec
    index maps (GQA without materialising repeated K/V).

    Returns ``o`` or ``(o, lse)`` with ``lse: (BHq, N)`` f32 (per-row
    logsumexp) when ``return_residuals``.
    """
    bhq, n, d = q.shape
    bhkv, nk_len, _ = k.shape
    # Flattened layouts: bhq = B·Hq, bhkv = B·Hkv with Hq = q_per_kv·Hkv, so
    # bh → kv row is bh // q_per_kv IF heads are flattened per-batch-major,
    # which the ops.py wrapper guarantees by flattening (B, Hkv, r) → B·Hkv·r.
    assert bhq == bhkv * q_per_kv, (bhq, bhkv, q_per_kv)

    grid = (bhq, n // block_q, nk_len // block_k)

    def q_index(bh, i, j):
        return (bh, i, 0)

    def kv_index(bh, i, j):
        return (bh // q_per_kv, j, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        with_lse=return_residuals,
    )
    out_specs = pl.BlockSpec((None, block_q, d), q_index)
    out_shape = jax.ShapeDtypeStruct((bhq, n, d), q.dtype)
    if return_residuals:
        out_specs = [
            out_specs,
            pl.BlockSpec((None, block_q), lambda bh, i, j: (bh, i)),
        ]
        out_shape = [
            out_shape,
            jax.ShapeDtypeStruct((bhq, n), jnp.float32),
        ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), q_index),
            pl.BlockSpec((None, block_k, d), kv_index),
            pl.BlockSpec((None, block_k, d), kv_index),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
