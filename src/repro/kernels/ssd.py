"""Mamba-2 SSD (state-space duality) chunked Pallas TPU kernel.

Used by the attention-free / hybrid assigned architectures (mamba2-130m,
zamba2-7b).  DistrAttention itself is inapplicable there (no QKᵀ stage —
DESIGN.md §4); this kernel is the corresponding perf-critical hot spot.

Chunked SSD: the sequence is split into chunks of ``chunk`` steps.  Within a
chunk the recurrence is expanded into a (masked, decay-weighted) quadratic
form evaluated on the MXU; across chunks a small (S × P) state is carried in
VMEM scratch — grid dim 1 is sequential ("arbitrary").

Recurrence (per head): state_t = exp(a_t)·state_{t-1} + b_t xᵀ_t,
y_t = c_tᵀ·state_t.  Heads share B/C projections in groups (like GQA); the
head→group mapping happens in the BlockSpec index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)  # (chunk, P)
    a = a_ref[...].astype(jnp.float32)  # (chunk, 1) log-decays
    b = b_ref[...].astype(jnp.float32)  # (chunk, S)
    c = c_ref[...].astype(jnp.float32)  # (chunk, S)
    state = state_scr[...]  # (S, P)

    a_cum = jnp.cumsum(a[:, 0])  # (chunk,) inclusive

    # Intra-chunk: L[i, j] = exp(a_cum[i] - a_cum[j]) for i >= j (else 0).
    li = a_cum[:, None] - a_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(col <= row, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * l_mat  # (chunk, chunk)
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # Inter-chunk: carry-in state contribution, decayed to each step.
    y = y + jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update for the next chunk.
    w = jnp.exp(a_cum[-1] - a_cum)  # (chunk,)
    state_scr[...] = jnp.exp(a_cum[-1]) * state + jax.lax.dot_general(
        b * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[...] = y.astype(y_ref.dtype)


def ssd_kernel_call(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    heads_per_group: int,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call.

    x: (BH, N, P);  a: (BH, N, 1);  b, c: (BG, N, S) with BH = BG·heads_per_group
    (flattened batch-major, head/group-minor).  N must divide by ``chunk``.
    """
    bh, n, p = x.shape
    bg, _, s = b.shape
    assert bh == bg * heads_per_group, (bh, bg, heads_per_group)
    assert n % chunk == 0, (n, chunk)

    grid = (bh, n // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, chunk, s), lambda h, i: (h // heads_per_group, i, 0)),
            pl.BlockSpec((None, chunk, s), lambda h, i: (h // heads_per_group, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((s, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_fwd",
    )(x, a, b, c)
