"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` around
0.5; the container pins an older jax.  Every kernel imports the name from
here so the repo runs on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
