"""Pallas TPU kernels: flash attention (exact), DistrAttention, split-K
flash-decoding (serve path), SSD.

Each kernel ships with a jit wrapper in ``ops.py`` and a pure-jnp oracle in
``ref.py``; tests sweep shapes/dtypes and assert allclose in interpret mode.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
