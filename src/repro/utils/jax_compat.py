"""Cross-version jax API shims (the container pins jax 0.4.x).

Newer jax promoted ``shard_map`` to the top level and replaced the
``with mesh:`` context with ``jax.sharding.set_mesh`` /
``get_abstract_mesh``.  All mesh-touching code imports from here so the
same source runs on both API generations.
"""
from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (old).

    ``check_vma`` is the new name of the old ``check_rep`` replication
    check; translated to whichever the running jax understands."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as fn_old

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return fn_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis_name: str, mesh=None):
    """Static size of a mapped axis inside shard_map.  New jax exposes
    ``jax.lax.axis_size``; old jax reads it off the (closed-over) mesh."""
    f = getattr(jax.lax, "axis_size", None)
    if f is not None:
        return f(axis_name)
    return int(mesh.shape[axis_name])


def pvary(x, axis_names):
    """``jax.lax.pvary`` marks a value device-varying for the new VMA
    (varying-manual-axes) checker; old jax has no such notion — identity."""
    f = getattr(jax.lax, "pvary", None)
    return x if f is None else f(x, axis_names)


def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding constraints."""
    sm = getattr(jax.sharding, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    # Old jax: a physical Mesh is itself the context manager.
    return mesh


def get_abstract_mesh():
    """The active mesh, or None when none is set (old jax returns the
    physical mesh — it carries the same ``axis_names`` surface)."""
    f = getattr(jax.sharding, "get_abstract_mesh", None)
    if f is not None:
        return f()
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


@contextlib.contextmanager
def maybe_set_mesh(mesh):
    """set_mesh that tolerates mesh=None (no-op)."""
    if mesh is None:
        yield
        return
    with set_mesh(mesh):
        yield
