from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_cast,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_norm,
)
