"""Small pytree utilities used across the framework (no flax/optax here)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    """Cast every floating leaf to ``dtype`` (leaves int leaves alone)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_norm(tree):
    """Global L2 norm of a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
