"""Ring sequence-parallel ("context parallel") attention over the Pallas
kernel path.

The online-softmax merge that makes FlashAttention-2 associative over KV
*tiles* is equally associative over device-resident KV *shards*: partial
``(O, LSE)`` pairs merge as

    LSE = logaddexp(LSE_a, LSE_b)
    O   = O_a · exp(LSE_a − LSE) + O_b · exp(LSE_b − LSE)

so Q/K/V are sharded on the sequence axis under ``shard_map``, each device
runs the existing fused Pallas kernels (flash or distr) on its local Q tile
against whichever KV shard it currently holds, and KV rotates one hop around
the ICI ring with ``ppermute`` between kernel launches — IO-aware blocking
extended from VMEM tiles to ring hops.  Sequence length then scales with
device count instead of HBM per chip.

Schedule (P = ring size, device ``i`` owns Q/KV shard ``i``):

  hop 0:  every device attends its *own* shard — the causal diagonal, so
          this is the only hop that runs the causal kernel variant;
  hop h:  device ``i`` holds KV shard ``src = (i − h) mod P``.  Causal rings
          skip the hop when ``src > i`` (the shard is entirely in the
          future) — ~half the hops run; both modes skip hops whose KV shard
          holds no live tokens, and devices whose Q shard is all padding.
          Skips are real ``lax.cond`` branches, counted by an executed-hop
          probe (``return_hops=True``) so tests can assert dead hops never
          launch a kernel.

DistrAttention under the ring keeps the paper's grouping *shard-local*: each
device derives its per-Q-block LSH permutations from its own Q shard
(``block_q`` never crosses a shard boundary — shards are rounded to a
``block_q`` multiple), and the fused K̂ is rebuilt in-kernel from the raw
rotating K under those local permutations — K̂ cannot be rotated as state
because every destination fuses under *different* (Q-shard-local) perms.

The backward runs the same ring in reverse over the already-tuned dQ/dKV
kernels (``kernels.backward``): dQ accumulates locally across hops while
(K, V, dK, dV) rotate together; after P rotations the dK/dV accumulators are
back at their owner shard.  The merged (global) LSE and the local
Δ = rowsum(dO ∘ O) are row statistics of the *local* Q shard, so no
statistics ever cross the ring.

Everything here is a shard_map-level building block in the style of
``distributed.collectives``; ``core.api.attend`` dispatches to it when
``AttentionConfig.context_axis`` names an axis of the active mesh.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.distr_attention import DistrConfig
from repro.kernels import backward as bwd
from repro.kernels import ops
from repro.kernels.distr_attention import distr_attention_kernel_call
from repro.kernels.flash_attention import NEG_INF, flash_attention_kernel_call
from repro.tune.block_sizes import BlockSizes
from repro.tune.cache import dtype_str as _dtype_str

# A ring shard is only worth its ppermute overhead once it holds at least a
# full lane tile of tokens; below this the dispatch layer keeps the call on
# one device (serve-side short prompts).
MIN_RING_SHARD = 128


def context_shard_len(n: int, p: int, *, multiple: int = 128) -> int:
    """Per-device sequence shard for a ring of size ``p``: ceil(n/p) rounded
    up to ``multiple`` (the kernels' lane tile / LSH block granularity)."""
    per = -(-int(n) // int(p))
    return max(multiple, -(-per // multiple) * multiple)


def _fit_block(block: int, shard: int) -> int:
    """Clamp a tuned block size to one that tiles the shard exactly."""
    b = min(int(block), shard)
    return b if shard % b == 0 else 128


def _merge_partial(o, lse, o_h, lse_h):
    """Associative online-softmax merge of two (O, LSE) partials (f32)."""
    lse_new = jnp.logaddexp(lse, lse_h)
    w = jnp.exp(lse - lse_new)[..., None]
    w_h = jnp.exp(lse_h - lse_new)[..., None]
    return o * w + o_h.astype(jnp.float32) * w_h, lse_new


def _rotate(tree, axis: str, p: int):
    """One KV hop: every device sends its shard to the next ring position."""
    perm = [(j, (j + 1) % p) for j in range(p)]
    return jax.tree_util.tree_map(
        lambda x: jax.lax.ppermute(x, axis, perm), tree
    )


@dataclass(frozen=True)
class _RingMeta:
    """Static ring configuration riding through ``custom_vjp`` nondiff args."""

    axis: str
    size: int
    causal: bool
    scale: float
    interpret: bool
    n_live: int  # global live sequence length (pre-padding)
    shard: int  # per-device padded shard length
    blocks: BlockSizes  # flash tiles (fwd + bwd; distr reads dcfg instead)
    dcfg: DistrConfig | None = None  # distr mode when set (resolved blocks)
    bk_bwd_distr: tuple[int, int] | None = None  # distr bwd (bk_dq, bk_dkv)

    @property
    def tail_idx(self) -> int:
        """Index of the partially-live shard (−1 when none: the live length
        lands exactly on a shard boundary)."""
        return self.n_live // self.shard if self.n_live % self.shard else -1

    @property
    def tail_len(self) -> int:
        return self.n_live % self.shard


# -- fault injection (serve.faults catalog point "dead_ring_shard") --------
#
# Shards listed here model a dead host mid-ring: its KV shard never arrives
# at the other devices (the ppermute from that neighbor yields nothing), so
# every hop h > 0 whose source is a dead shard is skipped and the ring
# serves a degraded-but-finite result instead of hanging or NaN-ing.  Hop 0
# (a device's own local KV) always runs — it is resident, not rotated — so
# no Q row ever loses its softmax diagonal.  Read at trace time: apply the
# context manager around an untraced call (the chaos suite does), not
# around an already-jitted function.
_DEAD_SHARDS: frozenset[int] = frozenset()


@contextlib.contextmanager
def dead_shard_fault(shards):
    """Treat KV shards in ``shards`` as dead for ring sweeps traced inside
    the context (graceful-degradation fault injection; see serve.faults)."""
    global _DEAD_SHARDS
    prev = _DEAD_SHARDS
    _DEAD_SHARDS = frozenset(int(s) for s in shards)
    try:
        yield
    finally:
        _DEAD_SHARDS = prev


def _hop_schedule(meta: _RingMeta, idx, h: int):
    """(run, kernel_causal) for hop ``h`` on device ``idx``.

    ``run`` is the traced skip predicate: the hop launches no kernel when the
    held KV shard has no live tokens, when the device's own Q shard is all
    padding, or — causal rings — when the shard is entirely in the future
    (``src > idx``; the diagonal ``src == idx`` is always hop 0 under this
    rotation direction, so it alone runs the causal kernel variant).
    """
    p = meta.size
    src = (idx - h) % p if h else idx
    run = (src * meta.shard < meta.n_live) & (idx * meta.shard < meta.n_live)
    if meta.causal and h > 0:
        run = run & (src < idx)
    if _DEAD_SHARDS and h > 0:
        # Injected dead shards (dead_shard_fault): the rotated KV from a
        # dead source never arrives — skip the hop, keep serving.
        dead = jnp.asarray(sorted(_DEAD_SHARDS), jnp.int32)
        run = run & jnp.all(src != dead)
    return src, run, (meta.causal and h == 0)


def _hop_kv_variants(meta: _RingMeta, src, call):
    """Invoke ``call(kv_len)`` with the static live length of the held KV
    shard: full shards stream ``shard`` live columns, the single partial
    (tail) shard masks past ``tail_len``.  ``kv_len`` is static inside the
    kernels, so the choice is a two-branch ``lax.cond`` on the traced shard
    origin rather than a dynamic argument."""
    if meta.tail_idx < 0:
        return call(meta.shard)
    return jax.lax.cond(
        src == meta.tail_idx,
        lambda: call(meta.tail_len),
        lambda: call(meta.shard),
    )


def _live_row_mask(meta: _RingMeta, idx, n_rows: int):
    """(n_rows,) bool — rows of the local Q shard that are real tokens."""
    live = jnp.clip(meta.n_live - idx * meta.shard, 0, meta.shard)
    return jnp.arange(n_rows) < live


def _ring_hops(meta: _RingMeta, kv, carry, hop_body, *, post_hop=None):
    """The ring-loop scaffold shared by all four sweeps (flash/distr ×
    fwd/bwd): per hop, derive the schedule, run ``hop_body(src,
    kernel_causal, k_c, v_c, carry)`` under the skip predicate (a real
    ``lax.cond`` — skipped hops launch no kernel), apply ``post_hop`` to
    the carry *unconditionally* (the backwards rotate their dK/dV
    accumulators every hop, skipped or not, so they land back on the owner
    after P rotations), then rotate KV — except after the last hop.

    Keeping the skip/rotation ordering in one place is the point: it is
    the subtlest invariant of the ring and must not drift between the four
    sweeps."""
    idx = jax.lax.axis_index(meta.axis)
    for h in range(meta.size):
        src, run, kernel_causal = _hop_schedule(meta, idx, h)
        k_c, v_c = kv

        def compute(c, k_c=k_c, v_c=v_c, src=src, kc=kernel_causal):
            return hop_body(src, kc, k_c, v_c, c)

        carry = jax.lax.cond(run, compute, lambda c: c, carry)
        if post_hop is not None:
            carry = post_hop(carry)
        if h < meta.size - 1:
            kv = _rotate(kv, meta.axis, meta.size)
    return carry


# ---------------------------------------------------------------------------
# Exact flash ring
# ---------------------------------------------------------------------------


def _ring_flash_fwd_impl(meta: _RingMeta, q, k, v):
    b, hq, n_sh, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv
    bq, bk = meta.blocks.fwd()

    qf = q.reshape(b * hq, n_sh, d)
    kv = (k.reshape(b * hkv, n_sh, d), v.reshape(b * hkv, n_sh, d))

    o0 = jnp.zeros((b * hq, n_sh, d), jnp.float32)
    lse0 = jnp.full((b * hq, n_sh), NEG_INF, jnp.float32)

    def hop_body(src, kernel_causal, k_c, v_c, c):
        o, lse, hops = c

        def call(kv_len):
            return flash_attention_kernel_call(
                qf, k_c, v_c, q_per_kv=q_per_kv, scale=meta.scale,
                causal=kernel_causal, block_q=bq, block_k=bk,
                kv_len=kv_len, interpret=meta.interpret,
                return_residuals=True,
            )

        o_h, lse_h = _hop_kv_variants(meta, src, call)
        o, lse = _merge_partial(o, lse, o_h, lse_h)
        return o, lse, hops + 1

    o, lse, hops = _ring_hops(
        meta, kv, (o0, lse0, jnp.zeros((), jnp.int32)), hop_body
    )
    out = o.reshape(b, hq, n_sh, d).astype(q.dtype)
    return out, lse, jax.lax.psum(hops, meta.axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring_flash_local(meta: _RingMeta, q, k, v):
    out, _, hops = _ring_flash_fwd_impl(meta, q, k, v)
    return out, hops


def _ring_flash_vjp_fwd(meta, q, k, v):
    out, lse, hops = _ring_flash_fwd_impl(meta, q, k, v)
    return (out, hops), (q, k, v, out, lse)


def _ring_flash_vjp_bwd(meta, res, cts):
    q, k, v, o, lse = res
    do, _ = cts  # the hop count is a probe: no cotangent flows through it
    b, hq, n_sh, d = q.shape
    hkv = k.shape[1]
    q_per_kv = hq // hkv
    idx = jax.lax.axis_index(meta.axis)
    # Backward tiles must tile the fixed local shard; a tuned tile that
    # doesn't fit falls back to the 128 lane tile (which tiles every shard
    # by construction).
    bq_dq, bk_dq = (_fit_block(x, n_sh) for x in meta.blocks.dq())
    bq_dkv, bk_dkv = (_fit_block(x, n_sh) for x in meta.blocks.dkv())

    qf = q.reshape(b * hq, n_sh, d)
    dof = do.astype(q.dtype).reshape(b * hq, n_sh, d)
    of = o.reshape(b * hq, n_sh, d)
    delta = bwd.delta_kernel_call(
        of, dof, block_q=bq_dq, interpret=meta.interpret
    )
    # Padded Q rows never carry cotangent (the public wrapper zero-pads dO),
    # but their LSE is garbage from the unmasked forward rows; pin it to
    # +big so P ≡ 0 and they contribute nothing to dK/dV.
    row_live = _live_row_mask(meta, idx, n_sh)[None, :]
    lse_b = jnp.where(row_live, lse, ops.LSE_PAD)

    kv = (k.reshape(b * hkv, n_sh, d), v.reshape(b * hkv, n_sh, d))
    state = (
        jnp.zeros((b * hq, n_sh, d), jnp.float32),
        jnp.zeros((b, hkv, n_sh, d), jnp.float32),
        jnp.zeros((b, hkv, n_sh, d), jnp.float32),
    )

    def hop_body(src, kernel_causal, k_c, v_c, c):
        dq, dk, dv = c

        def call(kv_len):
            dq_h = bwd.flash_dq_kernel_call(
                qf, k_c, v_c, dof, lse_b, delta,
                q_per_kv=q_per_kv, scale=meta.scale,
                causal=kernel_causal, block_q=bq_dq, block_k=bk_dq,
                kv_len=kv_len, interpret=meta.interpret,
            )
            dk_h, dv_h = bwd.flash_dkv_kernel_call(
                qf, k_c, v_c, dof, lse_b, delta,
                q_per_kv=q_per_kv, scale=meta.scale,
                causal=kernel_causal, block_q=bq_dkv, block_k=bk_dkv,
                kv_len=kv_len, interpret=meta.interpret,
            )
            return dq_h, dk_h, dv_h

        dq_h, dk_h, dv_h = _hop_kv_variants(meta, src, call)
        # GQA group-sum per hop: the rotating accumulator carries the
        # per-KV-head layout (P× less ring traffic than per-Q-head).
        dk = dk + ops._gqa_sum(dk_h, b, hkv, q_per_kv, n_sh)
        dv = dv + ops._gqa_sum(dv_h, b, hkv, q_per_kv, n_sh)
        return dq + dq_h, dk, dv

    def rotate_dkv(c):
        # dK/dV rotate *with* their KV shard every hop (P rotations total),
        # landing back on the owner; dQ stays local.
        dq, dk, dv = c
        dk, dv = _rotate((dk, dv), meta.axis, meta.size)
        return dq, dk, dv

    dq, dk, dv = _ring_hops(meta, kv, state, hop_body, post_hop=rotate_dkv)
    dq = dq.reshape(b, hq, n_sh, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_ring_flash_local.defvjp(_ring_flash_vjp_fwd, _ring_flash_vjp_bwd)


# ---------------------------------------------------------------------------
# DistrAttention ring (shard-local LSH grouping)
# ---------------------------------------------------------------------------


def _distr_stage1(meta: _RingMeta, q, hkv: int):
    """The LSH stage (per-Q-block permutations + sampled Q̂), run as plain
    XLA *outside* the shard_map — the shared ``ops.distr_stage1``
    implementation, so the grouping decision cannot diverge from the
    single-device op.  Blocks never cross a shard boundary (shards are
    ``block_q``-aligned), so grouping is shard-local by construction and
    computing it on the global (GSPMD-sharded) array is bit-identical to a
    per-shard computation.  ``hkv`` enables the shared-KV-perm variant
    (one permutation per KV group from the group's mean query block,
    broadcast back to Hq) — still shard-local for the same reason."""
    return ops.distr_stage1(meta.dcfg, q, meta.scale, hkv=hkv)


def _ring_distr_local_fwd(meta: _RingMeta, q_hat, perms, k, v):
    """Shard-local ring forward: q_hat (b, hq, n_sh, dG), perms
    (b, hq, nq_local, d), k/v (b, hkv, n_sh, d)."""
    cfg = meta.dcfg
    b, hq, n_sh, dg = q_hat.shape
    hkv, d = k.shape[1], k.shape[-1]
    q_per_kv = hq // hkv
    g = cfg.group_size

    q_hat = q_hat.reshape(b * hq, n_sh, dg)
    nq_blocks = n_sh // cfg.block_q
    perm_f = perms.reshape(b * hq, nq_blocks, d)
    kv = (k.reshape(b * hkv, n_sh, d), v.reshape(b * hkv, n_sh, d))

    o0 = jnp.zeros((b * hq, n_sh, d), jnp.float32)
    lse0 = jnp.full((b * hq, n_sh), NEG_INF, jnp.float32)

    def hop_body(src, kernel_causal, k_c, v_c, c):
        o, lse, hops = c

        def call(kv_len):
            # K̂ is re-fused inside the kernel from the rotating raw K
            # under the *local* permutations — the shard-local grouping
            # invariant (it never rides the ring as state).
            return distr_attention_kernel_call(
                q_hat, k_c, v_c, perm_f, q_per_kv=q_per_kv,
                causal=kernel_causal, group_size=g,
                block_q=cfg.block_q, block_k=cfg.block_k, kv_len=kv_len,
                interpret=meta.interpret, return_residuals=True,
            )

        o_h, lse_h = _hop_kv_variants(meta, src, call)
        o, lse = _merge_partial(o, lse, o_h, lse_h)
        return o, lse, hops + 1

    o, lse, hops = _ring_hops(
        meta, kv, (o0, lse0, jnp.zeros((), jnp.int32)), hop_body
    )
    out = o.reshape(b, hq, n_sh, d).astype(k.dtype)
    return out, lse.reshape(b, hq, n_sh), jax.lax.psum(hops, meta.axis)


def _ring_distr_local_bwd(meta: _RingMeta, q_hat, perms, inv_perms, k, v, o,
                          lse, do):
    """Shard-local ring backward.  All args shard-local; lse is the merged
    (global over KV hops) logsumexp of the local Q rows.  Returns
    (dq_hat, dk, dv) — dq_hat still in sampled space; the global wrapper
    transposes the sampling gather."""
    cfg = meta.dcfg
    b, hq, n_sh, dg = q_hat.shape
    hkv, d = k.shape[1], k.shape[-1]
    q_per_kv = hq // hkv
    g = cfg.group_size
    idx = jax.lax.axis_index(meta.axis)
    nq_blocks = n_sh // cfg.block_q

    q_hat = q_hat.reshape(b * hq, n_sh, dg)
    bk_dq, bk_dkv = meta.bk_bwd_distr or (cfg.block_k, cfg.block_k)
    bk_dq, bk_dkv = _fit_block(bk_dq, n_sh), _fit_block(bk_dkv, n_sh)

    dof = do.astype(k.dtype).reshape(b * hq, n_sh, d)
    of = o.reshape(b * hq, n_sh, d)
    perm_f = perms.reshape(b * hq, nq_blocks, d)
    inv_perm_f = inv_perms.reshape(b * hq, nq_blocks, d)
    delta = bwd.delta_kernel_call(
        of, dof, block_q=cfg.block_q, interpret=meta.interpret
    )
    row_live = _live_row_mask(meta, idx, n_sh)[None, :]
    lse_b = jnp.where(row_live, lse.reshape(b * hq, n_sh), ops.LSE_PAD)

    kv = (k.reshape(b * hkv, n_sh, d), v.reshape(b * hkv, n_sh, d))
    state = (
        jnp.zeros((b * hq, n_sh, dg), jnp.float32),
        jnp.zeros((b, hkv, n_sh, d), jnp.float32),
        jnp.zeros((b, hkv, n_sh, d), jnp.float32),
    )

    def hop_body(src, kernel_causal, k_c, v_c, c):
        dq_hat_acc, dk, dv = c

        def call(kv_len):
            dq_h = bwd.distr_dq_kernel_call(
                q_hat, k_c, v_c, perm_f, dof, lse_b, delta,
                q_per_kv=q_per_kv, causal=kernel_causal, group_size=g,
                block_q=cfg.block_q, block_k=bk_dq, kv_len=kv_len,
                interpret=meta.interpret,
            )
            dk_h, dv_h = bwd.distr_dkv_kernel_call(
                q_hat, k_c, v_c, perm_f, inv_perm_f, dof, lse_b, delta,
                q_per_kv=q_per_kv, causal=kernel_causal, group_size=g,
                block_q=cfg.block_q, block_k=bk_dkv, kv_len=kv_len,
                interpret=meta.interpret,
            )
            return dq_h, dk_h, dv_h

        dq_h, dk_h, dv_h = _hop_kv_variants(meta, src, call)
        dk = dk + ops._gqa_sum(dk_h, b, hkv, q_per_kv, n_sh)
        dv = dv + ops._gqa_sum(dv_h, b, hkv, q_per_kv, n_sh)
        return dq_hat_acc + dq_h, dk, dv

    def rotate_dkv(c):
        dq_hat_acc, dk, dv = c
        dk, dv = _rotate((dk, dv), meta.axis, meta.size)
        return dq_hat_acc, dk, dv

    dq_hat, dk, dv = _ring_hops(meta, kv, state, hop_body,
                                post_hop=rotate_dkv)
    return (
        dq_hat.reshape(b, hq, n_sh, dg),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


# Global-level custom_vjp: stage 1 (and its transpose) run as plain XLA on
# the GSPMD-sharded global arrays; only the hop loops live inside shard_map.


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ring_distr(meta: _RingMeta, mesh, axis, q, k, v):
    (out, _, hops), _, _ = _ring_distr_fwd_global(meta, mesh, axis, q, k, v)
    return out, hops


def _ring_distr_fwd_global(meta, mesh, axis, q, k, v):
    q_hat, perms = _distr_stage1(meta, q, k.shape[1])
    qkv_spec, out_spec = _ring_specs(
        mesh, axis, q.shape[0], q.shape[1], k.shape[1]
    )
    from repro.utils.jax_compat import shard_map

    res = shard_map(
        functools.partial(_ring_distr_local_fwd, meta),
        mesh=mesh,
        in_specs=(qkv_spec, qkv_spec, qkv_spec, qkv_spec),
        out_specs=(out_spec, P(*out_spec[:3]), P()),
        check_vma=False,
    )(q_hat, perms, k, v)
    return res, q_hat, perms


def _ring_distr_vjp_fwd(meta, mesh, axis, q, k, v):
    (out, lse, hops), q_hat, perms = _ring_distr_fwd_global(
        meta, mesh, axis, q, k, v
    )
    return (out, hops), (k, v, out, lse, q_hat, perms)


def _ring_distr_vjp_bwd(meta, mesh, axis, res, cts):
    cfg = meta.dcfg
    k, v, o, lse, q_hat, perms = res
    do, _ = cts
    b, hq = o.shape[0], o.shape[1]
    g = cfg.group_size
    inv_perms = jnp.argsort(perms, axis=-1).astype(perms.dtype)

    qkv_spec, out_spec = _ring_specs(mesh, axis, b, hq, k.shape[1])
    from repro.utils.jax_compat import shard_map

    dq_hat, dk, dv = shard_map(
        functools.partial(_ring_distr_local_bwd, meta),
        mesh=mesh,
        in_specs=(qkv_spec,) * 8,
        out_specs=(qkv_spec, qkv_spec, qkv_spec),
        check_vma=False,
    )(q_hat, perms, inv_perms, k, v, o, lse[..., None], do)

    dq = ops.distr_dq_from_dq_hat(
        cfg.estimator, dq_hat, perms,
        block_q=cfg.block_q, group_size=g, scale=meta.scale,
    ).astype(k.dtype)
    return dq, dk, dv


_ring_distr.defvjp(_ring_distr_vjp_fwd, _ring_distr_vjp_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _ring_specs(mesh, axis: str, b: int, hq: int, hkv: int):
    """(qkv_spec, out_spec): seq over ``axis``; batch over whatever DP axes
    divide it; heads over "model" only when *both* head counts divide (a
    lopsided GQA split would break the kernels' q_per_kv mapping)."""
    batch = []
    prod = 1
    for a in mesh.axis_names:
        sz = int(mesh.shape[a])
        if a in ("model", axis) or sz == 1:
            continue
        if b % (prod * sz) == 0:
            batch.append(a)
            prod *= sz
    msize = int(mesh.shape.get("model", 1))
    head = "model" if msize > 1 and hq % msize == 0 and hkv % msize == 0 else None
    spec = P(tuple(batch) or None, head, axis, None)
    return spec, spec


def _pad_global(x, n_pad):
    pad = n_pad - x.shape[2]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x


def _run_ring(local_fn, meta, q, k, v, mesh, axis):
    n = q.shape[2]
    n_pad = meta.size * meta.shard
    q, k, v = (_pad_global(x, n_pad) for x in (q, k, v))
    qkv_spec, out_spec = _ring_specs(
        mesh, axis, q.shape[0], q.shape[1], k.shape[1]
    )
    from repro.utils.jax_compat import shard_map

    out, hops = shard_map(
        functools.partial(local_fn, meta),
        mesh=mesh,
        in_specs=(qkv_spec, qkv_spec, qkv_spec),
        # The executed-hop probe is psum'd over the ring inside the local
        # body — replicated by construction, which VMA can't infer.
        out_specs=(out_spec, P()),
        check_vma=False,
    )(q, k, v)
    return out[:, :, :n, :], hops


def ring_flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mesh,
    *,
    axis: str = "context",
    causal: bool = False,
    scale: float | None = None,
    blocks: BlockSizes | None = None,
    interpret: bool | None = None,
    return_hops: bool = False,
):
    """Exact FA-2 ring attention.  q: (B, Hq, N, d); k, v: (B, Hkv, N, d)
    with N the *global* sequence length — sharded over ``mesh.shape[axis]``
    devices inside.  Differentiable (ring backward over the fused dQ/dKV
    kernels).  ``return_hops=True`` additionally returns the total number of
    ring hops that actually launched kernels (the causal/dead-shard skip
    probe)."""
    if q.shape[2] != k.shape[2]:
        raise ValueError(
            f"ring attention is self-attention only: N_q={q.shape[2]} != "
            f"N_k={k.shape[2]}"
        )
    scale = float(scale) if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    if interpret is None:
        interpret = ops.default_interpret()
    p = int(mesh.shape[axis]) if axis in mesh.axis_names else 1
    if p == 1:
        out = ops.flash_attention(
            q, k, v, causal=causal, scale=scale, blocks=blocks,
            interpret=interpret,
        )
        return (out, jnp.asarray(1, jnp.int32)) if return_hops else out

    n = q.shape[2]
    if blocks is None:
        # Per-shard sequence bucket: the tuner key is the length one device
        # actually streams, not the global N (tune/ satellite).
        from repro.tune.autotune import resolve_block_sizes, tune_mode

        shard0 = context_shard_len(n, p)
        blocks = resolve_block_sizes(
            "flash", d=q.shape[-1], n=shard0, dtype=_dtype_str(q),
            causal=causal, interpret=interpret,
            bwd=(tune_mode() == "measure"),
        )
    from math import lcm

    shard = context_shard_len(n, p, multiple=lcm(128, blocks.block_q))
    blocks = blocks.with_(
        block_q=_fit_block(blocks.block_q, shard),
        block_k=_fit_block(blocks.block_k, shard),
    )
    meta = _RingMeta(
        axis=axis, size=p, causal=causal, scale=scale, interpret=interpret,
        n_live=n, shard=shard, blocks=blocks,
    )
    out, hops = _run_ring(_ring_flash_local, meta, q, k, v, mesh, axis)
    return (out, hops) if return_hops else out


def ring_distr_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: DistrConfig,
    mesh,
    *,
    axis: str = "context",
    causal: bool = False,
    scale: float | None = None,
    interpret: bool | None = None,
    return_hops: bool = False,
):
    """DistrAttention ring with shard-local LSH grouping: permutations and
    Q̂ sampling run on the local Q shard (``block_q`` never crosses a shard
    boundary); raw K/V rotate and K̂ is re-fused in-kernel per hop under the
    local permutations."""
    if q.shape[2] != k.shape[2]:
        raise ValueError(
            f"ring attention is self-attention only: N_q={q.shape[2]} != "
            f"N_k={k.shape[2]}"
        )
    scale = float(scale) if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    if interpret is None:
        interpret = ops.default_interpret()
    p = int(mesh.shape[axis]) if axis in mesh.axis_names else 1
    if p == 1:
        out = ops.distr_attention(
            q, k, v, cfg, causal=causal, scale=scale, interpret=interpret
        )
        return (out, jnp.asarray(1, jnp.int32)) if return_hops else out

    n = q.shape[2]
    shard0 = context_shard_len(n, p)
    cfg = cfg.resolved(
        q.shape[-1], shard0, dtype=_dtype_str(q), causal=causal, xla=False,
        interpret=interpret,
    )
    # The grouping grain is sacrosanct: shards are rounded to a multiple of
    # lcm(block_q, 128), so the configured block_q always tiles the shard
    # exactly — the ring never silently regroups at a different granularity
    # than the single-device path.
    from math import lcm

    shard = context_shard_len(n, p, multiple=lcm(128, cfg.block_q))
    from dataclasses import replace as dc_replace

    cfg = dc_replace(cfg, block_k=_fit_block(cfg.block_k, shard))
    bk_bwd = _resolve_distr_bwd_pair(cfg, q, shard, causal, interpret)
    meta = _RingMeta(
        axis=axis, size=p, causal=causal, scale=scale, interpret=interpret,
        n_live=n, shard=shard, blocks=BlockSizes.from_pair(cfg.block_q, cfg.block_k),
        dcfg=cfg, bk_bwd_distr=bk_bwd,
    )
    n_pad = p * shard
    qp, kp, vp = (_pad_global(x, n_pad) for x in (q, k, v))
    out, hops = _ring_distr(meta, mesh, axis, qp, kp, vp)
    out = out[:, :, :n, :]
    return (out, hops) if return_hops else out


def _resolve_distr_bwd_pair(cfg, q, shard, causal, interpret):
    """Backward ``block_k`` for the distr ring via the shared resolver in
    ``kernels.ops`` (eager: the ring's static meta is fixed at
    forward-dispatch time, so the lazy backward-trace resolution the
    single-device op uses isn't available here; ``n`` is the per-device
    shard — the length one ring device actually streams)."""
    return ops.resolve_distr_bwd_blocks(
        cfg, d=q.shape[-1], n=shard, dtype=_dtype_str(q), causal=causal,
        interpret=interpret,
    )
