"""Explicit collective schedules: compute/communication overlap.

XLA's scheduler already overlaps independent collectives with compute; the
routines here make the overlap *structural* for the cases that matter at
1000-node scale:

* ``ring_allgather_matmul`` — tensor-parallel matmul where the right operand
  is gathered ring-hop by ring-hop (collective_permute) while each shard's
  partial product is computed, instead of a bulk all-gather followed by one
  big matmul.  Each of the P-1 permute hops is overlapped with a chunk
  matmul — the classic "all-gather matmul" fusion on TPU ICI rings.
* ``psum_scatter_matmul`` — the reverse (reduce-scatter) direction for
  row-parallel layers.

Both are shard_map-level building blocks, validated against the unfused
reference in tests (they are numerically identical).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import shard_map
from jax.sharding import PartitionSpec as P


def ring_allgather_matmul(x: jnp.ndarray, w: jnp.ndarray, mesh, axis: str = "model"):
    """Compute ``x @ W`` where W's *input* dim is sharded over ``axis``.

    x: (..., K) replicated over ``axis``;  w: (K, N) with K sharded — each
    shard holds (K/P, N).  Ring schedule: at step s each shard multiplies the
    x-chunk it currently holds with its local W block while permuting the
    next chunk around the ring.
    """
    p = mesh.shape[axis]

    def local(x_l, w_l):
        # x_l: (..., K) full (replicated); w_l: (K/P, N) local block.
        idx = jax.lax.axis_index(axis)
        k_loc = w_l.shape[0]

        def chunk(i):
            # chunk of x this shard needs at ring step i
            start = ((idx + i) % p) * k_loc
            return jax.lax.dynamic_slice_in_dim(x_l, start, k_loc, axis=-1)

        # Step 0 computes with the local chunk; remaining chunks arrive
        # "via the ring" (here: sliced locally since x is replicated, but the
        # schedule is the TPU ring schedule — on hardware w would be the
        # resident tensor and x-chunks the permuted ones).
        acc = chunk(0) @ w_l
        for i in range(1, p):
            acc = acc + chunk(i) @ jax.lax.ppermute(
                w_l, axis, [(j, (j - i) % p) for j in range(p)]
            )
        return acc

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axis, None)),
        out_specs=P(),
        # every shard reconstructs the full product via the ring — value-
        # replicated by construction, which VMA can't infer statically.
        check_vma=False,
    )(x, w)


def psum_scatter_matmul(x: jnp.ndarray, w: jnp.ndarray, mesh, axis: str = "model"):
    """Row-parallel ``x @ W`` with a reduce-scatter epilogue.

    x: (..., K) sharded over axis on K (passed replicated here; each shard
    slices its K block); w: (K, N) K-sharded.  Output: (..., N) sharded on N,
    reduce-scattered instead of all-reduced — half the bytes on the wire.
    """
    p = mesh.shape[axis]

    def local(x_l, w_l):
        idx = jax.lax.axis_index(axis)
        k_loc = w_l.shape[0]
        x_chunk = jax.lax.dynamic_slice_in_dim(x_l, idx * k_loc, k_loc, axis=-1)
        partial = x_chunk @ w_l  # (..., N) partial sum
        return jax.lax.psum_scatter(partial, axis, scatter_dimension=partial.ndim - 1,
                                    tiled=True)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axis, None)),
        out_specs=P(*([None] * (x.ndim - 1)), axis),
    )(x, w)


def allreduce_with_compression(grads, mesh, *, compress_fn=None, decompress_fn=None):
    """DP gradient all-reduce hook point (see train.compression for int8
    error-feedback); identity compression = plain psum-mean."""
    axes = tuple(a for a in mesh.axis_names if a != "model")

    def local(g):
        if compress_fn is not None:
            g = compress_fn(g)
        for a in axes:
            g = jax.lax.pmean(g, a)
        if decompress_fn is not None:
            g = decompress_fn(g)
        return g

    spec = P()
    return shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)(grads)
