"""Logical-axis → mesh sharding rules (DP/FSDP × TP × EP).

Parameters carry logical axis tuples (from ``models.*_axes``); this module
maps them onto the production mesh:

  vocab / mlp / heads / kv_heads / experts → "model"   (TP / EP)
  one large unsharded dim per tensor       → "data"    (FSDP, if cfg.fsdp)

FSDP picks the largest None-axis (excluding the layer-stack dim) whose size
divides the data-axis size and is ≥ MIN_FSDP_DIM; optimizer state shards
exactly like its parameter.  Activations: batch → every non-"model" axis
(so the "pod" axis is pure DP in the multi-pod mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    None: None,
}

MIN_FSDP_DIM = 1024

# The reserved mesh-axis name for ring sequence-parallel attention
# (distributed.ring_attention).  The ring functions themselves accept any
# axis name, but the built-in sharding rules — dp_axes here and the
# "data"/"seq" expansion in models.layers.constrain — special-case this
# literal: model-integrated training/serving must name the mesh axis
# CONTEXT_AXIS (and set AttentionConfig.context_axis to it) or the batch
# dim would shard over the ring and every layer would re-gather it.
CONTEXT_AXIS = "context"

# Parameter subtrees that are layer-stacked (leading dim = scan axis; never
# FSDP-shard it — scan would reshard every step).
STACKED_KEYS = ("blocks", "dense_blocks", "enc_blocks", "groups", "tail")


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes the batch dimension shards over: everything except "model" (TP)
    and CONTEXT_AXIS (sequence-sharded ring attention — the batch must stay
    whole across it or each ring device would hold a different batch)."""
    return tuple(
        a for a in mesh.axis_names if a not in ("model", CONTEXT_AXIS)
    )


def data_axis_size(mesh) -> int:
    return int(mesh.shape.get("data", 1))


def _spec_for(axes: tuple, shape: tuple, mesh, *, fsdp: bool, stacked: bool) -> P:
    assignment = [LOGICAL_RULES.get(a, None) for a in axes]
    # Explicit in/out shardings must divide evenly; drop assignments that
    # don't (e.g. a 3352-wide mamba in_proj on a 16-way model axis).
    for i, a in enumerate(assignment):
        if a is not None and shape[i] % int(mesh.shape.get(a, 1)):
            assignment[i] = None
    if fsdp and "data" in mesh.axis_names:
        dsz = data_axis_size(mesh)
        candidates = [
            i
            for i, a in enumerate(axes)
            if a is None
            and not (stacked and i == 0)
            and shape[i] >= MIN_FSDP_DIM
            and shape[i] % dsz == 0
        ]
        if candidates:
            best = max(candidates, key=lambda i: shape[i])
            assignment[best] = "data"
    return P(*assignment)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def param_pspecs(axes_tree, shapes_tree, mesh, *, fsdp: bool = True):
    """Tree of PartitionSpec matching the params tree.

    axes_tree: from models.lm.param_axes(cfg);
    shapes_tree: from jax.eval_shape(init_params, ...).
    """

    def walk(axes, shapes, stacked):
        if _is_axes_leaf(axes):
            return _spec_for(axes, shapes.shape, mesh, fsdp=fsdp, stacked=stacked)
        if isinstance(axes, dict):
            return {
                k: walk(
                    v, shapes[k], stacked or (k in STACKED_KEYS)
                )
                for k, v in axes.items()
            }
        if isinstance(axes, (list, tuple)):
            return type(axes)(
                walk(a, s, stacked) for a, s in zip(axes, shapes)
            )
        raise TypeError(f"unexpected axes node {type(axes)}")

    return walk(axes_tree, shapes_tree, False)


def param_shardings(axes_tree, shapes_tree, mesh, *, fsdp: bool = True):
    specs = param_pspecs(axes_tree, shapes_tree, mesh, fsdp=fsdp)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspec(mesh) -> P:
    """Token batches: batch dim over every non-model axis."""
    return P(dp_axes(mesh))


def dp_axes_for(mesh, dim: int) -> tuple[str, ...] | None:
    """DP axes whose product divides ``dim`` (prefix of the axis list)."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        size = int(mesh.shape[a])
        if dim % (prod * size) == 0:
            axes.append(a)
            prod *= size
    return tuple(axes) or None


def batch_shardings(batch_specs: dict, mesh) -> dict:
    """Shardings for an input_specs() dict: dim0 = batch, rest replicated."""
    out = {}
    for k, v in batch_specs.items():
        spec = [None] * len(v.shape)
        spec[0] = dp_axes_for(mesh, v.shape[0])
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_params(params, shardings):
    """Device_put a params tree onto its shardings (host → mesh)."""
    return jax.tree_util.tree_map(jax.device_put, params, shardings)


def kv_cache_pspec(mesh, *, seq_axis_sharded: bool) -> P:
    """(B, Hkv, S, dh) cache: batch over DP axes; seq over model when the
    head count doesn't divide the TP size (flash-decoding style)."""
    if seq_axis_sharded:
        return P(dp_axes(mesh), None, "model", None)
    return P(dp_axes(mesh), "model", None, None)
