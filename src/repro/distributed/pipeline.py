"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

The multi-pod dry-run's default config runs DP over the pod axis; this module
provides the alternative PP mapping: layer stages live on successive pods and
activations hop pod→pod with ``collective_permute`` while microbatches fill
the pipeline (M + S - 1 ticks, GPipe schedule).

``pipeline_apply`` is deliberately generic — ``stage_fn(stage_params, x)``
is any per-stage transform (e.g. a slice of transformer layers) — and is
validated in tests against running the stages sequentially on one device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.utils.jax_compat import pvary, shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    stage_fn,
    stage_params,
    x: jnp.ndarray,
    mesh,
    *,
    axis: str = "pod",
    num_microbatches: int | None = None,
):
    """Run ``x`` through S pipeline stages laid out along ``axis``.

    stage_params: pytree with a leading stage dim (S, ...), sharded over
      ``axis`` on that dim (each pod holds one stage's params).
    x: (M, mb, ...) — M microbatches (M = num_microbatches or x.shape[0]).
    Returns (M, mb, ...) with every stage applied in order.
    """
    s_total = int(mesh.shape[axis])
    m = num_microbatches or x.shape[0]
    assert x.shape[0] == m

    def local(params_local, x_local):
        # params_local: (1, ...) — this pod's stage; x_local: (M, mb, ...)
        stage = jax.lax.axis_index(axis)
        params_stage = jax.tree_util.tree_map(lambda t: t[0], params_local)
        mb_shape = x_local.shape[1:]

        def tick(carry, t):
            outs, cur = carry
            # Stage 0 injects microbatch t while t < M; drain ticks (t >= M)
            # inject zeros — re-injecting the clamped index M-1 would make
            # every stage recompute the final microbatch S-1 extra times
            # (pure waste: those late copies can never reach the emit tick).
            inj = jax.lax.dynamic_index_in_dim(
                x_local, jnp.minimum(t, m - 1), axis=0, keepdims=False
            )
            inj = jnp.where(t < m, inj, jnp.zeros_like(inj))
            cur = jnp.where(stage == 0, inj, cur)
            y = stage_fn(params_stage, cur)
            # Last stage emits microbatch t - (S-1).
            emit_idx = t - (s_total - 1)
            do_emit = (stage == s_total - 1) & (emit_idx >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, y.astype(outs.dtype), jnp.maximum(emit_idx, 0), axis=0
            )
            outs = jnp.where(do_emit, upd, outs)
            # Rotate activations one hop around the ring.
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s_total) for i in range(s_total)]
            )
            return (outs, nxt), None

        outs0 = pvary(jnp.zeros((m,) + mb_shape, x_local.dtype), (axis,))
        cur0 = pvary(jnp.zeros(mb_shape, x_local.dtype), (axis,))
        (outs, _), _ = jax.lax.scan(
            tick, (outs0, cur0), jnp.arange(m + s_total - 1)
        )
        # Only the last stage holds real outputs; broadcast via psum-mask.
        mask = (stage == s_total - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    n_extra = x.ndim - 1
    stage_specs = jax.tree_util.tree_map(
        lambda t: P(axis, *([None] * (t.ndim - 1))), stage_params
    )
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(stage_specs, P(*([None] * (n_extra + 1)))),
        out_specs=P(*([None] * (n_extra + 1))),
    )(stage_params, x)


def stage_split(params_stacked, n_stages: int):
    """Reshape a (L, ...) layer-stacked tree into (S, L/S, ...) stages."""

    def reshape(t):
        l = t.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return t.reshape((n_stages, l // n_stages) + t.shape[1:])

    return jax.tree_util.tree_map(reshape, params_stacked)
