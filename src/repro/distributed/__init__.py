"""Distributed runtime: sharding rules, explicit collectives, pipeline PP."""
from repro.distributed import collectives, pipeline, sharding

__all__ = ["collectives", "pipeline", "sharding"]
