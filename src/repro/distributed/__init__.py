"""Distributed runtime: sharding rules, explicit collectives, pipeline PP,
and ring sequence-parallel (context-parallel) attention."""
from repro.distributed import collectives, pipeline, ring_attention, sharding

__all__ = ["collectives", "pipeline", "ring_attention", "sharding"]
