"""Elasticity, failure handling, straggler mitigation — the control-plane
logic (pure, unit-tested); the data plane is mesh-agnostic checkpoints
(train.checkpoint) + reshard-on-restore (distributed.sharding).

At 1000+ nodes the failure model is: a pod/host drops → the job controller
(1) drains, (2) emergency-checkpoints from surviving hosts, (3) replans the
mesh for the surviving device count, (4) restarts from the latest step with
a deterministic re-assignment of data shards.  These helpers implement the
deterministic pieces of that loop; ``train.supervisor.TrainSupervisor``
drives them against a live Trainer (DESIGN.md §Training robustness).
"""
from __future__ import annotations

from dataclasses import dataclass, field

# -- frozen observability schema --------------------------------------------
# The training analog of serve.lifecycle.COUNTER_KEYS: Trainer and
# TrainSupervisor both snapshot against THIS key set (zero-filled), and
# tests/test_train_chaos.py freezes it with a regression test.  Adding a
# counter means adding it here, on purpose.

#: Robustness counters common to Trainer and TrainSupervisor.
COUNTER_KEYS = (
    "nan_skips",  # in-step NaN guard suppressed an update
    "rollbacks",  # anomaly guard restored params+opt from a checkpoint
    "anomaly_halts",  # rollback retries exhausted → AnomalyHalt
    "torn_ckpt_fallbacks",  # resume/rollback skipped corrupt checkpoints
    "data_corrupt_batches",  # injected data_shard_corrupt batches seen
    "emergency_saves",  # best-effort checkpoint on an escaping exception
    "emergency_save_failures",  # ... and the save itself failed (logged)
    "remesh_events",  # mesh replanned to a new survivor count
    "worker_deaths",  # workers declared dead by the FailureDetector
    "straggler_flags",  # StragglerPolicy flag events (per worker per tick)
)


def counters_view(counters) -> dict:
    """Freeze a Counter/dict into the canonical zero-filled schema."""
    return {k: int(counters.get(k, 0)) for k in COUNTER_KEYS}


def reassign_shards(num_shards: int, alive_workers: list[int]) -> dict[int, list[int]]:
    """Deterministic round-robin data-shard → surviving-worker assignment.

    Restart-safe: depends only on (num_shards, sorted alive set).
    """
    alive = sorted(alive_workers)
    if not alive:
        raise ValueError("no surviving workers")
    out: dict[int, list[int]] = {w: [] for w in alive}
    for s in range(num_shards):
        out[alive[s % len(alive)]].append(s)
    return out


def replan_mesh(n_devices: int, *, model_parallel: int = 16,
                pods: int | None = None) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) mesh fitting the surviving device count.

    Keeps TP fixed (model_parallel must divide per-pod devices — resharding
    TP means re-tiling every weight, whereas shrinking DP is free with
    mesh-agnostic checkpoints).
    """
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by TP={model_parallel}")
    data = n_devices // model_parallel
    if pods and pods > 1:
        if data % pods:
            pods = 1  # fall back to single logical pod
        else:
            return (pods, data // pods, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


@dataclass
class StragglerPolicy:
    """Flag workers whose step time exceeds ``threshold``× the median.

    The trainer reacts by (a) logging, (b) after ``patience`` consecutive
    flags, excluding the worker and triggering the elastic replan path.
    """

    threshold: float = 2.0
    patience: int = 3

    def flag(self, step_times: dict[int, float]) -> list[int]:
        if not step_times:
            return []
        times = sorted(step_times.values())
        median = times[len(times) // 2]
        return [w for w, t in step_times.items() if t > self.threshold * median]


@dataclass
class StragglerTracker:
    """Stateful wrapper over :class:`StragglerPolicy`: tracks *consecutive*
    flags per worker and reports the ones that crossed ``patience`` —
    the point where the supervisor excludes the worker and triggers the
    elastic replan path.  A single slow step clears on the next fast one;
    only a persistent straggler escalates."""

    policy: StragglerPolicy = field(default_factory=StragglerPolicy)
    _consecutive: dict[int, int] = field(default_factory=dict, repr=False)

    def observe(self, step_times: dict[int, float]) -> tuple[list[int], list[int]]:
        """Feed one round of per-worker step times → ``(flagged, to_exclude)``:
        workers flagged this round, and those whose consecutive-flag streak
        just reached ``policy.patience``."""
        flagged = set(self.policy.flag(step_times))
        to_exclude = []
        for w in step_times:
            if w in flagged:
                self._consecutive[w] = self._consecutive.get(w, 0) + 1
                if self._consecutive[w] == self.policy.patience:
                    to_exclude.append(w)
            else:
                self._consecutive[w] = 0
        return sorted(flagged), sorted(to_exclude)

    def forget(self, worker: int) -> None:
        """Drop tracking for an excluded/dead worker."""
        self._consecutive.pop(worker, None)


class FailureDetector:
    """Heartbeat bookkeeping: a worker missing ``max_missed`` beats is dead."""

    def __init__(self, workers: list[int], max_missed: int = 3):
        self.max_missed = max_missed
        self._missed = {w: 0 for w in workers}

    def beat(self, worker: int) -> None:
        if worker in self._missed:
            self._missed[worker] = 0

    def tick(self) -> list[int]:
        """Advance one heartbeat interval; returns newly-dead workers."""
        dead = []
        for w in list(self._missed):
            self._missed[w] += 1
            if self._missed[w] >= self.max_missed:
                dead.append(w)
                del self._missed[w]
        return dead

    @property
    def alive(self) -> list[int]:
        return sorted(self._missed)
