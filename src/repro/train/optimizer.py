"""AdamW + LR schedules (cosine, WSD) — built from scratch (no optax here).

Optimizer state is a pytree congruent with params, so the FSDP/TP shardings
derived for parameters apply 1:1 to the moments (repro.distributed.sharding).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils import tree_norm


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | wsd | constant
    wsd_decay_frac: float = 0.1  # WSD: final fraction of steps spent decaying
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_accum: int = 1  # microbatch accumulation steps


def schedule(opt_cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Learning rate at ``step`` (traced-friendly)."""
    step = step.astype(jnp.float32)
    warm = opt_cfg.warmup_steps
    total = opt_cfg.total_steps
    peak = opt_cfg.peak_lr
    floor = peak * opt_cfg.min_lr_ratio

    warmup_lr = peak * step / jnp.maximum(warm, 1)

    if opt_cfg.schedule == "constant":
        post = jnp.full_like(step, peak)
    elif opt_cfg.schedule == "cosine":
        frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
        post = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    elif opt_cfg.schedule == "wsd":
        # Warmup-Stable-Decay (minicpm): hold at peak, then linear decay over
        # the final wsd_decay_frac of training.
        decay_steps = jnp.maximum(total * opt_cfg.wsd_decay_frac, 1)
        decay_start = total - decay_steps
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        post = peak - (peak - floor) * frac
    else:
        raise ValueError(f"unknown schedule {opt_cfg.schedule!r}")
    return jnp.where(step < warm, warmup_lr, post)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gnorm = tree_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adamw_update(params, grads, state, opt_cfg: OptimizerConfig, lr):
    """One AdamW step → (new_params, new_state)."""
    count = state["count"] + 1
    b1, b2 = opt_cfg.b1, opt_cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        m_hat = m / c1
        v_hat = v / c2
        step_ = m_hat / (jnp.sqrt(v_hat) + opt_cfg.eps)
        if opt_cfg.weight_decay and jnp.issubdtype(p.dtype, jnp.floating):
            step_ = step_ + opt_cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
